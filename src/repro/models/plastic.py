"""Plastic fast-weight adapter — FireFly-P's rule as an LM serving feature.

A two-population spiking micro-network rides on the backbone's hidden state
during DECODE (adaptation is a serve-time behavior, matching the paper's
Phase 2).  Per decode step, per request:

    drive   = h @ P_in                  (fixed random projection, D -> N)
    s1      = LIF(v1, drive)            (presynaptic population)
    s2, W_fast <- PlasticEngine.layer_step(s1)   (fused forward + rule)
    h'      = h + scale * (s2 @ P_out)  (readout back into the residual)

The synaptic layer between the two populations is ONE fleet-mode
`core.engine.layer_step` over the whole batch: W_fast carries a leading
request rank (B, N, N) and every decode stream rewrites its own synapses
with a per-sample dw inside a single fused launch (grid (tiles, B) on
Pallas) — not B vmap-stamped kernel calls.  The serving hot path runs the
SAME fused dual-engine program as the SNN controller; ``cfg.adapter_impl``
selects the backend ("xla" | "pallas" | "pallas-interpret").

W_fast starts at ZERO and lives in the decode cache (B, N, N) — one plastic
memory per request stream, continuously rewritten online.  theta is the
offline-learned rule (ES / PEPG in core/), frozen at serve time.

Applicability notes per arch family are in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core import plasticity as P
from repro.core.snn import LIFConfig, lif_step
from repro.models.config import ModelConfig
from repro.models.layers import ParamDesc

LIF = LIFConfig(tau_m=2.0, v_threshold=1.0, v_reset=0.0)


def plan(cfg: ModelConfig) -> dict:
    d, n = cfg.d_model, cfg.adapter_neurons
    return {
        "p_in": ParamDesc((d, n), ("data", "model"), fan_in=d, dtype=cfg.dtype),
        "p_out": ParamDesc((n, d), ("model", "data"), fan_in=n, dtype=cfg.dtype),
        "theta": ParamDesc((P.NUM_TERMS, n, n), (None, None, "model"),
                           scale=0.3, fan_in=n, dtype="float32"),
        "scale": ParamDesc((), (), init="zeros", dtype="float32"),
    }


def plan_cache(cfg: ModelConfig, batch: int) -> dict:
    n = cfg.adapter_neurons
    f32 = "float32"

    def z(shape, spec):
        return ParamDesc(shape, spec, init="zeros", dtype=f32)

    return {
        "w_fast": z((batch, n, n), ("data", None, "model")),
        "v1": z((batch, n), ("data", "model")),
        "v2": z((batch, n), ("data", "model")),
        "tr1": z((batch, n), ("data", "model")),
        "tr2": z((batch, n), ("data", "model")),
    }


def decode_step(params, state: dict, h, cfg: ModelConfig,
                trace_decay: float = 0.8, w_clip: float = 4.0):
    """h (B,1,D) -> (h', new_state).  One online plasticity step per token."""
    drive = jnp.einsum("bd,dn->bn", h[:, 0].astype(jnp.float32),
                       params["p_in"].astype(jnp.float32))
    v1, s1 = lif_step(state["v1"], drive, LIF)
    tr1 = P.update_trace(state["tr1"], s1, trace_decay)

    # Plastic synaptic layer: ONE fleet-mode fused dual-engine launch over
    # all request streams — w_fast (B, N, N) triggers per-sample dw, each
    # stream rewriting its own W_fast against the shared rule theta.
    ep = engine.EngineParams(
        tau_m=LIF.tau_m, v_th=LIF.v_threshold, v_reset=LIF.v_reset,
        trace_decay=trace_decay, w_clip=w_clip, plastic=True, spiking=True)
    layer = engine.LayerState(
        w=state["w_fast"], v=state["v2"], trace_pre=tr1,
        trace_post=state["tr2"], theta=params["theta"].astype(jnp.float32))
    layer, s2 = engine.layer_step(layer, s1, params=ep,
                                  impl=cfg.adapter_impl)

    out = jnp.einsum("bn,nd->bd", s2, params["p_out"].astype(jnp.float32))
    h = h + (params["scale"] * out[:, None, :]).astype(h.dtype)
    return h, {"w_fast": layer.w, "v1": v1, "v2": layer.v,
               "tr1": tr1, "tr2": layer.trace_post}


def decode_rollout(params, state: dict, h, cfg: ModelConfig,
                   trace_decay: float = 0.8, w_clip: float = 4.0):
    """h (B, K, D) -> (h', new_state).  K plasticity steps, ONE fused launch.

    The multi-token form of K sequential `decode_step` calls — speculative
    drafts, chunked prefill tails, any case where a decode stream advances
    several tokens at once.  The presynaptic population is feedforward
    (v1/s1 depend only on the tokens), so its LIF series is peeled into a
    cheap scan of per-token projections; the expensive part — K steps of
    the plastic synaptic layer, forward + four-term rule on every stream's
    own (N, N) W_fast — then runs as ONE time-fused `engine.rollout`
    launch (a single `pallas_call` on the Pallas backends) instead of K
    per-token `layer_step` launches.  Bit-identical to the sequential path
    (`tests/test_fused.py` pins it): the per-token einsums stay per-token
    inside scans, and the rollout oracle is the same `layer_step` program.
    """
    p_in = params["p_in"].astype(jnp.float32)
    p_out = params["p_out"].astype(jnp.float32)
    hk = jnp.swapaxes(h, 0, 1)                       # time-major (K, B, D)

    def pre(v1, h_t):
        drive = jnp.einsum("bd,dn->bn", h_t.astype(jnp.float32), p_in)
        v1, s1 = lif_step(v1, drive, LIF)
        return v1, s1

    v1, s1_series = jax.lax.scan(pre, state["v1"], hk)   # (K, B, N)

    ep = engine.EngineParams(
        tau_m=LIF.tau_m, v_th=LIF.v_threshold, v_reset=LIF.v_reset,
        trace_decay=trace_decay, w_clip=w_clip, plastic=True, spiking=True)
    net = engine.NetworkState(
        w=(state["w_fast"],), v=(state["v2"],),
        trace=(state["tr1"], state["tr2"]), t=jnp.zeros((), jnp.int32))
    net, s2_series = engine.rollout(
        net, [params["theta"].astype(jnp.float32)], s1_series,
        params=ep, impl=cfg.adapter_impl)

    def post(_, s2):
        return None, jnp.einsum("bn,nd->bd", s2, p_out)

    _, outs = jax.lax.scan(post, None, s2_series)        # (K, B, D)
    h = h + (params["scale"] * jnp.swapaxes(outs, 0, 1)).astype(h.dtype)
    return h, {"w_fast": net.w[0], "v1": v1, "v2": net.v[0],
               "tr1": net.trace[0], "tr2": net.trace[1]}
