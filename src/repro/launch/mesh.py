"""Production mesh construction (functions, not module constants, so the
import never touches jax device state).

  single-pod:  (16, 16)      axes (data, model)   — 256 chips (one v5e pod)
  multi-pod:   (2, 16, 16)   axes (pod, data, model) — 512 chips

Model code names only LOGICAL axes ("data"/"model"/"seq");
distributed/sharding.py maps "data" to ("pod","data") when a pod axis
exists, so the same program lowers on either mesh unchanged.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Mesh over whatever devices exist (CPU smoke / small runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16 * 1024**3,   # 16 GiB
}
