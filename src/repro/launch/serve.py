"""Batched serving driver: prefill + decode with KV/SSM caches, optional
FireFly-P plastic adapter (the paper's Phase-2 online adaptation running
inside an LM serving stack).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --batch 4 --prompt-len 32 --gen 16 --plastic [--plastic-impl pallas]

With --plastic every decode step runs the fused dual-engine program
(core.engine.layer_step) once per request stream; --plastic-impl picks the
backend ("xla" oracle, "pallas" TPU kernel, "pallas-interpret" validation).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.distributed import sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_decode_step, make_prefill
from repro.models import transformer as T


def generate(cfg, params, prompts, max_len: int, gen: int,
             temperature: float = 0.0, seed: int = 0):
    """Greedy/temperature sampling loop.  prompts (B, S) int32.

    Returns (tokens (B, gen), per-step latencies).  The decode step is
    AOT-compiled BEFORE the timed loop — historically the first iteration
    absorbed the jit compile, skewing decode_ms_p50/mean and tokens_per_s;
    all reported latencies are now steady-state."""
    prefill = jax.jit(make_prefill(cfg, max_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    logits, cache = prefill(params, prompts)
    key = jax.random.PRNGKey(seed)
    outs, lats = [], []
    tok = _sample(logits, key, temperature)
    # Warm-up: compile against the real avals without consuming the (donated)
    # cache buffers or advancing the generation state; the loop calls the
    # compiled executable, so no iteration pays trace+compile.
    decode_c = decode.lower(params, cache, tok[:, None]).compile()
    for i in range(gen):
        outs.append(tok)
        t0 = time.perf_counter()
        logits, cache = decode_c(params, cache, tok[:, None])
        logits.block_until_ready()
        lats.append(time.perf_counter() - t0)
        key = jax.random.fold_in(key, i)
        tok = _sample(logits, key, temperature)
    return jnp.stack(outs, axis=1), lats


def _sample(logits, key, temperature):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--plastic", action="store_true",
                    help="attach the FireFly-P plastic adapter at decode")
    ap.add_argument("--plastic-impl", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"],
                    help="PlasticEngine backend for the adapter's fused "
                         "dual-engine step (pallas on TPU)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (2.3x decode memory-roofline win)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.plastic:
        cfg = cfg.with_(plastic_adapter=True,
                        adapter_neurons=min(128, cfg.d_model),
                        adapter_impl=args.plastic_impl)
    if args.kv_quant:
        cfg = cfg.with_(kv_quant=True)
    mesh = make_local_mesh()
    max_len = args.prompt_len + args.gen

    with shd.use_mesh(mesh), mesh:
        params = T.init(cfg, jax.random.PRNGKey(args.seed))
        prompts = jax.random.randint(
            jax.random.PRNGKey(args.seed + 1),
            (args.batch, args.prompt_len), 0, cfg.vocab)
        if cfg.input_mode == "embeddings":
            prompts_in = jax.nn.one_hot(prompts % cfg.d_model, cfg.d_model,
                                        dtype=cfg.adtype)
        else:
            prompts_in = prompts
        toks, lats = generate(cfg, params, prompts_in, max_len, args.gen,
                              args.temperature, args.seed)

    print(json.dumps({
        "arch": cfg.name, "plastic": bool(cfg.plastic_adapter),
        "batch": args.batch, "generated": int(toks.shape[1]),
        "decode_ms_p50": sorted(lats)[len(lats) // 2] * 1e3,
        "decode_ms_mean": sum(lats) / len(lats) * 1e3,
        "tokens_per_s": args.batch * len(lats) / sum(lats),
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
