"""Config-driven model factory: one uniform surface over every layout.

`build(arch_or_cfg)` turns any `ModelConfig` in `repro.configs` — dense GQA
(qwen*, internlm2, musicgen, pixtral), fine-grained MoE (deepseek-moe,
grok-1), Mamba2 SSM (mamba2), Zamba2 hybrid — into a `Model` bundle whose
entry points (`init` / `forward` / `loss_fn` / `prefill` / `decode_step` /
`decode_rollout` / cache builders) are what `launch/steps.py`,
`launch/serve.py`, and `serving.lm.LMScheduler` consume.  Callers never
import `models.transformer` directly: a config that the factory cannot
lower fails `tests/test_factory.py` at tier-1 instead of failing at serve
time.

The factory also owns the SERVING-POOL plumbing the `SessionPool`
machinery needs (`serving/scheduler.py`): which axis of each decode-cache
leaf carries the slot rows (`cache_axes` — inferred structurally, so a new
segment layout cannot silently desynchronize the scheduler's gather/
scatter), a pooled cache with per-slot sequence indices (`pool_cache`),
and the B=1-prefill -> session-row conversion (`session_from_prefill`)
that makes "admit a freshly prefilled stream" one traced-slot scatter.

Layout x adapter applicability is documented in DESIGN.md
§Arch-applicability.
"""
from __future__ import annotations

from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke
from repro.core.engine import IMPLS
from repro.models import transformer as T
from repro.models.config import ModelConfig

_LAYOUTS = ("dense", "moe", "ssm", "hybrid")


def _validate(cfg: ModelConfig) -> None:
    if not isinstance(cfg, ModelConfig):
        raise TypeError(
            f"factory.build needs a ModelConfig (an LM backbone); got "
            f"{type(cfg).__name__}.  The 'firefly-snn' arch is the paper's "
            "SNN controller (core.snn.SNNConfig) — it is served through "
            "serving.FleetScheduler, not the LM decode path.")
    if cfg.layout not in _LAYOUTS:
        raise ValueError(f"unknown layout {cfg.layout!r}; expected one of "
                         f"{_LAYOUTS}")
    if cfg.layout == "moe" and cfg.moe is None:
        raise ValueError(f"{cfg.name}: layout 'moe' needs cfg.moe")
    if cfg.layout in ("ssm", "hybrid") and cfg.ssm is None:
        raise ValueError(f"{cfg.name}: layout {cfg.layout!r} needs cfg.ssm")
    if cfg.plastic_adapter:
        if cfg.adapter_impl not in IMPLS:
            raise ValueError(
                f"{cfg.name}: adapter_impl must be one of {IMPLS}, got "
                f"{cfg.adapter_impl!r}")
        if cfg.adapter_neurons < 1:
            raise ValueError(f"{cfg.name}: plastic_adapter needs "
                             f"adapter_neurons >= 1")


def _infer_axes(cfg: ModelConfig, max_len: int):
    """Per-leaf slot axis of the pooled decode cache, found structurally:
    the one axis whose extent tracks the batch argument.  Survives any
    segment layout (zsuper's stacked inner SSM caches put the slot axis at
    position 2) without hand-maintained tables."""
    import numpy as np
    a = T.cache_plan(cfg, 2, max_len, per_slot_index=True)
    b = T.cache_plan(cfg, 3, max_len, per_slot_index=True)

    def one(da, db):
        diff = [i for i, (x, y) in enumerate(zip(da.shape, db.shape))
                if x != y]
        if len(diff) != 1:
            raise ValueError(
                f"cannot infer the slot axis of cache leaf {da.shape} vs "
                f"{db.shape}: expected exactly one batch-tracking axis, "
                f"found {diff}")
        return diff[0]

    is_desc = lambda x: hasattr(x, "shape") and hasattr(x, "spec")
    return jax.tree.map(one, a, b, is_leaf=is_desc)


class Model:
    """A `ModelConfig` bound to every entry point the stack consumes.

    Thin by design: each method forwards to `models.transformer` (which
    already dispatches per segment kind), so the factory adds validation
    and the serving-pool plumbing, not a parallel implementation.
    """

    def __init__(self, cfg: ModelConfig):
        _validate(cfg)
        self.cfg = cfg

    # ---- parameters ------------------------------------------------------

    def plan(self, fsdp: bool = True):
        return T.plan(self.cfg, fsdp)

    def init(self, key: jax.Array, fsdp: bool = True):
        return T.init(self.cfg, key, fsdp)

    def abstract(self, mesh=None, fsdp: bool = True):
        return T.abstract(self.cfg, mesh, fsdp)

    def shardings(self, mesh, fsdp: bool = True):
        return T.shardings(self.cfg, mesh, fsdp)

    def n_params(self) -> int:
        return T.n_params(self.cfg)

    # ---- train / eval ----------------------------------------------------

    def forward(self, params, inputs, **kw):
        return T.forward(params, inputs, self.cfg, **kw)

    def loss_fn(self, params, batch, **kw):
        return T.loss_fn(params, batch, self.cfg, **kw)

    # ---- serving ---------------------------------------------------------

    def prefill(self, params, inputs, max_len: int, **kw):
        return T.prefill(params, inputs, self.cfg, max_len, **kw)

    def decode_step(self, params, cache, tokens, active=None):
        return T.decode_step(params, cache, tokens, self.cfg, active=active)

    def decode_rollout(self, params, cache, tokens, active=None):
        return T.decode_rollout(params, cache, tokens, self.cfg,
                                active=active)

    def cache_plan(self, batch: int, max_len: int,
                   per_slot_index: bool = False):
        return T.cache_plan(self.cfg, batch, max_len, per_slot_index)

    def init_cache(self, batch: int, max_len: int,
                   per_slot_index: bool = False):
        return T.init_cache(self.cfg, batch, max_len, per_slot_index)

    # ---- serving-pool plumbing (SessionPool contract) --------------------

    def pool_cache(self, slots: int, max_len: int):
        """Zeroed pooled decode cache: per-slot ``(B,)`` sequence indices,
        one session row per slot in every leaf."""
        return T.init_cache(self.cfg, slots, max_len, per_slot_index=True)

    def cache_axes(self, max_len: int):
        """Slot-axes pytree for `pool_cache` (see `serving.scheduler`)."""
        return _infer_axes(self.cfg, max_len)

    def session_from_prefill(self, cache1):
        """Squeeze a B=1 prefill cache into one session row (the pytree a
        `SessionPool` scatters into a slot and a `SessionStore` persists).
        The prefill's scalar index passes through as the session's
        position."""
        axes = self.cache_axes(self._max_len_of(cache1))

        def one(leaf, ax):
            leaf = jnp.asarray(leaf)
            if leaf.ndim > ax and leaf.shape[ax] == 1:
                return jnp.squeeze(leaf, ax)
            if leaf.ndim == 0:      # the scalar prefill index
                return leaf
            raise ValueError(
                f"session_from_prefill needs a batch=1 cache; got a leaf "
                f"of shape {leaf.shape} with slot axis {ax}")

        return jax.tree.map(one, cache1, axes)

    def session_template(self, max_len: int):
        """Abstract one-session pytree (ShapeDtypeStructs): the
        `SessionStore` validation template for this pool layout."""
        pool = jax.eval_shape(
            lambda: self.pool_cache(2, max_len))

        def one(leaf, ax):
            shape = leaf.shape[:ax] + leaf.shape[ax + 1:]
            return jax.ShapeDtypeStruct(shape, leaf.dtype)

        return jax.tree.map(one, pool, self.cache_axes(max_len))

    @staticmethod
    def _max_len_of(cache) -> int:
        # any attention/ssm layout keeps max_len discoverable from the
        # index-free leaves only through construction args; callers that
        # built the cache know it — this helper just needs A consistent
        # value for axis inference, which does not depend on max_len.
        return 8


def build(arch_or_cfg: Union[str, ModelConfig], smoke: bool = False,
          **overrides) -> Model:
    """Resolve an arch id (or pass a ModelConfig through), apply overrides,
    validate, and return the bound `Model` bundle.

    ``smoke=True`` resolves the reduced same-family config (CPU tests).
    ``overrides`` are `ModelConfig.with_` fields (e.g.
    ``plastic_adapter=True, adapter_impl="pallas-interpret"``).
    """
    if isinstance(arch_or_cfg, str):
        if arch_or_cfg not in ARCHS:
            raise KeyError(f"unknown arch {arch_or_cfg!r}; choose from "
                           f"{ARCHS}")
        cfg = (get_smoke(arch_or_cfg) if smoke else get_config(arch_or_cfg))
    else:
        cfg = arch_or_cfg
    if not isinstance(cfg, ModelConfig):
        _validate(cfg)  # raises the informative TypeError (firefly-snn)
    if overrides:
        cfg = cfg.with_(**overrides)
    return Model(cfg)
