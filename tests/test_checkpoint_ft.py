"""Checkpointing, fault tolerance, elastic restore."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.core import snn
from repro.distributed.ft import (FaultTolerantRunner, StragglerMonitor,
                                  loss_is_bad)


@pytest.fixture
def tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"mu": jnp.ones((3, 4)), "step": jnp.asarray(7)}}


class TestCheckpoint:
    def test_roundtrip(self, tree, tmp_path):
        save_checkpoint(str(tmp_path), 3, tree, extra={"k": 1})
        out, step, extra = load_checkpoint(str(tmp_path), tree)
        assert step == 3 and extra == {"k": 1}
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_k_gc(self, tree, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tree, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(5, tree, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_partial_dir_ignored(self, tree, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(1, tree)
        os.makedirs(tmp_path / "step_000000099.tmp")   # crashed writer
        assert mgr.latest_step() == 1
        mgr.gc()
        assert not (tmp_path / "step_000000099.tmp").exists()

    def test_shape_mismatch_rejected(self, tree, tmp_path):
        save_checkpoint(str(tmp_path), 1, tree)
        bad = dict(tree, w=jnp.zeros((5, 5)))
        with pytest.raises(ValueError):
            load_checkpoint(str(tmp_path), bad)

    def test_reshard_on_load(self, tree, tmp_path):
        """Restore places leaves onto explicit shardings (elastic path)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        save_checkpoint(str(tmp_path), 1, tree)
        mesh = jax.make_mesh((1,), ("data",))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
        out, _, _ = load_checkpoint(str(tmp_path), tree, shardings=sh)
        assert out["w"].sharding == NamedSharding(mesh, P())


class TestRegisteredDataclassCheckpoint:
    """checkpoint.manager round-trips registered-dataclass pytrees — the
    `NetworkState` (tuple-of-array fields) the SessionStore persists per
    user — bit-identically, on the session directory layout."""

    def _state(self, seed=0):
        cfg = snn.SNNConfig(layer_sizes=(6, 12, 4))
        z = snn.init_state(cfg)
        ks = jax.random.split(jax.random.PRNGKey(seed), len(z.w))
        return cfg, dataclasses.replace(
            z,
            w=tuple(0.2 * jax.random.normal(k, w.shape)
                    for k, w in zip(ks, z.w)),
            t=jnp.asarray(9, jnp.int32))

    def test_networkstate_roundtrip_bit_identical(self, tmp_path):
        cfg, st = self._state()
        save_checkpoint(str(tmp_path), 9, st)
        out, step, _ = load_checkpoint(str(tmp_path), snn.init_state(cfg))
        assert step == 9
        assert type(out) is type(st) and len(out.w) == len(st.w)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
            assert np.asarray(a).dtype == np.asarray(b).dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_continuation_after_restore_is_bit_identical(self, tmp_path):
        """Restore -> step must equal step-without-the-detour: the round
        trip may not perturb a single bit of the subsequent trajectory."""
        cfg, st = self._state(1)
        theta = snn.init_theta(cfg, jax.random.PRNGKey(2))
        drive = jax.random.normal(jax.random.PRNGKey(3), (6,))
        save_checkpoint(str(tmp_path), 1, st)
        restored, _, _ = load_checkpoint(str(tmp_path), snn.init_state(cfg))
        s1, o1 = snn.timestep(cfg, st, theta, drive)
        s2, o2 = snn.timestep(cfg, restored, theta, drive)
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_session_layout_gc_and_latest(self, tmp_path):
        """keep-K gc + LATEST on the per-user directory the SessionStore
        uses (<root>/<uid>/step_*): repeated checkins rotate checkpoints."""
        from repro.serving import SessionStore
        cfg, st = self._state(2)
        store = SessionStore(root=str(tmp_path), keep=2)
        for step in (1, 2, 3, 4):
            store.checkin("alice", st, step)
        mgr = CheckpointManager(str(tmp_path / "alice"), keep=2)
        assert mgr.all_steps() == [3, 4]           # keep-K rotated
        assert mgr.latest_step() == 4              # LATEST pointer current
        assert (tmp_path / "alice" / "LATEST").exists()
        # a second user's directory is independent
        store.checkin("bob", st, 7)
        assert CheckpointManager(str(tmp_path / "bob")).latest_step() == 7
        assert mgr.latest_step() == 4


class TestStraggler:
    def test_flags_outlier(self):
        mon = StragglerMonitor(warmup=3, k=3.0)
        flagged = [mon.observe(0.1 + 0.001 * i) for i in range(10)]
        assert not any(flagged)
        assert mon.observe(10.0)

    def test_warmup_never_flags(self):
        mon = StragglerMonitor(warmup=5)
        assert not any(mon.observe(t) for t in (0.1, 99.0, 0.1, 50.0, 0.1))

    def test_warmup_primes_sample_variance(self):
        """After warmup, `var` is the unbiased sample variance of the
        warmup observations (Welford), not an unnormalized M2 sum — the
        historical bug kept the M2 sum in `var`, so the first post-warmup
        std was sqrt(sum) and every EWMA step shrank it further."""
        vals = [0.10, 0.13, 0.09, 0.15, 0.11]
        mon = StragglerMonitor(warmup=len(vals))
        for v in vals:
            mon.observe(v)
        assert mon.mean == pytest.approx(np.mean(vals))
        assert mon.var == pytest.approx(np.var(vals, ddof=1))

    def test_warmup_clamped_to_two_observations(self):
        """warmup=0/1 must not let the second observation flag off a
        degenerate (single-sample) std of 1e-9."""
        for w in (0, 1):
            mon = StragglerMonitor(warmup=w, k=3.0)
            assert not mon.observe(0.1)
            assert not mon.observe(0.1001)   # would flag pre-clamp
            assert mon.observe(10.0)         # genuine outlier still flags

    def test_flags_with_realistic_variance(self):
        """A 2x step-time spike over a noisy-but-stable baseline flags;
        baseline noise within the spread does not (the sample-variance
        priming keeps std honest instead of biased low)."""
        rng = np.random.RandomState(0)
        mon = StragglerMonitor(warmup=10, k=4.0)
        flagged = [mon.observe(0.1 + 0.005 * rng.rand())
                   for _ in range(50)]
        assert not any(flagged)
        assert mon.observe(0.2)


class TestFaultTolerantRunner:
    def _runner(self, tmp_path, poison_at=None):
        calls = {"n": 0}

        def step(state, batch):
            calls["n"] += 1
            x = state["x"] + batch
            loss = jnp.where(
                jnp.asarray(poison_at == int(batch)), jnp.nan, x.sum())
            return {"x": x}, {"loss": loss}

        ckpt = CheckpointManager(str(tmp_path), keep=3)
        return FaultTolerantRunner(step, ckpt, save_every=2,
                                   max_rollbacks=3), calls

    def test_runs_and_checkpoints(self, tmp_path):
        runner, _ = self._runner(tmp_path)
        state, hist = runner.run({"x": jnp.zeros(())},
                                 lambda s: jnp.asarray(float(s)), 6)
        assert len(hist) == 6
        assert runner.ckpt.latest_step() == 6
        assert float(state["x"]) == sum(range(6))

    def test_nan_rollback_skips_poisoned_batch(self, tmp_path):
        runner, _ = self._runner(tmp_path, poison_at=3)
        state, hist = runner.run({"x": jnp.zeros(())},
                                 lambda s: jnp.asarray(float(s)), 6)
        assert runner.rollbacks == 1
        assert 3 in runner.skipped_steps
        assert all(np.isfinite(h["loss"]) for h in hist)

    def test_resume_from_checkpoint(self, tmp_path):
        runner, _ = self._runner(tmp_path)
        state, _ = runner.run({"x": jnp.zeros(())},
                              lambda s: jnp.asarray(1.0), 4)
        runner2, _ = self._runner(tmp_path)
        state2, start = runner2.restore_or_init({"x": jnp.zeros(())})
        assert start == 4
        assert float(state2["x"]) == 4.0

    def test_rollback_budget_enforced(self, tmp_path):
        def bad_step(state, batch):
            return state, {"loss": jnp.nan}

        ckpt = CheckpointManager(str(tmp_path), keep=2)
        runner = FaultTolerantRunner(bad_step, ckpt, save_every=10,
                                     max_rollbacks=2)
        with pytest.raises(RuntimeError):
            runner.run({"x": jnp.zeros(())}, lambda s: jnp.zeros(()), 5)


def test_loss_is_bad():
    assert loss_is_bad(float("nan")) and loss_is_bad(float("inf"))
    assert not loss_is_bad(3.5)


def test_loss_is_bad_arrays():
    """Per-shard / per-session loss vectors: the reduction is any-NaN —
    one poisoned shard poisons the step like one poisoned scalar."""
    assert loss_is_bad(np.array([1.0, np.nan, 3.0]))
    assert loss_is_bad(jnp.array([1.0, -np.inf]))
    assert loss_is_bad(np.full((2, 3), np.nan))
    assert not loss_is_bad(np.zeros(4))
    assert not loss_is_bad(jnp.arange(6.0).reshape(2, 3))
    assert not loss_is_bad(jnp.float32(2.0))
