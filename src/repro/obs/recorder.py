"""Device-side flight recorder: per-slot telemetry history + incident dumps.

The black box for the serving pools: a fixed-shape ``(B, W, C)`` ring
buffer of per-slot telemetry channels (`health.CHANNELS` — spike rate,
mean |dw|, saturation fraction, weight-norm drift vs admission snapshot)
written INSIDE the existing jitted pool-step / decode programs as pure
array ops.  Recording is a static trace variant exactly like PR 8's
``telemetry=`` flag: the schedulers' ``record=`` flag dispatches one extra
stable executable per entry point, the off-path program stays byte-
identical to the unrecorded build, and a recorded step performs NO host
sync — the streaming detectors (`obs.health`) fold into the same launch
and the host reads the latched verdict only when it decides to act.

Ring mechanics: every slot records in lockstep (occupancy is a runtime
mask, not a shape), so ONE host-side cursor serves the whole pool — the
scheduler passes it in as a traced scalar operand (like the fleet clock,
it is replicated state under `engine.fleet_spmd`; every `RecorderState`
leaf is slot-major, so the state shards over the ``"data"`` axis at
axis 0 with no shared leaves).

`dump_incident` is the post-mortem exit: one JSON (verdicts, streaks,
config, registry snapshot, watchdog state) + one NPZ (the unrolled ring
and detector baselines) per flagged session — the `serve.py --flight-dir`
artifact format documented in README §Session health.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.health import (CHANNELS, DETECTORS, HealthConfig, HealthState,
                              health_update, init_health)
from repro.obs.telemetry import adapter_telemetry


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RecorderState:
    """Flight-recorder device state — every leaf slot-major ``(B, ...)``.

    ring     ``(B, W, C) float32`` channel history (W = cfg.window); row
             ``pos % W`` is overwritten each recorded step.
    wnorm0   ``(B,) float32`` admission-time weight-norm snapshot, captured
             ON DEVICE at the slot's first recorded step (host and device
             reduction orders never have to agree).
    health   streaming detector state (`obs.health.HealthState`).
    """

    ring: jax.Array
    wnorm0: jax.Array
    health: HealthState


def init_recorder(cfg: HealthConfig, slots: int) -> RecorderState:
    return RecorderState(
        ring=jnp.zeros((slots, cfg.window, len(CHANNELS)), jnp.float32),
        wnorm0=jnp.zeros((slots,), jnp.float32),
        health=init_health(cfg, slots))


def recorder_update(cfg: HealthConfig, rec: RecorderState,
                    channels: jax.Array, pos: jax.Array,
                    active: jax.Array) -> tuple:
    """One recorded step: ``(new_state, verdict (B,) bool)``.

    `channels` is the raw ``(B, C)`` vector in `health.CHANNELS` order
    with the LAST column carrying the CURRENT weight norm (not yet a
    drift): the recorder owns the admission snapshot, so the drift is
    computed here — ``wnorm0`` latches the first recorded active value and
    channel 3 becomes ``|wnorm - wnorm0|``.  `pos` is the traced global
    ring cursor.  Pure array ops; gates everything by `active` so vacant
    and frozen slots write exact zeros and never perturb their detector
    state.
    """
    act = jnp.asarray(active).astype(jnp.bool_)
    channels = channels.astype(jnp.float32)
    wnorm = channels[:, -1]
    first = act & (rec.health.steps == 0)
    wnorm0 = jnp.where(first, wnorm, rec.wnorm0)
    x = jnp.concatenate(
        [channels[:, :-1], jnp.abs(wnorm - wnorm0)[:, None]], axis=-1)
    x = jnp.where(act[:, None], x, 0.0)
    ring = rec.ring.at[:, pos % cfg.window].set(x)
    health, verdict = health_update(cfg, rec.health, x, act)
    return RecorderState(ring=ring, wnorm0=wnorm0, health=health), verdict


def reset_slot(rec: RecorderState, slot: jax.Array) -> RecorderState:
    """Zero one slot's rows across every recorder leaf (traced slot index —
    one executable serves all slots).  The scheduler calls this on
    admit/evict/rollback so a slot's history always belongs to exactly one
    session tenancy."""
    return jax.tree.map(
        lambda a: a.at[slot].set(jnp.zeros(a.shape[1:], a.dtype)), rec)


# ---- weight-norm channels ---------------------------------------------------


def network_weight_norm(state, quant: bool) -> jax.Array:
    """Per-slot mean |w| summed over layers for a fleet `NetworkState`
    (``(B,) float32``; int8 planes are dequantized by their per-slot
    scale so both datapaths report in float weight units)."""
    tot = None
    for i, w in enumerate(state.w):
        if quant:
            a = jnp.abs(w.astype(jnp.int32)).astype(jnp.float32) \
                .mean(axis=(-2, -1)) * state.w_scale[i]
        else:
            a = jnp.abs(w.astype(jnp.float32)).mean(axis=(-2, -1))
        tot = a if tot is None else tot + a
    return tot.astype(jnp.float32)


def adapter_weight_norm(adapter: dict, quant: bool) -> jax.Array:
    """Per-slot mean |w_fast| for an LM adapter cache (``(B,) float32``)."""
    w = adapter["w_fast"]
    if quant:
        return jnp.abs(w.astype(jnp.int32)).astype(jnp.float32) \
            .mean(axis=(-2, -1)) * adapter["w_scale"]
    return jnp.abs(w.astype(jnp.float32)).mean(axis=(-2, -1))


# ---- post-mortem export -----------------------------------------------------


def unroll_ring(ring_row: np.ndarray, pos: int, window: int) -> np.ndarray:
    """The valid portion of one slot's ring, oldest -> newest ``(n, C)``.

    `pos` is the recorder's global cursor (total recorded steps); only
    ``min(pos, window)`` rows have ever been written."""
    n = min(int(pos), window)
    if n == 0:
        return ring_row[:0]
    return np.roll(ring_row, -(int(pos) % window), axis=0)[-n:]


def _safe_uid(uid: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", str(uid)) or "session"


def dump_incident(directory: str, *, uid: str, slot: int,
                  rec: RecorderState, cfg: HealthConfig, pos: int,
                  registry=None, watchdog=None,
                  extra: Optional[dict] = None) -> str:
    """Write one incident's post-mortem bundle; returns the JSON path.

    Two files per incident, ``incident_<uid>_p<pos>.{json,npz}``: the JSON
    carries everything human/jq-readable — per-detector latched flags and
    streaks, the detector config, a metrics-registry snapshot, and the
    recompile-watchdog state at dump time — while the NPZ carries the
    arrays (unrolled ring history plus the EWMA baselines the verdict was
    computed against).
    """
    os.makedirs(directory, exist_ok=True)
    slot = int(slot)
    host = jax.device_get(rec)
    h: HealthState = host.health
    stem = f"incident_{_safe_uid(uid)}_p{int(pos)}"
    npz_path = os.path.join(directory, stem + ".npz")
    np.savez(
        npz_path,
        ring=unroll_ring(np.asarray(host.ring[slot]), pos, cfg.window),
        ewma_mean=np.asarray(h.ewma_mean[slot]),
        ewma_var=np.asarray(h.ewma_var[slot]),
        last=np.asarray(h.last[slot]),
        streaks=np.asarray(h.streaks[slot]),
        flagged=np.asarray(h.flagged[slot]),
        wnorm0=np.asarray(host.wnorm0[slot]))
    flags = np.asarray(h.flagged[slot])
    doc = {
        "uid": str(uid),
        "slot": slot,
        "pos": int(pos),
        "channels": list(CHANNELS),
        "detectors": list(DETECTORS),
        "verdict": bool(flags.any()),
        "flagged": {d: bool(flags[i]) for i, d in enumerate(DETECTORS)},
        "streaks": {d: int(h.streaks[slot][i])
                    for i, d in enumerate(DETECTORS)},
        "recorded_steps": int(h.steps[slot]),
        "wnorm0": float(host.wnorm0[slot]),
        "config": dataclasses.asdict(cfg),
        "npz": os.path.basename(npz_path),
        "registry": registry.snapshot() if registry is not None else None,
        "watchdog": ({
            "compiles": watchdog.compiles,
            "violations": watchdog.violations,
            "signatures": list(watchdog.violation_signatures),
        } if watchdog is not None else None),
    }
    if extra:
        doc.update(extra)
    path = os.path.join(directory, stem + ".json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return path


# ---- the lockstep-batch recorder (launch/serve.py) --------------------------


class AdapterFlightRecorder:
    """Flight recorder for the classic lockstep batch driver.

    `launch/serve.py` decodes a fixed batch through one AOT-compiled step
    (no scheduler in the loop), so this helper owns the recorder state and
    a single jitted update that recovers the adapter channels from cache
    deltas (`obs.telemetry.adapter_telemetry`) and folds the detectors in —
    one extra launch per decode step, no host sync.  ``observe(before,
    after)`` per step, then ``dump(directory, ...)`` writes one incident
    bundle per flagged slot.

    `qcfg`: the adapter's quant config (``models.plastic.QUANT``) for int8
    pools, None for float32.

    `mesh`: when the decode step runs under a mesh, the recorder state is
    committed to a replicated NamedSharding up front — otherwise the first
    ``observe`` takes uncommitted arrays and returns mesh-sharded ones,
    and the second call re-lowers the update for the new input shardings
    (one extra executable the recompile watchdog would flag).
    """

    def __init__(self, cfg: HealthConfig, slots: int, qcfg=None,
                 trace_decay: float = 0.8, mesh=None):
        self.cfg = cfg
        self.slots = int(slots)
        self.rec = init_recorder(cfg, self.slots)
        if mesh is not None:
            self.rec = jax.device_put(
                self.rec, jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()))
        self.pos = 0

        def _update(rec, before, after, active, pos):
            tel = adapter_telemetry(before, after, active, qcfg=qcfg,
                                    trace_decay=trace_decay)
            wnorm = adapter_weight_norm(after, quant=qcfg is not None)
            ch = jnp.stack([tel.spike_rate, tel.mean_abs_dw, tel.sat_frac,
                            wnorm], axis=-1)
            return recorder_update(cfg, rec, ch, pos, active)

        self._update = jax.jit(_update)

    def observe(self, before: dict, after: dict, active=None) -> None:
        """Record one decode step from the adapter cache before/after."""
        if active is None:
            active = jnp.ones((self.slots,), jnp.float32)
        self.rec, _ = self._update(self.rec, before, after,
                                   jnp.asarray(active),
                                   jnp.int32(self.pos))
        self.pos += 1

    def flagged_slots(self) -> list:
        """Slots whose latched verdict is unhealthy (host read on demand)."""
        flags = np.asarray(jax.device_get(self.rec.health.flagged))
        return [int(s) for s in np.nonzero(flags.any(axis=-1))[0]]

    def dump(self, directory: str, uid_by_slot=None, registry=None,
             watchdog=None) -> list:
        """One incident bundle per flagged slot; returns the JSON paths.

        Always writes ``flight_summary.json`` (steps recorded, flagged
        slots, detector config) so a clean flight still leaves proof the
        recorder ran — a missing directory is "recording never started",
        an empty incident list is "recorded and healthy".
        """
        uid_by_slot = uid_by_slot or {}
        flagged = self.flagged_slots()
        os.makedirs(directory, exist_ok=True)
        summary = {
            "steps_recorded": self.pos,
            "slots": self.slots,
            "flagged_slots": flagged,
            "channels": list(CHANNELS),
            "detectors": list(DETECTORS),
            "config": dataclasses.asdict(self.cfg),
        }
        with open(os.path.join(directory, "flight_summary.json"), "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
        return [dump_incident(
                    directory,
                    uid=uid_by_slot.get(s, f"slot{s}"), slot=s,
                    rec=self.rec, cfg=self.cfg, pos=self.pos,
                    registry=registry, watchdog=watchdog)
                for s in flagged]
