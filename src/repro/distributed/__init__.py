from repro.distributed import ft, sharding
from repro.distributed.sharding import (fleet_mesh, logical_to_physical,
                                        named_sharding, pool_shardings,
                                        shard_constraint, slot_pspec)
from repro.distributed.ft import (FaultTolerantRunner, StragglerMonitor,
                                  elastic_restore, loss_is_bad)

__all__ = ["ft", "sharding", "fleet_mesh", "logical_to_physical",
           "named_sharding", "pool_shardings", "shard_constraint",
           "slot_pspec", "FaultTolerantRunner", "StragglerMonitor",
           "elastic_restore", "loss_is_bad"]
