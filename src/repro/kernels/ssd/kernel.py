"""Mamba2 SSD Pallas TPU kernel — chunked scan with VMEM-resident state.

Grid: (B*H, n_chunks), chunk innermost.  The (S, P) state matrix lives in
fp32 VMEM scratch and persists across the sequential chunk walk (TPU grids
execute serially on a core), so the recurrent carry never round-trips HBM.
Each chunk does two MXU matmuls (C@B^T duality term, gated @ x) plus the
rank-1-sum state update — arithmetic intensity scales with chunk length,
which is how the SSD insight maps onto the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sf_ref, state_scr,
                *, n_chunks, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)    # (Q,)
    a = a_ref[0, 0].astype(jnp.float32)   # scalar
    bm = b_ref[0].astype(jnp.float32)     # (Q, S)
    cm = c_ref[0].astype(jnp.float32)     # (Q, S)

    lg = a * jnp.cumsum(dt)               # (Q,) cumulative log-decay
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = row >= col

    # ---- intra-chunk (duality matmul) --------------------------------------
    gate = jnp.where(tri, jnp.exp(lg[:, None] - lg[None, :]), 0.0)  # (Q,Q)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)    # (Q,Q)
    g = cb * gate * dt[None, :]
    y_intra = jax.lax.dot_general(g, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # ---- inter-chunk (carry-in state) ---------------------------------------
    state = state_scr[...]                                          # (S, P)
    y_inter = jnp.exp(lg)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # ---- state update -------------------------------------------------------
    w = jnp.exp(lg[-1] - lg) * dt                                   # (Q,)
    upd = jax.lax.dot_general(bm * w[:, None], x, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)   # (S, P)
    state_scr[...] = jnp.exp(lg[-1]) * state + upd

    @pl.when(ci == n_chunks - 1)
    def _final():
        sf_ref[0] = state_scr[...].astype(sf_ref.dtype)


def ssd_pallas(x, dt, a, bmat, c, *, chunk: int = 64, interpret: bool = False):
    """x (B,L,H,P), dt (B,L,H), a (H,), bmat/c (B,L,H,S).

    Returns (y (B,L,H,P), state_final (B,H,S,P)).  L must be chunk-padded by
    the wrapper (ops.py pads with dt=0 steps, which are exact no-ops:
    da=exp(0)=1, update term scales by dt=0).
    """
    bsz, length, h, p = x.shape
    s = bmat.shape[-1]
    assert length % chunk == 0, (length, chunk)
    n_chunks = length // chunk
    bh = bsz * h

    xf = x.transpose(0, 2, 1, 3).reshape(bh, length, p)
    dtf = dt.transpose(0, 2, 1).reshape(bh, length)
    bf = bmat.transpose(0, 2, 1, 3).reshape(bh, length, s)
    cf = c.transpose(0, 2, 1, 3).reshape(bh, length, s)
    af = jnp.tile(a[None, :], (bsz, 1)).reshape(bh, 1)

    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks, chunk=chunk)

    y, sf = pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk), lambda i, ci: (i, ci)),
            pl.BlockSpec((1, 1), lambda i, ci: (i, 0)),
            pl.BlockSpec((1, chunk, s), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, s), lambda i, ci: (i, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, s, p), lambda i, ci: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, length, p), x.dtype),
            jax.ShapeDtypeStruct((bh, s, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((s, p), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf)

    return (y.reshape(bsz, h, length, p).transpose(0, 2, 1, 3),
            sf.reshape(bsz, h, s, p))
