"""Fixed-point engine (quant mode): the FPGA-faithful datapath's contracts.

Pins, in order of load-bearing-ness:

  1. BIT-determinism across backends: the quantized layer step returns
     IDENTICAL int32/int8 outputs on "xla" and "pallas-interpret" (not
     allclose — array_equal), across shapes, padded tiles, teach/readout
     modes, and per-slot scales.  Same style as test_fleet.py parity, but
     exact because every reduction in the quant path is an integer
     reduction.
  2. The quantized fleet step is bit-equal to B independent unbatched
     quantized steps (per-sample semantics), and the active mask freezes
     inactive slots bit-exactly.
  3. Serving: evict -> persist -> re-admit of an int8 session (different
     slot, rival traffic in between) is bit-identical to an uninterrupted
     quantized run — the deterministic stochastic round follows the
     SESSION's step counter, not the pool clock or the slot.
  4. SessionStore.checkout validates restored payloads against the pool
     mode (the satellite bugfix): a float32 session can no longer be
     silently cast into an int8 slot.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, snn
from repro.kernels.plasticity import ops
from repro.kernels.plasticity import quant as Q
from repro.serving import FleetScheduler, SessionStore

IMPLS = ["xla", "pallas-interpret"]
QC = Q.QuantConfig()


def _qparams(qc=QC, **over):
    return engine.EngineParams(tau_m=qc.tau_m, trace_decay=qc.decay,
                               quant=qc, **over)


def _qlayer(key, b, n, m, fleet=False, plastic=True, scale=None):
    """Random fixed-point layer state + binary-spike input."""
    ks = jax.random.split(key, 6)
    wshape = (b, n, m) if fleet else (n, m)
    spikes = (jax.random.uniform(ks[0], (b, n)) > 0.5).astype(jnp.float32)
    state = engine.LayerState(
        w=jax.random.randint(ks[1], wshape, -100, 100, jnp.int8),
        v=jax.random.randint(ks[2], (b, m), -500, 500, jnp.int32),
        trace_pre=jax.random.randint(ks[3], (b, n), 0, 3 * QC.one, jnp.int32),
        trace_post=jax.random.randint(ks[4], (b, m), 0, 3 * QC.one,
                                      jnp.int32),
        theta=(0.05 * jax.random.normal(ks[5], (4, n, m))).astype(jnp.float32)
        if plastic else None,
        w_scale=scale if scale is not None else (
            jnp.full((b,), QC.w_scale, jnp.float32) if fleet
            else jnp.float32(QC.w_scale)))
    return state, Q.to_fixed(spikes, QC)


def _assert_bits(a, b, names=("out", "w", "v", "trace_post")):
    for name, x, y in zip(names, a, b):
        assert x.dtype == y.dtype, name
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)


class TestQuantConfig:
    def test_defaults_are_the_papers_dynamics(self):
        qc = Q.QuantConfig()
        assert qc.tau_m == 2.0                 # multiplier-free tau_m = 2
        assert qc.decay == 0.75                # 1 - 2**-2
        assert qc.one == 256
        assert qc.w_scale == 1.0 / 32.0

    def test_invalid_fields_raise(self):
        with pytest.raises(ValueError, match="frac_bits"):
            Q.QuantConfig(frac_bits=-1)
        with pytest.raises(ValueError, match="trace_shift"):
            Q.QuantConfig(trace_shift=99)

    def test_hashable_jit_static(self):
        assert hash(Q.QuantConfig()) == hash(Q.QuantConfig())

    def test_fixed_point_round_trip_exact_on_grid(self):
        x = jnp.asarray([0.0, 1.0, -1.0, 0.25, -3.5])
        np.testing.assert_array_equal(
            np.asarray(Q.from_fixed(Q.to_fixed(x, QC), QC)), np.asarray(x))

    def test_uniform_hash_deterministic_and_sensitive(self):
        idx = jnp.arange(1024, dtype=jnp.int32)
        u1 = Q.uniform_hash(jnp.int32(7), idx)
        u2 = Q.uniform_hash(jnp.int32(7), idx)
        u3 = Q.uniform_hash(jnp.int32(8), idx)
        np.testing.assert_array_equal(np.asarray(u1), np.asarray(u2))
        assert not np.array_equal(np.asarray(u1), np.asarray(u3))
        assert float(u1.min()) >= 0.0 and float(u1.max()) < 1.0
        # roughly uniform (loose sanity, not a statistical test)
        assert 0.35 < float(u1.mean()) < 0.65


class TestQuantBackendBitParity:
    """xla vs pallas-interpret: IDENTICAL ints, not allclose."""

    def _run(self, state, x, impl, params=None, teach=None, active=None,
             seed=None):
        return engine.layer_step(state, x, params=params or _qparams(),
                                 impl=impl, teach=teach, active=active,
                                 seed=seed)

    @pytest.mark.parametrize("b,n,m", [(1, 8, 8), (4, 10, 30), (3, 17, 257),
                                       (8, 128, 128)])
    def test_shared_weights(self, b, n, m):
        state, x = _qlayer(jax.random.PRNGKey(b + n + m), b, n, m)
        rs, ro = self._run(state, x, "xla", seed=jnp.int32(5))
        ps, po = self._run(state, x, "pallas-interpret", seed=jnp.int32(5))
        _assert_bits((ro, rs.w, rs.v, rs.trace_post),
                     (po, ps.w, ps.v, ps.trace_post))
        assert rs.w.dtype == jnp.int8 and ro.dtype == jnp.int32

    # the tile-padding edge: m deliberately NOT a multiple of block_m
    @pytest.mark.parametrize("m,block_m", [(48, 32), (130, 128), (40, 16),
                                           (257, 64)])
    @pytest.mark.parametrize("fleet", [False, True])
    def test_padded_postsynaptic_tiles(self, m, block_m, fleet):
        state, x = _qlayer(jax.random.PRNGKey(m + block_m), 3, 24, m,
                           fleet=fleet)
        params = _qparams(block_m=block_m)
        seed = jnp.arange(3, dtype=jnp.int32) if fleet else jnp.int32(3)
        rs, ro = self._run(state, x, "xla", params=params, seed=seed)
        ps, po = self._run(state, x, "pallas-interpret", params=params,
                           seed=seed)
        _assert_bits((ro, rs.w, rs.v, rs.trace_post),
                     (po, ps.w, ps.v, ps.trace_post))

    @pytest.mark.parametrize("spiking", [True, False])
    def test_fleet_teach_and_readout(self, spiking):
        b, n, m = 3, 12, 20
        state, x = _qlayer(jax.random.PRNGKey(7), b, n, m, fleet=True)
        teach = Q.to_fixed(2.0 * jax.random.normal(jax.random.PRNGKey(8),
                                                   (b, m)), QC)
        params = _qparams(spiking=spiking)
        seeds = jnp.array([1, 2, 3], jnp.int32)
        rs, ro = self._run(state, x, "xla", params=params, teach=teach,
                           seed=seeds)
        ps, po = self._run(state, x, "pallas-interpret", params=params,
                           teach=teach, seed=seeds)
        _assert_bits((ro, rs.w, rs.v, rs.trace_post),
                     (po, ps.w, ps.v, ps.trace_post))

    def test_heterogeneous_per_slot_scales(self):
        """Each slot's int8 payload is interpreted through ITS scale."""
        b, n, m = 3, 10, 16
        scale = jnp.array([1 / 32, 1 / 16, 1 / 64], jnp.float32)
        state, x = _qlayer(jax.random.PRNGKey(9), b, n, m, fleet=True,
                           scale=scale)
        rs, ro = self._run(state, x, "xla")
        ps, po = self._run(state, x, "pallas-interpret")
        _assert_bits((ro, rs.w, rs.v, rs.trace_post),
                     (po, ps.w, ps.v, ps.trace_post))
        # a coarser scale means the same fixed psum maps to larger currents:
        # slot dynamics must actually DIFFER across scales for equal payloads
        state_eq = dataclasses.replace(
            state, w=jnp.broadcast_to(state.w[0], state.w.shape))
        _, o_eq = self._run(state_eq, jnp.broadcast_to(x[:1], x.shape), "xla")
        assert not np.array_equal(np.asarray(o_eq[0]), np.asarray(o_eq[1]))

    def test_plastic_off_passes_weights_through(self):
        state, x = _qlayer(jax.random.PRNGKey(13), 3, 16, 16, fleet=True,
                           plastic=False)
        params = _qparams(plastic=False)
        for impl in IMPLS:
            ns, _ = self._run(state, x, impl, params=params)
            np.testing.assert_array_equal(np.asarray(ns.w),
                                          np.asarray(state.w))


class TestQuantFleetSemantics:
    def test_fleet_equals_independent_unbatched_steps(self):
        """Per-sample semantics: fleet == B separate quantized steps."""
        b, n, m = 4, 10, 14
        state, x = _qlayer(jax.random.PRNGKey(2), b, n, m, fleet=True)
        seeds = jnp.array([3, 1, 4, 1], jnp.int32)
        fs, fo = engine.layer_step(state, x, params=_qparams(), impl="xla",
                                   seed=seeds)
        for i in range(b):
            ev, v, tp, w = ops.dual_engine_step(
                x[i], state.w[i], state.theta, state.v[i],
                state.trace_pre[i], state.trace_post[i],
                w_scale=state.w_scale[i], seed=seeds[i], quant=QC,
                v_th=1.0, v_reset=0.0, w_clip=4.0, impl="xla")
            np.testing.assert_array_equal(np.asarray(fo[i]), np.asarray(ev))
            np.testing.assert_array_equal(np.asarray(fs.w[i]), np.asarray(w))
            np.testing.assert_array_equal(np.asarray(fs.v[i]), np.asarray(v))

    @pytest.mark.parametrize("impl", IMPLS)
    def test_active_mask_freezes_bitwise(self, impl):
        state, x = _qlayer(jax.random.PRNGKey(5), 4, 10, 30, fleet=True)
        act = jnp.array([True, False, True, False])
        seeds = jnp.arange(4, dtype=jnp.int32)
        ns, out = engine.layer_step(state, x, params=_qparams(), impl=impl,
                                    active=act, seed=seeds)
        ns0, out0 = engine.layer_step(state, x, params=_qparams(), impl=impl,
                                      seed=seeds)
        for i in range(4):
            if bool(act[i]):
                np.testing.assert_array_equal(np.asarray(ns.w[i]),
                                              np.asarray(ns0.w[i]))
                np.testing.assert_array_equal(np.asarray(out[i]),
                                              np.asarray(out0[i]))
            else:
                for fld in ("w", "v", "trace_post"):
                    np.testing.assert_array_equal(
                        np.asarray(getattr(ns, fld)[i]),
                        np.asarray(getattr(state, fld)[i]), err_msg=fld)
                assert (np.asarray(out[i]) == 0).all()

    def test_stochastic_round_is_seeded(self):
        """Same seed -> identical weights; different seed -> different."""
        state, x = _qlayer(jax.random.PRNGKey(11), 2, 16, 16)
        s1, _ = engine.layer_step(state, x, params=_qparams(), impl="xla",
                                  seed=jnp.int32(10))
        s2, _ = engine.layer_step(state, x, params=_qparams(), impl="xla",
                                  seed=jnp.int32(10))
        s3, _ = engine.layer_step(state, x, params=_qparams(), impl="xla",
                                  seed=jnp.int32(11))
        np.testing.assert_array_equal(np.asarray(s1.w), np.asarray(s2.w))
        assert not np.array_equal(np.asarray(s1.w), np.asarray(s3.w))

    def test_weights_stay_on_the_clipped_int8_grid(self):
        """A huge constant-term theta saturates w_q at min(floor(clip/s),127)."""
        state, x = _qlayer(jax.random.PRNGKey(12), 2, 8, 8)
        hot = dataclasses.replace(
            state, theta=state.theta.at[3].set(100.0))   # DELTA plane
        params = _qparams()
        ns, _ = engine.layer_step(hot, x, params=params, impl="xla")
        assert ns.w.dtype == jnp.int8
        assert int(np.asarray(ns.w).max()) == 127        # floor(4*32)=128->127


class TestQuantEngineGuards:
    def test_mismatched_trace_decay_raises(self):
        state, x = _qlayer(jax.random.PRNGKey(0), 2, 8, 8)
        bad = engine.EngineParams(quant=QC)              # float decay 0.8
        with pytest.raises(ValueError, match="trace_decay"):
            engine.layer_step(state, x, params=bad, impl="xla")

    def test_mismatched_tau_raises(self):
        state, x = _qlayer(jax.random.PRNGKey(0), 2, 8, 8)
        bad = engine.EngineParams(tau_m=3.0, trace_decay=QC.decay, quant=QC)
        with pytest.raises(ValueError, match="tau_m"):
            engine.layer_step(state, x, params=bad, impl="xla")

    def test_float_teach_rejected_loudly(self):
        """A float teach would be truncated to zeros by the int cast —
        demand the fixed-point event bus format instead."""
        state, x = _qlayer(jax.random.PRNGKey(2), 2, 8, 8)
        with pytest.raises(ValueError, match="quant mode needs teach"):
            engine.layer_step(state, x, params=_qparams(), impl="xla",
                              teach=0.5 * jnp.ones((2, 8)))

    def test_float_state_rejected_loudly(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        state = engine.LayerState(
            w=0.1 * jax.random.normal(ks[0], (8, 8)),
            v=jnp.zeros((2, 8)), trace_pre=jnp.zeros((2, 8)),
            trace_post=jnp.zeros((2, 8)),
            theta=0.01 * jax.random.normal(ks[1], (4, 8, 8)))
        with pytest.raises(ValueError, match="quant mode needs w"):
            engine.layer_step(state, jnp.zeros((2, 8), jnp.int32),
                              params=_qparams(), impl="xla")


class TestQuantSNN:
    def _cfg(self, impl="xla"):
        return snn.quant_config(snn.SNNConfig(layer_sizes=(6, 16, 4),
                                              timesteps=3, impl=impl))

    def test_init_state_representation(self):
        cfg = self._cfg()
        st = snn.init_state(cfg)
        assert st.w[0].dtype == jnp.int8
        assert st.v[0].dtype == jnp.int32 and st.trace[0].dtype == jnp.int32
        assert len(st.w_scale) == cfg.num_layers
        assert float(st.w_scale[0]) == QC.w_scale
        fl = snn.init_state(cfg, batch=5, fleet=True)
        assert fl.w[0].shape == (5, 6, 16) and fl.w[0].dtype == jnp.int8
        assert fl.w_scale[0].shape == (5,)

    def test_controller_bitwise_across_backends(self):
        theta = snn.init_theta(self._cfg(), jax.random.PRNGKey(0), scale=0.5)
        obs = jnp.linspace(-1, 1, 6)
        results = {}
        for impl in IMPLS:
            cfg = self._cfg(impl)
            st = snn.init_state(cfg)
            for _ in range(3):
                st, act = snn.controller_step(cfg, st, theta, obs)
            results[impl] = (act, st.w)
        a, b = results["xla"], results["pallas-interpret"]
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        for x, y in zip(a[1], b[1]):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_quantize_state_migrates_float_sessions(self):
        cfg = self._cfg()
        fcfg = dataclasses.replace(cfg, quant=None)
        fst = snn.init_state(fcfg)
        fst = dataclasses.replace(
            fst, w=tuple(0.5 * jax.random.normal(jax.random.PRNGKey(i),
                                                 w.shape)
                         for i, w in enumerate(fst.w)))
        qst = snn.quantize_state(cfg, fst)
        assert qst.w[0].dtype == jnp.int8
        for wq, s, wf in zip(qst.w, qst.w_scale, fst.w):
            err = np.abs(np.asarray(wq, np.float32) * float(s)
                         - np.asarray(wf))
            assert err.max() <= float(s) * 0.5 + 1e-6   # one rounding
        # and the result actually steps
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.3)
        st, out = snn.timestep(cfg, qst, theta, jnp.ones((6,)))
        assert out.dtype == jnp.float32

    def test_quantize_state_requires_quant_cfg(self):
        fcfg = snn.SNNConfig(layer_sizes=(6, 16, 4))
        with pytest.raises(ValueError, match="cfg.quant"):
            snn.quantize_state(fcfg, snn.init_state(fcfg))

    def test_float_vs_quant_actions_close_early(self):
        """The quant datapath tracks the float reference on matched
        (power-of-two) dynamics over an early window.  Spiking plasticity
        is chaotic — threshold flips amplify — so long-horizon trajectories
        legitimately diverge; the per-step/task-level error is measured and
        documented by benchmarks/quant_parity.py, not bounded here."""
        cfg = self._cfg()
        fcfg = dataclasses.replace(cfg, quant=None)
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.3)
        qst, fst = snn.init_state(cfg), snn.init_state(fcfg)
        obs = 0.5 * jnp.sin(jnp.arange(6, dtype=jnp.float32))
        errs = []
        for _ in range(3):
            qst, qa = snn.controller_step(cfg, qst, theta, obs)
            fst, fa = snn.controller_step(fcfg, fst, theta, obs)
            errs.append(float(jnp.abs(qa - fa).max()))
        assert max(errs) < 0.5, errs


class TestQuantServing:
    def _cfg(self, impl="xla"):
        return snn.quant_config(snn.SNNConfig(layer_sizes=(6, 12, 4),
                                              timesteps=2, impl=impl))

    def _drive(self, uid, t, n=6):
        phase = (hash(uid) % 97) / 97.0
        return np.sin(0.3 * t + phase + np.arange(n)).astype(np.float32)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_evict_restore_different_slot_bit_identical(self, impl,
                                                        tmp_path):
        """THE acceptance pin, quantized: interrupted == uninterrupted."""
        cfg = self._cfg(impl)
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
        steps = 8 if impl == "xla" else 6
        cut = steps // 2

        def trajectory(interrupt):
            sub = "int" if interrupt else "unint"
            sched = FleetScheduler(
                cfg, theta, slots=2,
                store=SessionStore(root=str(tmp_path / f"{impl}-{sub}")))
            assert sched.admit("probe") == 0
            outs = []
            for t in range(steps):
                if interrupt and t == cut:
                    sched.evict("probe")           # int8 payload -> disk
                    sched.store._warm.clear()      # force the disk path
                    sched.admit("rival")           # rival takes slot 0 and
                    sched.step({"rival": self._drive("rival", 99)})  # ticks
                    assert sched.admit("probe") == 1   # DIFFERENT slot
                outs.append(np.asarray(sched.step(
                    {u: self._drive(u, t) for u in sched.active_users}
                )["probe"]))
            sched.evict("probe")
            final, step = sched.store.checkout(
                "probe", lambda: snn.init_state(cfg))
            return outs, final, step

        o1, f1, s1 = trajectory(False)
        o2, f2, s2 = trajectory(True)
        assert s1 == s2 == steps
        for a, b in zip(o1, o2):
            np.testing.assert_array_equal(a, b)
        for a, b in zip(jax.tree.leaves(f1), jax.tree.leaves(f2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_quant_pool_is_int8_and_smaller(self):
        cfg = self._cfg()
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
        q = FleetScheduler(cfg, theta, slots=8, store=SessionStore())
        f = FleetScheduler(dataclasses.replace(cfg, quant=None,
                                               trace_decay=0.8),
                           theta, slots=8, store=SessionStore())
        assert q.fleet.w[0].dtype == jnp.int8
        assert q.pool_nbytes() < f.pool_nbytes() / 2   # weights dominate

    def test_churn_never_recompiles_after_warmup(self):
        cfg = self._cfg()
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
        s = FleetScheduler(cfg, theta, slots=3, store=SessionStore())
        s.admit("w"); s.step({"w": self._drive("w", 0)})
        s.evict("w"); s.admit("w"); s.step({"w": self._drive("w", 1)})
        s.evict("w")
        c0 = s.compile_count()
        for t in range(12):
            uid = f"u{t % 4}"
            if uid in s.user_slot:
                s.evict(uid)
            else:
                s.admit(uid, evict_lru=True)
            s.step({u: self._drive(u, t) for u in s.active_users})
        assert s.compile_count() == c0

    def test_checkout_rejects_mode_mismatch_ram(self):
        """Satellite bugfix: float payload can't enter an int8 pool."""
        qcfg = self._cfg()
        fcfg = dataclasses.replace(qcfg, quant=None, trace_decay=0.8)
        store = SessionStore(root=None)
        store.checkin("u", snn.init_state(fcfg), 3)
        store._warm.clear()                       # force the archive path
        with pytest.raises(ValueError, match="quantize_state"):
            store.checkout("u", lambda: snn.init_state(qcfg))

    def test_checkout_rejects_mode_mismatch_warm_and_disk(self, tmp_path):
        qcfg = self._cfg()
        fcfg = dataclasses.replace(qcfg, quant=None, trace_decay=0.8)
        store = SessionStore(root=str(tmp_path))
        store.checkin("u", snn.init_state(fcfg), 3)
        with pytest.raises(ValueError):           # warm-cache path
            store.checkout("u", lambda: snn.init_state(qcfg))
        store = SessionStore(root=str(tmp_path))  # fresh store: disk path
        with pytest.raises(ValueError):
            store.checkout("u", lambda: snn.init_state(qcfg))

    def test_checkout_matching_mode_still_works(self, tmp_path):
        qcfg = self._cfg()
        store = SessionStore(root=str(tmp_path))
        st = snn.init_state(qcfg)
        store.checkin("u", st, 5)
        store._warm.clear()
        out, step = store.checkout("u", lambda: snn.init_state(qcfg))
        assert step == 5
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_quantized_session_admits_via_quantize_state(self):
        """The sanctioned float -> int8 migration path works end to end."""
        qcfg = self._cfg()
        fcfg = dataclasses.replace(qcfg, quant=None, trace_decay=0.8)
        store = SessionStore(root=None)
        fstate = snn.init_state(fcfg)
        store.checkin("u", snn.quantize_state(qcfg, fstate), 0)
        store._warm.clear()
        theta = snn.init_theta(qcfg, jax.random.PRNGKey(0))
        sched = FleetScheduler(qcfg, theta, slots=2, store=store)
        sched.admit("u")
        out = sched.step({"u": self._drive("u", 0)})
        assert np.isfinite(np.asarray(out["u"])).all()
