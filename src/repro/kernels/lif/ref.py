"""Pure-jnp oracle for the Forward Engine (matmul + LIF + trace), no plasticity."""
from __future__ import annotations

import jax.numpy as jnp


def lif_forward(x, w, v, trace, *, tau_m: float = 2.0, v_th: float = 1.0,
                v_reset: float = 0.0, trace_decay: float = 0.8):
    """x (B,K), w (K,M), v (B,M), trace (B,M) ->
    (spikes (B,M), v_out (B,M), trace_new (B,M))."""
    compute = jnp.float32
    current = jnp.dot(x.astype(compute), w.astype(compute))
    v_new = v.astype(compute) + (current - v.astype(compute)) / tau_m
    spikes = (v_new >= v_th).astype(compute)
    v_out = jnp.where(spikes > 0, v_reset, v_new)
    trace_new = trace_decay * trace.astype(compute) + spikes
    return (spikes.astype(x.dtype), v_out.astype(v.dtype),
            trace_new.astype(trace.dtype))
