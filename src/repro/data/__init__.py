"""Deterministic, shard-aware data substrate (no external datasets offline).

  tokens   — procedural LM token pipeline: seeded, restartable (step-indexed),
             per-host sharded; a Zipf-ish unigram mixture with short-range
             structure so cross-entropy has learnable signal
  mnist    — procedural 28x28 digit renderer + Poisson-rate spike encoding
             (Table II stand-in; accuracy not comparable, protocol is)
"""
from repro.data.tokens import TokenPipelineConfig, batch_at_step, host_batch
from repro.data.mnist import (mnist_batch, render_digit, spike_encode)

__all__ = ["TokenPipelineConfig", "batch_at_step", "host_batch",
           "mnist_batch", "render_digit", "spike_encode"]
