"""Post-optimization HLO analyzer: FLOPs / bytes / collective traffic with
while-loop trip-count attribution.

Why not ``compiled.cost_analysis()`` alone?  XLA's HloCostAnalysis visits a
``while`` body ONCE — for scan-over-layers models (all of ours) that
undercounts FLOPs and collective bytes by a factor of n_layers.  The
optimized HLO text carries ``backend_config={"known_trip_count":{"n":"80"}}``
on every counted loop, so we parse the module into a computation call graph
and multiply every nested computation's totals by the trip counts on the
path from ENTRY.

Per-device semantics: the analyzed module is the post-SPMD-partition
program, so every number here is PER DEVICE (chip) — exactly what the
roofline terms want (all chips run the same program concurrently).

Bytes-accessed model (mirrors HloCostAnalysis):
  * instruction bytes = result bytes + operand read bytes
  * dynamic-slice / gather read only the slice, not the source buffer
  * dynamic-update-slice reads+writes only the update window
  * fusion operands consumed exclusively by slicing ops inside the fused
    computation are charged at slice size (this is what keeps a 2 GiB KV
    cache from being "read" once per decode layer)

Collective wire-bytes model (ring algorithms, G = group size, R = result
bytes):
    all-gather          R * (G-1)/G         (bytes received per device)
    all-reduce          2 * R * (G-1)/G     (reduce-scatter + all-gather)
    reduce-scatter      R * (G-1)           (operand = R*G)
    all-to-all          R * (G-1)/G
    collective-permute  R

FLOPs are counted from ``dot`` instructions (2 * prod(result) * K); the VPU
elementwise tail inside fusions is not counted — the MXU term dominates
every cell we report, and the bytes term covers elementwise traffic.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; tuple types are summed."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# %name = TYPE op(...).  TYPE may be a tuple containing /*index=N*/ comments,
# so match lazily up to the first ``word(`` (types never precede '(').
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{"n"\s*:\s*"(\d+)"\}')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")

_FREE_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "while", "conditional", "call", "custom-call", "copy-start", "copy-done",
    # dtype converts are XLA:CPU float-normalization artifacts: the CPU
    # backend legalizes bf16 dots by materializing f32 copies; on the TPU
    # target bf16 is native and the convert fuses into its consumer.
    "convert",
}
_SLICING_OPS = {"dynamic-slice", "gather", "slice"}


def _through_convert(comp: "Comp", name: str) -> Optional["Instr"]:
    """Resolve an operand through convert instructions / wrapped-convert
    fusions so reads are charged at the source (storage) dtype."""
    inst = comp.by_name.get(name)
    for _ in range(4):
        if inst is None:
            return None
        if inst.op == "convert" and inst.operands:
            inst = comp.by_name.get(inst.operands[0])
            continue
        if (inst.op == "fusion" and inst.name.startswith("wrapped_convert")
                and inst.operands):
            inst = comp.by_name.get(inst.operands[0])
            continue
        return inst
    return inst


@dataclasses.dataclass
class Instr:
    name: str
    type: str
    op: str
    operands: List[str]
    line: str
    is_root: bool = False


@dataclasses.dataclass
class Comp:
    name: str
    instrs: List[Instr] = dataclasses.field(default_factory=list)
    by_name: Dict[str, Instr] = dataclasses.field(default_factory=dict)


def _split_computations(text: str) -> tuple[Dict[str, Comp], Optional[str]]:
    comps: Dict[str, Comp] = {}
    entry: Optional[str] = None
    cur: Optional[Comp] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line)
        if hdr and "->" in line and line.endswith("{"):
            cur = Comp(hdr.group(2))
            comps[cur.name] = cur
            if hdr.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        iname, itype, op, rest = m.groups()
        # operand names: everything inside the first (...) of the call
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERANDS_RE.findall(rest[:end])
        inst = Instr(iname, itype.strip(), op, operands, line,
                     is_root="ROOT " in line)
        cur.instrs.append(inst)
        cur.by_name[iname] = inst
    return comps, entry


def _result_write_bytes(inst: Instr, comps: Dict[str, Comp]) -> float:
    """Result bytes, window-sized for in-place dynamic-update-slice roots."""
    if inst.op == "dynamic-update-slice":
        return 0.0  # write charged by _operand_read_bytes (update window x2)
    if inst.op == "fusion":
        fm = _CALLS_RE.search(inst.line)
        fused = comps.get(fm.group(1)) if fm else None
        if fused is not None:
            roots = [i for i in fused.instrs if i.is_root]
            r = roots[0] if roots else None
            # look through transparent ops (convert/copy/bitcast chains)
            for _ in range(4):
                if r is not None and r.op in _TRANSPARENT_OPS and r.operands:
                    r = fused.by_name.get(r.operands[0])
                else:
                    break
            if r is not None and r.op == "dynamic-update-slice":
                upd = (fused.by_name.get(r.operands[1])
                       if len(r.operands) > 1 else None)
                return float(_shape_bytes(upd.type) if upd
                             else _shape_bytes(r.type))
    return float(_shape_bytes(inst.type))


def _group_size(line: str) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))   # [num_groups, group_size]
    return 1


def _collective_wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return float(result_bytes) * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    if kind == "collective-permute":
        return float(result_bytes)
    return 0.0


def _operand_read_bytes(comp: Comp, inst: Instr, comps: Dict[str, Comp]) -> float:
    """Bytes read from inst's operands, with slice-aware accounting."""
    if inst.op in ("dynamic-slice", "slice", "gather"):
        # reads ~result-sized window (+ tiny indices)
        return _shape_bytes(inst.type)
    if inst.op == "dynamic-update-slice":
        # reads the update window and writes it back; the aliased source
        # buffer is not otherwise traversed
        upd = comp.by_name.get(inst.operands[1]) if len(inst.operands) > 1 else None
        return 2.0 * (_shape_bytes(upd.type) if upd else _shape_bytes(inst.type))

    if inst.op == "fusion":
        fm = _CALLS_RE.search(inst.line)
        fused = comps.get(fm.group(1)) if fm else None
        total = 0.0
        for pos, oname in enumerate(inst.operands):
            o = _through_convert(comp, oname)
            if o is None:
                continue
            full = _shape_bytes(o.type)
            if fused is not None:
                total += _fused_param_read(fused, pos, full)
            else:
                total += full
        return total

    total = 0.0
    for oname in inst.operands:
        o = _through_convert(comp, oname)
        if o is not None:
            total += _shape_bytes(o.type)
    return total


_TRANSPARENT_OPS = {"convert", "copy", "bitcast", "bitcast-convert"}


def _fused_param_read(fused: Comp, param_idx: int, full_bytes: int) -> float:
    """Bytes a fusion reads from parameter `param_idx`: slice/window-sized
    when every (transitively, through transparent ops) internal consumer is
    a slicing op or the in-place buffer of a dynamic-update-slice; else the
    full operand."""
    pname = None
    for inst in fused.instrs:
        if inst.op == "parameter":
            m = _PARAM_IDX_RE.search(inst.line)
            if m and int(m.group(1)) == param_idx:
                pname = inst.name
                break
    if pname is None:
        return full_bytes
    frontier = [pname]
    read = 0.0
    seen = set()
    for _ in range(6):
        next_frontier = []
        for name in frontier:
            for c in fused.instrs:
                if name not in c.operands or c.name in seen:
                    continue
                seen.add(c.name)
                if c.op in _TRANSPARENT_OPS:
                    next_frontier.append(c.name)
                elif c.op in _SLICING_OPS:
                    read += _shape_bytes(c.type)
                elif c.op == "dynamic-update-slice":
                    # reading as the in-place buffer (operand 0) is free;
                    # as the update (operand 1+) costs window bytes
                    if c.operands and c.operands[0] != name:
                        upd = fused.by_name.get(c.operands[1]) \
                            if len(c.operands) > 1 else None
                        read += _shape_bytes(upd.type) if upd else full_bytes
                else:
                    return float(full_bytes)
        if not next_frontier:
            break
        frontier = next_frontier
    return min(read, float(full_bytes))


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    collective_wire: float = 0.0
    per_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    children: List[tuple] = dataclasses.field(default_factory=list)


def _comp_stats(comp: Comp, comps: Dict[str, Comp]) -> CompStats:
    st = CompStats()
    for inst in comp.instrs:
        op = inst.op
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            rb = _shape_bytes(inst.type)
            if op.endswith("-start") and inst.type.startswith("("):
                rb //= 2     # async tuple carries (operand, result)
            g = _group_size(inst.line)
            wire = _collective_wire_bytes(base, rb, g)
            st.collective_wire += wire
            st.per_kind[base] = st.per_kind.get(base, 0.0) + wire
            st.bytes += rb
            continue
        if op == "while":
            trip = 1
            tm = _TRIP_RE.search(inst.line)
            if tm:
                trip = int(tm.group(1))
            bm = _BODY_RE.search(inst.line)
            if bm:
                st.children.append((bm.group(1), trip))
            cm = _COND_RE.search(inst.line)
            if cm:
                st.children.append((cm.group(1), trip + 1))
            continue
        if op == "conditional":
            bm = _BRANCH_RE.search(inst.line)
            if bm:
                for branch in _OPERANDS_RE.findall(bm.group(1)):
                    st.children.append((branch, 1))
            continue
        if op in ("call", "custom-call"):
            cm = _CALLS_RE.search(inst.line)
            if cm:
                st.children.append((cm.group(1), 1))

        if op == "dot":
            out_dims = _shape_dims(inst.type) or []
            out_n = 1
            for d in out_dims:
                out_n *= d
            k = 1
            cm = _CONTRACT_RE.search(inst.line)
            if cm and inst.operands:
                lhs = comp.by_name.get(inst.operands[0])
                lhs_dims = _shape_dims(lhs.type) if lhs else None
                if lhs_dims is not None:
                    for ci in cm.group(1).split(","):
                        if ci:
                            idx = int(ci)
                            if idx < len(lhs_dims):
                                k *= lhs_dims[idx]
            st.flops += 2.0 * out_n * k

        if op not in _FREE_OPS:
            st.bytes += _result_write_bytes(inst, comps)
            st.bytes += _operand_read_bytes(comp, inst, comps)
    return st


def parse_hlo(text: str):
    comps, entry = _split_computations(text)
    stats = {name: _comp_stats(c, comps) for name, c in comps.items()}
    # fusions' internal computations are charged at the call site; do not
    # also walk them as standalone children
    return stats, entry


def analyze(text: str) -> dict:
    """Whole-module totals with trip-count multiplication from ENTRY."""
    stats, entry = parse_hlo(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    memo: Dict[str, tuple] = {}

    def total(name: str, depth=0) -> tuple:
        if name in memo:
            return memo[name]
        if name not in stats or depth > 64:
            return (0.0, 0.0, 0.0, {})
        st = stats[name]
        f, b, c = st.flops, st.bytes, st.collective_wire
        per_kind = dict(st.per_kind)
        for child, mult in st.children:
            cf, cb, cc, ck = total(child, depth + 1)
            f += mult * cf
            b += mult * cb
            c += mult * cc
            for kind, v in ck.items():
                per_kind[kind] = per_kind.get(kind, 0.0) + mult * v
        memo[name] = (f, b, c, per_kind)
        return memo[name]

    f, b, c, per_kind = total(entry)
    return {
        "flops_per_device": f,
        "bytes_per_device": b,
        "collective_wire_bytes_per_device": c,
        "collective_by_kind": per_kind,
    }


def roofline_terms(analysis: dict, hw: dict) -> dict:
    """Seconds per step for the three roofline terms (per-device == global
    wall-clock for an SPMD program)."""
    compute = analysis["flops_per_device"] / hw["peak_flops_bf16"]
    memory = analysis["bytes_per_device"] / hw["hbm_bw"]
    collective = analysis["collective_wire_bytes_per_device"] / hw["ici_bw"]
    dominant = max((compute, "compute"), (memory, "memory"),
                   (collective, "collective"))[1]
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dominant,
            "step_s_max": max(compute, memory, collective),
            "step_s_sum": compute + memory + collective}


if __name__ == "__main__":
    import sys
    with open(sys.argv[1]) as fh:
        print(json.dumps(analyze(fh.read()), indent=2))
