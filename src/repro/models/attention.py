"""GQA attention block: plan + apply (train/prefill) + cached decode.

Features per assigned-arch needs: grouped KV heads, optional QKV bias
(qwen1.5/qwen2), optional per-head q/k RMSNorm (qwen3), RoPE.

Sharding: heads shard over "model"; the output projection contracts over
the sharded head axis (XLA inserts the reduce-scatter/all-reduce); KV cache
shards batch over "data" and kv-heads over "model" (for batch=1 long-context
cells the cache seq axis takes "seq" instead — see plan_kv_cache).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_constraint
from repro.kernels.attention import attention as attn_op
from repro.models.config import ModelConfig
from repro.models.layers import ParamDesc, rms_norm, rope


def plan(cfg: ModelConfig, stack: int = 0) -> dict:
    """Parameter plan for one attention block (stacked `stack` deep if >0)."""
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.dtype

    def st(shape, spec):
        if stack:
            return (stack, *shape), (None, *spec)
        return shape, spec

    def desc(shape, spec, **kw):
        shape, spec = st(shape, spec)
        return ParamDesc(shape, spec, dtype=dt, **kw)

    p = {
        "wq": desc((d, h * hd), ("data", "model"), fan_in=d),
        "wk": desc((d, kv * hd), ("data", "model"), fan_in=d),
        "wv": desc((d, kv * hd), ("data", "model"), fan_in=d),
        "wo": desc((h * hd, d), ("model", "data"), fan_in=h * hd),
        "norm": desc((d,), (None,), init="ones"),
    }
    if cfg.qkv_bias:
        p["bq"] = desc((h * hd,), ("model",), init="zeros")
        p["bk"] = desc((kv * hd,), ("model",), init="zeros")
        p["bv"] = desc((kv * hd,), ("model",), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = desc((hd,), (None,), init="ones")
        p["k_norm"] = desc((hd,), (None,), init="ones")
    return p


def _qkv(params, x, cfg: ModelConfig, positions):
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dk->bsk", x, params["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, params["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q, k = rope(q, k, positions, cfg.rope_theta)
    return q, k, v


def apply(params, x, cfg: ModelConfig, positions=None,
          impl: str = "xla_flash"):
    """Full-sequence attention (train / prefill).  x (B,S,D) -> (B,S,D)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    q, k, v = _qkv(params, h, cfg, positions)
    q = shard_constraint(q, ("data", None, "model", None))
    k = shard_constraint(k, ("data", None, "model", None))
    v = shard_constraint(v, ("data", None, "model", None))
    o = attn_op(q, k, v, causal=True, impl=impl)
    o = jnp.einsum("bsk,kd->bsd", o.reshape(b, s, -1), params["wo"])
    # collected cache shards sequence over "model" so the stacked prefill
    # buffer (L,B,S,KV,HD) never materializes unsharded per device
    k = shard_constraint(k, ("data", "model", None, None))
    v = shard_constraint(v, ("data", "model", None, None))
    return x + shard_constraint(o, cfg.act_spec), (k, v)


def quantize_kv(x):
    """Symmetric int8 over the head_dim axis.  x (..., HD) ->
    (q int8 (..., HD), scale f32 (...,))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def plan_kv_scale(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: int) -> ParamDesc:
    """Scale plane for the int8 KV cache (same sharding as the cache)."""
    spec_b = None if batch == 1 else "data"
    spec_s = ("data", "model") if batch == 1 else "model"
    return ParamDesc((n_layers, batch, max_len, cfg.n_kv_heads),
                     (None, spec_b, spec_s, None),
                     init="zeros", dtype="float32")


def plan_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: int, seq_shard: bool = False) -> ParamDesc:
    """KV cache descriptor for one attention stack (k and v identical).

    The cache SEQUENCE axis shards over "model" (context-parallel decode):
    kv-head counts (8, 24, 32, 40...) rarely divide a 16-way model axis, but
    32k/524k sequences always do, and the decode attention's softmax
    reductions partition cleanly over the sequence.  batch=1 long-context
    cells spread sequence over data+model (all 256/512 chips)."""
    spec_b = None if batch == 1 else "data"
    spec_s = ("data", "model") if batch == 1 else "model"
    return ParamDesc(
        (n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd),
        (None, spec_b, spec_s, None, None),
        init="zeros", dtype=cfg.dtype)


def _write_at(cache, row, index, active=None):
    """Write one new position per stream into the (B,Smax,...) cache.

    Scalar `index` (all streams at the same length — the classic batched
    decode) lowers to one dynamic_update_slice; a per-slot ``(B,)`` index
    (the continuous-batching pool, where streams admitted at different
    times sit at different lengths) scatters each stream's row at its own
    position.  ``active (B,)`` makes vacant streams' writes no-ops: their
    cache rows stay bit-frozen instead of being scribbled with garbage
    (the pool's true-no-op contract — one row-sized gather+select, nothing
    cache-sized)."""
    b = cache.shape[0]
    if index.ndim == 0 and active is None:
        start = (0, index) + (0,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(
            cache, row.astype(cache.dtype), start)
    idx = (jnp.broadcast_to(index, (b,)) if index.ndim == 0 else index)
    new = row[:, 0].astype(cache.dtype)
    if active is not None:
        old = cache[jnp.arange(b), idx]
        mask = active.astype(bool).reshape((b,) + (1,) * (old.ndim - 1))
        new = jnp.where(mask, new, old)
    return cache.at[jnp.arange(b), idx].set(new)


def decode_step(params, x, cache_k, cache_v, index, cfg: ModelConfig,
                scale_k=None, scale_v=None, active=None):
    """One-token cached attention.  x (B,1,D); cache (B,Smax,KV,HD); index
    is the current length — scalar () when every stream decodes in lockstep,
    or per-slot ``(B,)`` under the continuous-batching pool (each stream
    writes/attends at its own position; ``active (B,)`` freezes vacant
    streams' cache rows bit-exactly).  Returns (out (B,1,D), new_k,
    new_v) — plus (new_scale_k, new_scale_v) appended when cfg.kv_quant."""
    b = x.shape[0]
    index = jnp.asarray(index, jnp.int32)
    if index.ndim == 0:
        positions = jnp.full((b, 1), index, jnp.int32)
    else:
        positions = index[:, None]
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    q, k, v = _qkv(params, h, cfg, positions)
    if cfg.kv_quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        cache_k = _write_at(cache_k, kq, index, active)
        cache_v = _write_at(cache_v, vq, index, active)
        scale_k = _write_at(scale_k, ks, index, active)
        scale_v = _write_at(scale_v, vs, index, active)
        # dequant fuses into the attention matmul on TPU; the resident cache
        # (and its HBM reads) are int8 + one f32 scale per (pos, kv-head)
        k_use = dequantize_kv(cache_k, scale_k, cfg.adtype)
        v_use = dequantize_kv(cache_v, scale_v, cfg.adtype)
    else:
        cache_k = _write_at(cache_k, k, index, active)
        cache_v = _write_at(cache_v, v, index, active)
        k_use, v_use = cache_k, cache_v
    # causal=False: every cached position is <= current; padding handled by
    # masking positions >= index+1 via kv_len... kv_len must be static, so we
    # mask inside via explicit iota compare (dynamic index).
    o = _decode_attend(q, k_use, v_use, index, cfg)
    o = jnp.einsum("bsk,kd->bsd", o.reshape(b, 1, -1), params["wo"])
    out = x + shard_constraint(o, ("data", None, None))
    if cfg.kv_quant:
        return out, cache_k, cache_v, scale_k, scale_v
    return out, cache_k, cache_v


def _decode_attend(q, k, v, index, cfg: ModelConfig):
    """q (B,1,H,HD) vs full cache with dynamic length mask.

    MXU-style numerics: operands stay in their storage dtype (bf16) with
    fp32 ACCUMULATION via preferred_element_type — upcasting k/v wholesale
    would materialize an fp32 copy of the entire cache (gigabytes).
    """
    b, _, h, hd = q.shape
    smax, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    if index.ndim == 0:
        valid = (jnp.arange(smax) <= index)[None, None, None, None, :]
    else:  # per-slot lengths: each stream masks its own tail
        valid = (jnp.arange(smax)[None, :]
                 <= index[:, None])[:, None, None, None, :]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, hd).astype(q.dtype)
