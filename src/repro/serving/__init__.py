"""Session serving: continuous batching of plastic streams into fixed slots.

FireFly-P's Phase-2 controllers rewrite their own synapses on every step, so
a "user" of this system is not a request — it is a long-lived plastic STATE
(`NetworkState` for controllers, ``W_fast`` for the LM adapter) that must
outlive any single residency in the accelerator fleet.  This package is the
machinery between the fleet tensor (PR 2: B per-request weight sets stepped
as one fused launch) and millions of such users:

  * `sessions.SessionStore`   — owns per-user plastic state: LRU warm cache
    over durable `checkpoint.manager` persistence (``<root>/<uid>/step_*``,
    atomic LATEST, keep-K gc).  Evict -> restore is bit-identical.
  * `scheduler.FleetScheduler` — admits/evicts sessions into a FIXED-shape
    ``(B, N, M)`` slot pool via jitted gather/scatter swaps (slot index
    traced: no shape change, no recompile, ever) and steps the whole pool
    through the `engine.layer_step` fleet path in one fused launch.
  * the ``active (B,)`` slot mask — threaded through ref/kernel/ops/engine
    (`engine.layer_step(active=...)`): vacant slots are TRUE no-ops, their
    weights/membranes/traces frozen bit-exactly and events zeroed, which is
    what makes fixed-shape continuous batching semantically correct rather
    than "idle slots drift anyway".

Both the SNN controller fleet (`FleetScheduler`) and the LM decode pool
(`lm.LMScheduler`: backbone caches + per-slot sequence indices + plastic
adapter rows + pending tokens, any `models.factory` layout) ride the same
generic `scheduler.SessionPool` base — one slot-axes pytree per pool, one
traced-slot gather/scatter pair, one active-mask no-op contract.

Entry points: ``launch/serve.py --plastic --session-dir`` (LM adapter
sessions via `lm.AdapterPool`), ``examples/session_serving.py`` (controller
pool under churn), ``benchmarks/serving_churn.py`` and
``benchmarks/serving_lm.py`` (churn sweeps; pin zero recompiles after
warm-up and evict->restore bit-equality).
"""
from repro.serving.lm import AdapterPool, LMScheduler
from repro.serving.scheduler import (SHARED, FleetScheduler, SessionPool,
                                     make_slot_ops, slot_put, slot_take,
                                     uniform_axes)
from repro.serving.sessions import SessionStore

__all__ = ["AdapterPool", "FleetScheduler", "LMScheduler", "SHARED",
           "SessionPool", "SessionStore", "make_slot_ops", "slot_put",
           "slot_take", "uniform_axes"]
