"""Fault tolerance: NaN sentinel + rollback, straggler monitor, elastic re-mesh.

Designed for the 1000+-node posture:

  * FaultTolerantRunner wraps any step function.  Every step's loss is
    checked by a NaN/inf sentinel; a poisoned step triggers rollback to the
    last good checkpoint (skipping the poisoned data batch — the batch index
    advances past it, which the deterministic pipeline makes exact).
  * StragglerMonitor keeps a per-step wall-time EWMA and flags steps (hosts,
    in multi-host deployments where each host reports) slower than
    mean + k * std — the signal a scheduler uses to trigger hot-spare swaps.
  * elastic_restore() reshards any checkpoint onto any new mesh: storage is
    unsharded (checkpoint/manager.py), so restore = device_put onto the new
    NamedShardings.  Works across device-count changes (elastic scaling).

Both the runner and the monitor accept an `obs.MetricsRegistry`: resume /
rollback / straggler events and step times land in the same `snapshot()` /
Prometheus surface the serving pools export (previously they lived only in
the in-process `events` list, invisible to scraping).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.obs import MetricsRegistry


def loss_is_bad(loss) -> bool:
    """Host-side NaN/inf sentinel: True if ANY element is non-finite.

    Accepts scalars OR arrays (per-shard / per-session loss vectors from a
    sharded pool report one value per device or slot) — the reduction is
    any-NaN, because one poisoned shard poisons the step exactly like one
    poisoned scalar did."""
    v = np.asarray(jax.device_get(loss))
    return not bool(np.isfinite(v).all())


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags outliers that exceed BOTH
    mean + k*std and (1 + rel_min)*mean — the relative floor stops noise
    flags when the variance is tiny (lock-step SPMD steps)."""

    alpha: float = 0.1
    k: float = 3.0
    rel_min: float = 0.2
    warmup: int = 5

    mean: float = 0.0
    var: float = 0.0          # VARIANCE estimate (not a Welford M2 sum)
    n: int = 0
    flagged: int = 0
    _m2: float = 0.0          # Welford sum of squared deviations (warmup)

    def observe(self, dt: float) -> bool:
        """Record one step time; returns True if it is a straggler event."""
        self.n += 1
        # var must be a sample variance by the time the flag branch reads
        # it, which takes at least two observations — clamp the warmup so a
        # warmup=0/1 monitor can't flag off a zero (1e-9) std.
        warmup = max(self.warmup, 2)
        if self.n <= warmup:
            # Welford priming: _m2 accumulates the sum of squared
            # deviations; var is its unbiased sample-variance view.  (The
            # historical code kept the M2 SUM in `var` and divided by the
            # ever-growing n-1 after warmup, while the EWMA below mixed
            # squared deviations into the same field — biasing std low and
            # shrinking it further every step.)
            d = dt - self.mean
            self.mean += d / self.n
            self._m2 += d * (dt - self.mean)
            self.var = self._m2 / max(self.n - 1, 1)
            return False
        std = max(self.var ** 0.5, 1e-9)
        is_straggler = (dt > self.mean + self.k * std
                        and dt > (1.0 + self.rel_min) * self.mean)
        if is_straggler:
            self.flagged += 1
        # EWMA update (outliers damped so one straggler doesn't poison stats)
        w = self.alpha if not is_straggler else self.alpha * 0.1
        self.mean = (1 - w) * self.mean + w * dt
        self.var = (1 - w) * self.var + w * (dt - self.mean) ** 2
        return is_straggler


class FaultTolerantRunner:
    """Checkpoint/restart + NaN rollback + straggler accounting around a step.

    step_fn(state, batch) -> (state, metrics) must be pure (jit-compiled).
    `state` is any pytree that fully determines training (params, opt state,
    step counter, rng).  Batches come from a step-indexed pipeline so replay
    after rollback is deterministic.
    """

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 save_every: int = 100, max_rollbacks: int = 3,
                 shardings: Any = None,
                 registry: Optional[MetricsRegistry] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.save_every = save_every
        self.max_rollbacks = max_rollbacks
        self.shardings = shardings
        self.monitor = StragglerMonitor()
        self.rollbacks = 0
        self.skipped_steps: list[int] = []
        self.events: list[dict] = []
        self.metrics = registry
        if registry is not None:
            self._m_rollbacks = registry.counter("ft_rollbacks_total")
            self._m_stragglers = registry.counter("ft_stragglers_total")
            self._m_resumes = registry.counter("ft_resumes_total")
            self._m_step_s = registry.histogram("ft_step_seconds")
        else:
            self._m_rollbacks = self._m_stragglers = None
            self._m_resumes = self._m_step_s = None

    def restore_or_init(self, state):
        """Resume from the latest checkpoint if one exists."""
        if self.ckpt.latest_step() is not None:
            state, step, _ = self.ckpt.restore(state, shardings=self.shardings)
            self.events.append({"kind": "resume", "step": step})
            if self._m_resumes is not None:
                self._m_resumes.inc()
            return state, step
        return state, 0

    def run(self, state, batches: Callable[[int], Any], num_steps: int,
            start_step: int = 0, log_every: int = 0):
        """Drive `num_steps` steps with checkpointing and rollback.

        batches(step) -> batch pytree (deterministic, step-indexed).
        Returns (state, history list of metric dicts).
        """
        history = []
        step = start_step
        if self.ckpt.latest_step() is None:
            self.ckpt.save(step, state, blocking=True)

        while step < num_steps:
            if step in self.skipped_steps:
                step += 1            # poisoned batch — do not replay it
                continue
            t0 = time.perf_counter()
            new_state, metrics = self.step_fn(state, batches(step))
            loss = jax.device_get(metrics["loss"])   # sync point
            dt = time.perf_counter() - t0

            if loss_is_bad(loss):
                # Rollback: reload the last good checkpoint, replay the
                # deterministic batches after it, and SKIP the poisoned one
                # (the skip set is consulted at the top of the loop).
                self.rollbacks += 1
                self.events.append({"kind": "rollback", "step": step,
                                    "loss": float(loss)})
                if self._m_rollbacks is not None:
                    self._m_rollbacks.inc()
                if self.rollbacks > self.max_rollbacks:
                    raise RuntimeError(
                        f"{self.rollbacks} rollbacks exceed budget; aborting")
                state, good_step, _ = self.ckpt.restore(
                    state, shardings=self.shardings)
                self.skipped_steps.append(step)
                step = min(good_step, step)
                continue

            if self._m_step_s is not None:
                self._m_step_s.observe(dt)
            if self.monitor.observe(dt):
                self.events.append({"kind": "straggler", "step": step,
                                    "dt": dt, "mean": self.monitor.mean})
                if self._m_stragglers is not None:
                    self._m_stragglers.inc()

            state = new_state
            step += 1
            history.append({"step": step, "loss": float(loss), "dt": dt})
            if log_every and step % log_every == 0:
                print(f"step {step}: loss={float(loss):.4f} dt={dt*1e3:.1f}ms")
            if step % self.save_every == 0:
                self.ckpt.save(step, state, blocking=False)

        self.ckpt.save(num_steps, state, blocking=True)
        return state, history


def elastic_restore(ckpt_dir: str, tree_like, new_mesh, sharding_fn,
                    step: Optional[int] = None):
    """Restore a checkpoint onto a DIFFERENT mesh (elastic scaling).

    sharding_fn(mesh) -> pytree of NamedShardings matching tree_like.
    Checkpoint leaves are stored unsharded, so this is a pure device_put
    re-layout — any divisor mesh works without resharding passes.
    """
    from repro.checkpoint import load_checkpoint
    shardings = sharding_fn(new_mesh)
    return load_checkpoint(ckpt_dir, tree_like, step=step,
                           shardings=shardings)
