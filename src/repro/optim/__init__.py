"""From-scratch optimizers (no optax in the container).

  adamw / sgd      — init/update pairs over arbitrary pytrees
  schedules        — warmup-cosine, linear, constant
  clip_by_global_norm
  compression      — error-feedback int8 gradient compression (opt-in
                     all-reduce replacement for bandwidth-bound meshes)
"""
from repro.optim.optimizers import (OptState, adamw, clip_by_global_norm,
                                    global_norm, sgd)
from repro.optim.schedules import constant, linear_warmup, warmup_cosine
from repro.optim.compression import (compress_int8, decompress_int8,
                                     ef_compress_update, init_ef_state)

__all__ = ["OptState", "adamw", "sgd", "clip_by_global_norm", "global_norm",
           "constant", "linear_warmup", "warmup_cosine",
           "compress_int8", "decompress_int8", "ef_compress_update",
           "init_ef_state"]
