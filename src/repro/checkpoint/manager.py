"""Sharded checkpointing with atomic manifest commit and resharding restore.

Layout per step:

    <dir>/step_000123/
        manifest.json      # tree structure, shapes, dtypes, leaf -> file map
        leaf_00000.npy ... # one .npy per leaf (host-local shard in multi-host;
                           # full array in single-process)
    <dir>/LATEST           # atomic pointer file, written LAST

Crash-safety contract: a checkpoint is visible only after its manifest AND
the LATEST pointer are fully written (os.replace is atomic on POSIX).  A
half-written step directory is ignored by loaders and reaped by `gc()`.

Resharding restore: leaves are stored unsharded (np.asarray gathers); load
places them onto whatever mesh/sharding the *new* topology asks for — this
is what makes elastic re-mesh (restore onto a different device count) work.
Async save: `save(..., blocking=False)` snapshots to host RAM immediately
(jax.device_get) and writes on a daemon thread — the train loop resumes
while I/O drains; `wait()` joins before the next save or at exit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in kp) for kp, _ in leaves_with_paths]
    leaves = [l for _, l in leaves_with_paths]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Write one checkpoint synchronously.  Returns the step dir path."""
    step_dir = os.path.join(directory, f"step_{step:09d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)

    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp_dir, fname), arr)
        manifest["leaves"].append({
            "path": path, "file": fname,
            "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)                      # atomic visibility
    _write_latest(directory, step)
    return step_dir


def _write_latest(directory: str, step: int) -> None:
    tmp = os.path.join(directory, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(directory, "LATEST"))


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def load_checkpoint(directory: str, tree_like: Any, step: Optional[int] = None,
                    shardings: Any = None):
    """Restore into the structure of `tree_like`.

    `shardings`: optional pytree of NamedShardings (same structure) — leaves
    are device_put onto them, which is the resharding path: the stored arrays
    are full (unsharded), so ANY target mesh works (elastic re-mesh).

    Returns (tree, step, extra).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    step_dir = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)

    paths, leaves, treedef = _flatten_with_paths(tree_like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for path, like, shd in zip(paths, leaves, shard_leaves):
        entry = by_path.get(path)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {path!r}")
        arr = np.load(os.path.join(step_dir, entry["file"]))
        want_shape = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {path}: ckpt {arr.shape} vs {want_shape}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), step, manifest["extra"]


class CheckpointManager:
    """Keep-K rotating checkpoints with async save.

    save() with blocking=False snapshots device arrays to host immediately
    and performs file I/O on a background thread; wait() joins it.  The
    manager is what the fault-tolerance layer (distributed/ft.py) drives.
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = True) -> None:
        self.wait()
        # snapshot NOW (cheap host copy) so training can mutate buffers
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if blocking:
            save_checkpoint(self.directory, step, host_tree, extra)
            self.gc()
            return

        def _bg():
            save_checkpoint(self.directory, step, host_tree, extra)
            self.gc()

        self._thread = threading.Thread(target=_bg, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None):
        self.wait()
        return load_checkpoint(self.directory, tree_like, step, shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(steps)

    def gc(self) -> None:
        """Remove all but the newest `keep` complete checkpoints + orphans."""
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else steps:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)
