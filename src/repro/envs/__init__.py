"""Pure-JAX continuous-control environments (Brax stand-ins, DESIGN.md §8.1).

Three tasks mirroring the paper's evaluation protocol (Sec. IV-A):

  * direction: planar 8-thruster locomotor trained on 8 target directions,
               evaluated on 72 unseen directions            (Brax `ant`)
  * velocity:  1-D runner trained on 8 target velocities,
               evaluated on 72 unseen velocities            (Brax `halfcheetah`)
  * position:  2-link torque-controlled reacher with random
               goal positions                               (Brax `ur5e`)

All are reset/step pure functions, vmap- and scan-compatible, with an
actuator-mask channel to simulate morphology damage ("leg failure").
"""
from repro.envs.base import Env, EnvState
from repro.envs.direction import DirectionEnv
from repro.envs.velocity import VelocityEnv
from repro.envs.reacher import ReacherEnv

ENVS = {
    "direction": DirectionEnv,
    "velocity": VelocityEnv,
    "position": ReacherEnv,
}


def make(name: str, **kwargs) -> Env:
    return ENVS[name](**kwargs)
