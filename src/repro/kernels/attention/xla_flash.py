"""Blocked online-softmax attention in pure XLA (no Pallas).

This is what the LM models lower for training/prefill: O(S^2) score tiles
never materialize in HBM (peak live tile is (B, H, bq, bkv)), and causality
is exploited structurally — the python-level loop over query blocks gives
each block a *statically bounded* KV range, so compiled FLOPs track the
~S^2/2 causal ideal instead of the dense S^2.

GQA without repeat: einsum over grouped heads (q head h -> kv head h // g),
K/V stay (HKV,)-shaped.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_attend(q, k, v, *, scale, q_start, causal, block_kv):
    """One (q block) x (kv range) online-softmax pass via scan over kv blocks.

    q (B, bq, Hkv, G, D); k/v (B, Skv, Hkv, D)  ->  (B, bq, Hkv, G, D)
    """
    b, bq, hkv, g, d = q.shape
    skv = k.shape[1]
    nf = jnp.float32
    q32 = q.astype(nf) * scale

    bkv = min(block_kv, skv)
    pad = (-skv) % bkv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_kv = (skv + pad) // bkv
    kb = k.reshape(b, n_kv, bkv, hkv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_kv, bkv, hkv, d).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m, l, acc = carry                                 # m/l (B,Hkv,G,bq)
        kt, vt, j = inp                                   # (B,bkv,Hkv,D)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q32, kt.astype(nf))
        k_pos = j * bkv + jnp.arange(bkv)[None, :]
        mask = k_pos < skv
        if causal:
            q_pos = q_start + jnp.arange(bq)[:, None]
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + p.sum(-1)
        pv = jnp.einsum("bhgqk,bkhd->bqhgd", p, vt.astype(nf))
        acc = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, bq), NEG_INF, nf)
    l0 = jnp.zeros((b, hkv, g, bq), nf)
    acc0 = jnp.zeros((b, bq, hkv, g, d), nf)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  (kb, vb, jnp.arange(n_kv)))
    l = jnp.where(l == 0, 1.0, l)
    return acc / l.transpose(0, 3, 1, 2)[..., None]


def blocked_attention(q, k, v, *, causal: bool = True,
                      scale: Optional[float] = None,
                      kv_len: Optional[int] = None,
                      block_q: int = 2048, block_kv: int = 1024):
    """q (B,Sq,H,D), k/v (B,Skv,HKV,D) -> (B,Sq,H,D).

    Python loop over q blocks => causal blocks only scan their own KV prefix
    (static bound), halving compiled attention FLOPs vs. a dense mask.
    """
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    q_offset = skv - sq
    qg = q.reshape(b, sq, hkv, g, d)  # q head h -> (h // g, h % g)

    outs = []
    for qs in range(0, sq, block_q):
        bq = min(block_q, sq - qs)
        qblk = qg[:, qs:qs + bq]
        kv_end = min(skv, qs + bq + q_offset) if causal else skv
        if kv_len is not None:
            kv_end = min(kv_end, kv_len)
        o = _block_attend(qblk, k[:, :kv_end], v[:, :kv_end], scale=scale,
                          q_start=qs + q_offset, causal=causal,
                          block_kv=block_kv)
        outs.append(o.reshape(b, bq, h, d))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
    return out.astype(q.dtype)
