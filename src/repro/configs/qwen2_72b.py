"""qwen2-72b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064; GQA, QKV bias.  [arXiv:2407.10671; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
    layout="dense",
)

SMOKE = ModelConfig(
    name="qwen2-72b-smoke",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512,
    qkv_bias=True, rope_theta=1_000_000.0,
    layout="dense", remat=False,
)
