import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers AND
compiles under the production meshes, and extract the roofline inputs.

The two lines above run before any other import — jax locks the device
count at first init, and the dry-run needs 512 placeholder CPU devices so
jax.make_mesh can build (16,16) and (2,16,16).

Per cell this driver:
  1. builds abstract inputs (specs.input_specs — ShapeDtypeStructs, no
     allocation),
  2. jit(...).lower(...).compile() under the mesh,
  3. records compiled.memory_analysis() (the fits-in-HBM proof),
     compiled.cost_analysis() (XLA's own counters, loop bodies counted
     once — kept for reference), and hlo_analysis.analyze() (trip-count-
     attributed FLOPs / bytes / per-kind collective wire bytes: the
     numbers §Roofline uses),
  4. writes benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json.

Usage:
    python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    python -m repro.launch.dryrun --all --mesh single --jobs 8
    python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import json
import sys
import time
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

LM_ARCHS = [
    "qwen2-72b", "internlm2-20b", "qwen3-4b", "qwen1.5-32b",
    "zamba2-7b", "deepseek-moe-16b", "grok-1-314b",
    "musicgen-medium", "pixtral-12b", "mamba2-1.3b",
]
SHAPE_NAMES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             plastic: bool = False, fsdp: bool = True,
             save: bool = True, overrides: dict | None = None) -> dict:
    import jax

    from repro.distributed import sharding as shd
    from repro.launch import hlo_analysis, steps
    from repro.launch.mesh import HW, make_production_mesh
    from repro.launch.specs import input_specs
    from repro.optim import adamw, warmup_cosine

    mesh_kind = "multi" if multi_pod else "single"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()

    if overrides and "fsdp" in overrides:
        fsdp = overrides.pop("fsdp")
    cfg_overrides = {}
    if overrides:
        from repro.configs import get_config as _gc
        probe = _gc(arch)
        cfg_overrides = {k: v for k, v in overrides.items()
                         if hasattr(probe, k)}
    with shd.use_mesh(mesh), mesh:
        spec = input_specs(arch, shape_name, mesh, plastic=plastic,
                           fsdp=fsdp, cfg_overrides=cfg_overrides)
        cfg = spec["cfg"]
        if overrides:
            spec["setup"].update({k: v for k, v in overrides.items()
                                  if not hasattr(cfg, k)})
        out = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "chips": int(n_chips), "plastic": plastic,
            "kind": spec["kind"], "setup": spec.get("setup", {}),
        }
        if spec["kind"] == "skip":
            out["skipped"] = spec["why"]
            if save:
                _save(out, mesh_kind, arch, shape_name, plastic)
            return out

        setup = spec["setup"]
        if spec["kind"] == "train":
            opt = adamw(lr=warmup_cosine(3e-4, 100, 10_000),
                        moment_dtype=setup.get("moment_dtype", "float32"))
            fn = steps.make_train_step(
                cfg, opt, microbatches=setup.get("microbatches", 1),
                accum_dtype=setup.get("accum_dtype", "float32"),
                remat_policy=setup.get("remat_policy", "nothing"))
            donate = (0, 1)
        elif spec["kind"] == "prefill":
            fn = steps.make_prefill(cfg, spec["shape"].seq_len)
            donate = ()
        else:
            fn = steps.make_decode_step(cfg)
            donate = (1,)

        lowered = jax.jit(fn, donate_argnums=donate).lower(*spec["args"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_rec = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_rec[attr] = int(v)
        # arguments are donated (params/opt/cache buffers are reused), so
        # live bytes per device = args + temps (outputs alias args)
        live = (mem_rec.get("argument_size_in_bytes", 0)
                + mem_rec.get("temp_size_in_bytes", 0))
        mem_rec["live_bytes_per_device"] = live
        est = estimate_tpu_memory(spec, mesh)
        mem_rec.update(est)
        mem_rec["hbm_frac"] = est["tpu_live_bytes"] / HW["hbm_bytes"]
        mem_rec["hbm_frac_cpu_compiled"] = live / HW["hbm_bytes"]
        print(f"[{mesh_kind}] {arch} x {shape_name}: "
              f"tpu-est {est['tpu_live_bytes']/2**30:.2f} GiB/chip "
              f"({100*mem_rec['hbm_frac']:.0f}% of HBM); "
              f"cpu-compiled live {live/2**30:.2f} GiB/chip")
        print(mem)

        cost = compiled.cost_analysis()
        cost_rec = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float))
                    and k in ("flops", "bytes accessed", "transcendentals")}
        print({k: f"{v:.3e}" for k, v in cost_rec.items()})

        hlo = hlo_analysis.analyze(compiled.as_text())
        out.update({
            "lower_s": t_lower, "compile_s": t_compile,
            "memory": mem_rec, "cost_analysis": cost_rec, "hlo": hlo,
            "model_flops": steps.model_flops(
                cfg, spec["kind"], spec["shape"].global_batch,
                spec["shape"].seq_len),
            "n_params": _n_params(cfg),
            "n_active_params": steps.n_active_params(cfg),
        })
        terms = hlo_analysis.roofline_terms(hlo, HW)
        out["roofline"] = terms
        print({k: (f"{v:.3e}" if isinstance(v, float) else v)
               for k, v in terms.items()})

    if save:
        _save(out, mesh_kind, arch, shape_name, plastic)
    return out


def _n_params(cfg) -> int:
    from repro.models import transformer as T
    return T.n_params(cfg)


def _tree_device_bytes(tree) -> int:
    """Exact per-device bytes of a ShapeDtypeStruct pytree with shardings."""
    import jax
    import math
    total = 0
    for l in jax.tree.leaves(tree):
        shape = l.shape
        if getattr(l, "sharding", None) is not None:
            shape = l.sharding.shard_shape(l.shape)
        total += math.prod(shape, start=1) * l.dtype.itemsize
    return total


def estimate_tpu_memory(spec, mesh) -> dict:
    """Analytic TPU-native live-bytes estimate per device.

    XLA:CPU's float-normalization pass legalizes bf16 dots by materializing
    fp32 copies of their operands (including multi-GiB KV caches), which
    inflates compiled ``memory_analysis`` temps ~2-3x relative to a TPU
    compilation where bf16 is native.  This estimate is the TPU-side
    number: exact sharded argument/output bytes + an activation/workspace
    model (documented in EXPERIMENTS.md §Dry-run).
    """
    cfg, kind, setup = spec["cfg"], spec["kind"], spec.get("setup", {})
    args_b = sum(_tree_device_bytes(a) for a in spec["args"])
    act_b = 0
    ws_b = 256 * 2**20        # flat transient allowance (tiles, psums)
    data_ax = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    model_ax = mesh.shape.get("model", 1)
    if kind == "train":
        sh = spec["shape"]
        mb = setup.get("microbatches", 1)
        b_loc = max(sh.global_batch // (data_ax * mb), 1)
        seq_div = model_ax if cfg.act_shard == "sp" else 1
        # saved residual per remat'd block + one block's live working set
        act_b = (cfg.n_layers * b_loc * sh.seq_len * cfg.d_model * 2
                 // seq_div)
        # fp32 grad-accumulator tree (params-shaped, 2D-sharded)
        accum_itemsize = 2 if setup.get("accum_dtype") == "bfloat16" else 4
        act_b += _n_params(cfg) * accum_itemsize // (data_ax * model_ax)
    elif kind == "prefill":
        from repro.models import transformer as T
        from repro.models.layers import abstract_from_plan
        cache_abs = abstract_from_plan(
            T.cache_plan(cfg, spec["shape"].global_batch,
                         spec["shape"].seq_len), mesh)
        act_b = _tree_device_bytes(cache_abs)
    # double-buffered fsdp gather working set: one layer's weights, still
    # tensor-sharded over the model axis after the data-axis gather
    ws_b += 2 * (_n_params(cfg) // max(cfg.n_layers, 1)) * 2 // model_ax
    return {"args_bytes": args_b, "activation_bytes": act_b,
            "workspace_bytes": ws_b,
            "tpu_live_bytes": args_b + act_b + ws_b}


def _save(out: dict, mesh_kind: str, arch: str, shape_name: str,
          plastic: bool) -> None:
    d = os.path.join(RESULTS_DIR, mesh_kind)
    os.makedirs(d, exist_ok=True)
    suffix = "__plastic" if plastic else ""
    path = os.path.join(d, f"{arch}__{shape_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)


def _cell_entry(job):
    """Subprocess entry (one fresh jax per cell keeps compiles independent)."""
    arch, shape_name, multi_pod, force = job
    mesh_kind = "multi" if multi_pod else "single"
    path = os.path.join(RESULTS_DIR, mesh_kind, f"{arch}__{shape_name}.json")
    if os.path.exists(path) and not force:
        return (arch, shape_name, mesh_kind, "cached")
    try:
        run_cell(arch, shape_name, multi_pod)
        return (arch, shape_name, mesh_kind, "ok")
    except Exception:
        err = traceback.format_exc()
        _save({"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "error": err.splitlines()[-1], "traceback": err},
              mesh_kind, arch, shape_name, False)
        return (arch, shape_name, mesh_kind, "FAIL: " + err.splitlines()[-1])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--plastic", action="store_true",
                    help="enable the FireFly-P plastic adapter (serve cells)")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    multi = args.mesh == "multi"
    if not args.all:
        assert args.arch and args.shape, "--arch/--shape or --all"
        run_cell(args.arch, args.shape, multi, plastic=args.plastic)
        return 0

    jobs = [(a, s, multi, args.force) for a in LM_ARCHS for s in SHAPE_NAMES]
    if args.jobs <= 1:
        results = [_cell_entry(j) for j in jobs]
    else:
        import multiprocessing as mp
        ctx = mp.get_context("spawn")
        with ctx.Pool(args.jobs) as pool:
            results = pool.map(_cell_entry, jobs)
    bad = [r for r in results if r[3].startswith("FAIL")]
    for r in results:
        print(r)
    print(f"{len(results) - len(bad)}/{len(results)} cells ok")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
