"""Jit'd public wrapper for the SSD scan. impl: "xla" (chunked ref) | "scan" |
"pallas".  Pads L to a chunk multiple with dt=0 no-op steps."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd import kernel as _kernel
from repro.kernels.ssd import ref as _ref


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def ssd(x, dt, a, bmat, c, *, chunk: int = 64, impl: str = "xla",
        interpret: bool = False):
    """x (B,L,H,P), dt (B,L,H), a (H,), bmat/c (B,L,H,S) ->
    (y (B,L,H,P), final_state (B,H,S,P))."""
    if impl == "scan":
        return _ref.ssd_scan_ref(x, dt, a, bmat, c)

    length = x.shape[1]
    pad = (-length) % chunk
    if pad:
        # dt=0 steps are exact no-ops for both state and (discarded) outputs
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))

    if impl == "pallas":
        y, sf = _kernel.ssd_pallas(x, dt, a, bmat, c, chunk=chunk,
                                   interpret=interpret)
    else:
        y, sf = _ref.ssd_chunked_ref(x, dt, a, bmat, c, chunk=chunk)
    return y[:, :length], sf


def ssd_decode_step(state, xt, dtt, a, bt, ct):
    """Single-token recurrent step. state (B,H,S,P); xt (B,H,P); dtt (B,H);
    bt/ct (B,H,S) -> (new_state, y (B,H,P))."""
    compute = jnp.float32
    xt, dtt, bt, ct = (t.astype(compute) for t in (xt, dtt, bt, ct))
    da = jnp.exp(a.astype(compute)[None, :] * dtt)
    upd = dtt[..., None, None] * bt[..., :, None] * xt[..., None, :]
    state = da[..., None, None] * state.astype(compute) + upd
    y = jnp.einsum("bhs,bhsp->bhp", ct, state)
    return state, y.astype(xt.dtype)
