"""Step builders: the jit-able train / prefill / decode programs.

These close over a ModelConfig and return pure functions whose signatures
match what dryrun.py lowers and train.py/serve.py execute:

    train_step(params, opt_state, batch)   -> (params, opt_state, metrics)
    prefill(params, inputs)                -> (last_logits, cache)
    decode_step(params, cache, tokens)     -> (logits, cache)
    decode_window(params, cache, tokens)   -> (logits (B,K,V), cache)

Every builder resolves the config through `models.factory.build`, so any
registered layout (dense GQA, MoE, Mamba2 SSM, zamba hybrid) lowers through
the same validated surface — no caller imports `models.transformer`.

Gradient accumulation (microbatches > 1) is a lax.scan over the leading
batch split — the standard memory knob that fits 72B/314B train cells in
16 GiB/chip together with remat and "sp" activation sharding.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_constraint
from repro.models import factory
from repro.models.config import ModelConfig


def make_loss_fn(cfg: ModelConfig, attn_impl: str = "xla_flash",
                 ssd_impl: str = "xla", remat_policy: str = "nothing"):
    model = factory.build(cfg)

    def loss(params, batch):
        return model.loss_fn(params, batch, attn_impl=attn_impl,
                             ssd_impl=ssd_impl, remat_policy=remat_policy)
    return loss


def make_train_step(cfg: ModelConfig, opt, *, microbatches: int = 1,
                    accum_dtype: str = "float32",
                    attn_impl: str = "xla_flash", ssd_impl: str = "xla",
                    remat_policy: str = "nothing") -> Callable:
    loss_fn = make_loss_fn(cfg, attn_impl, ssd_impl, remat_policy)
    adt = jnp.dtype(accum_dtype)

    def step(params, opt_state, batch):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            b = batch["labels"].shape[0]
            assert b % microbatches == 0, (b, microbatches)

            def split(x):
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                mb = jax.tree.map(
                    lambda x: shard_constraint(
                        x, ("data",) + (None,) * (x.ndim - 1)), mb)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, x: (a.astype(jnp.float32)
                                  + x.astype(jnp.float32)).astype(adt),
                    g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (g_sum, l_sum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32)
                                 / microbatches, g_sum)
            loss = l_sum / microbatches

        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return step


def make_prefill(cfg: ModelConfig, max_len: int,
                 attn_impl: str = "xla_flash", ssd_impl: str = "xla"):
    model = factory.build(cfg)

    def prefill(params, inputs):
        return model.prefill(params, inputs, max_len,
                             attn_impl=attn_impl, ssd_impl=ssd_impl)
    return prefill


def make_decode_step(cfg: ModelConfig):
    model = factory.build(cfg)

    def decode(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return decode


def make_decode_window(cfg: ModelConfig):
    """Multi-token teacher-forced decode: tokens (B, K) advance every
    stream K positions in one program — the backbone scans per token while
    the plastic adapter runs all K plasticity steps as ONE time-fused
    engine launch (`plastic.decode_rollout`).  Bit-identical to K
    `decode_step` calls."""
    model = factory.build(cfg)

    def decode_window(params, cache, tokens):
        return model.decode_rollout(params, cache, tokens)
    return decode_window


# ---------------------------------------------------------------------------
# Introspection: "useful" model FLOPs for the §Roofline ratio
# ---------------------------------------------------------------------------


def n_active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (== total for dense; active experts
    only for MoE).  Excludes the input embedding gather (not a matmul)."""
    total = factory.build(cfg).n_params()
    embed = cfg.vocab * cfg.d_model
    if cfg.moe is None:
        return total - embed
    m = cfg.moe
    expert_params = 3 * cfg.d_model * m.d_expert      # gate/up/down per expert
    n_moe_layers = cfg.n_layers - m.first_dense
    inactive = n_moe_layers * (m.num_experts - m.top_k) * expert_params
    return total - embed - inactive


def model_flops(cfg: ModelConfig, kind: str, global_batch: int,
                seq_len: int) -> float:
    """MODEL_FLOPS per step: 6*N*D train (fwd+bwd), 2*N*D prefill,
    2*N_active*B decode (one token per stream)."""
    n_act = n_active_params(cfg)
    if kind == "train":
        return 6.0 * n_act * global_batch * seq_len
    if kind == "prefill":
        return 2.0 * n_act * global_batch * seq_len
    if kind == "decode":
        return 2.0 * n_act * global_batch
    raise ValueError(kind)
