"""Procedural token pipeline: deterministic, restartable, shard-aware.

Design constraints for 1000-node training:
  * The batch for step N is a pure function of (seed, step, shard) — any host
    can reconstruct any step, so checkpoint-restart and elastic re-sharding
    need no data-loader state beyond the step counter.
  * Hosts materialize only their shard (host_batch) — the global batch never
    exists on one machine.

The generator is a two-level Markov-ish process: a slowly varying "topic"
selects one of K unigram tables (Zipf-tilted), and a copy channel repeats
the previous token with prob p_copy — enough structure that a real LM loss
decreases, while staying fully procedural/offline.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_topics: int = 16
    zipf_a: float = 1.1
    p_copy: float = 0.25
    topic_block: int = 64          # tokens per topic segment


def _topic_logits(cfg: TokenPipelineConfig) -> jax.Array:
    """(n_topics, vocab) fixed per-topic unigram logits (seeded)."""
    key = jax.random.PRNGKey(cfg.seed ^ 0x5EED)
    base = -cfg.zipf_a * jnp.log(jnp.arange(1, cfg.vocab + 1, dtype=jnp.float32))
    perm_keys = jax.random.split(key, cfg.n_topics)
    perms = jnp.stack([jax.random.permutation(k, cfg.vocab)
                       for k in perm_keys])
    return base[perms]             # each topic = permuted Zipf


def batch_at_step(cfg: TokenPipelineConfig, step: int,
                  shard: tuple[int, int] = (0, 1)):
    """Tokens+labels for global step `step`, restricted to `shard`=(i, n).

    Returns {"inputs": (B/n, S) int32, "labels": (B/n, S) int32} where
    labels are inputs shifted left (next-token prediction), -1 on the tail.
    """
    i, n = shard
    rows = cfg.global_batch // n
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    key = jax.random.fold_in(key, i)
    logits = _topic_logits(cfg)

    s_plus = cfg.seq_len + 1
    n_blocks = (s_plus + cfg.topic_block - 1) // cfg.topic_block
    k_topic, k_tok, k_copy = jax.random.split(key, 3)
    topics = jax.random.randint(k_topic, (rows, n_blocks), 0, cfg.n_topics)
    topics = jnp.repeat(topics, cfg.topic_block, axis=1)[:, :s_plus]
    tok_logits = logits[topics]                      # (rows, S+1, V)
    toks = jax.random.categorical(k_tok, tok_logits)  # (rows, S+1)

    # copy channel: with prob p_copy, token t repeats token t-1
    copy = jax.random.uniform(k_copy, (rows, s_plus)) < cfg.p_copy
    def roll(carry, inp):
        tok, cp = inp
        out = jnp.where(cp, carry, tok)
        return out, out
    _, seq = jax.lax.scan(roll, toks[:, 0], (toks.T, copy.T))
    seq = seq.T.astype(jnp.int32)                    # (rows, S+1)

    return {"inputs": seq[:, :-1],
            "labels": seq[:, 1:]}


def host_batch(cfg: TokenPipelineConfig, step: int, host_id: int,
               n_hosts: int):
    """The slice of step `step` this host feeds to its addressable devices."""
    return batch_at_step(cfg, step, shard=(host_id, n_hosts))
