"""FireFly-P core: four-term rule, PlasticEngine, LIF SNN, PEPG, two-phase learning."""
from repro.core import adaptation, engine, es, plasticity, snn

__all__ = ["adaptation", "engine", "es", "plasticity", "snn"]
