"""Fused dual-engine Pallas TPU kernel (FireFly-P Secs. III-B/C on TPU).

One kernel invocation = one SNN timestep for one synaptic layer: the Forward
Engine (psum matmul -> neuron dynamics -> trace) AND the Plasticity Engine
(four-term dw) execute on the SAME VMEM-resident weight/coefficient tiles.
This is the hot path behind `core.engine.layer_step` — every network-level
timestep in the product routes here when ``impl="pallas"``.

FPGA -> TPU adaptation (DESIGN.md Sec. 2):
  * psum-stationary PE registers  -> fp32 accumulation inside the MXU dot;
    the full fan-in N is kept resident per tile (controller layers are
    <= a few K wide, so (N, bm) weight tiles fit VMEM comfortably).
  * wide packed {a,b,g,d} fetch   -> theta is ONE (4, N, bm) block => a
    single HBM->VMEM DMA streams all four coefficient planes per tile.
  * dual-engine overlap           -> fusion: w/theta tiles are read once and
    consumed by both engines before leaving VMEM; there is no second pass
    over HBM for the update (the FPGA hides update latency in time, we
    eliminate the traffic instead).

Layer modes mirror the network semantics:
  * ``spiking=True``  — LIF with hard reset; events are binary spikes.
  * ``spiking=False`` — leaky-integrator readout; the event driving the
    postsynaptic trace is ``tanh(V)`` (bounded continuous activity).
  * ``teach``         — optional teaching current added to the psum
    (supervised online learning on the output layer).
  * ``plastic=False`` — the theta/trace_pre operands are dropped entirely;
    no coefficient DMA is issued and weights pass through unchanged.
  * ``active``        — fleet-only (B,) slot mask (session serving): an
    inactive stream's weights/membrane/traces are written back unchanged
    (dw gated, not merely small) and its events are zeroed, so vacated
    slots of a fixed-shape fleet tensor are true no-ops.

Grid: (M // bm,) — one program per block of postsynaptic neurons.  Every
block sees the whole batch and the whole fan-in, so both matmuls (forward
x@w and Hebbian trace_pre^T@trace_post) are single MXU calls per tile.

FLEET MODE (`dual_engine_fleet_step_pallas`): weights carry a leading
request-stream rank (B, N, M) and the grid becomes (cdiv(M, bm), B) — one
program per stream x postsynaptic tile, iterating streams INNERMOST so the
shared theta block's index is constant across the whole fleet and the
Pallas pipeline's block-revisit elision fetches each (4, N, bm) coefficient
tile from HBM once per tile, not once per stream.  Each stream rewrites its
OWN synapses with a per-sample dw (no batch averaging).  This is the
many-user serving path: B independent plastic memories advance in ONE
kernel launch instead of `vmap` stamping out B launches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.plasticity import ALPHA, BETA, GAMMA, DELTA
from repro.kernels.plasticity import quant as Q
from repro.obs.telemetry import sat_threshold, sat_threshold_q


def _forward_engine(x, w, v_ref, tpost_ref, teach_ref, s_out, v_out,
                    tpost_out, *, tau_m, v_th, v_reset, trace_decay,
                    spiking, gate=None):
    """Shared Forward Engine body: psum -> neuron dynamics -> trace update.

    Used verbatim by BOTH the shared-weight and the fleet kernel so the
    LIF/readout/trace math cannot diverge between them; returns the fresh
    postsynaptic trace the Plasticity Engine consumes.

    ``gate`` (fleet serving only) is this stream's scalar active flag: when
    false the membrane and trace writes select the OLD values and the event
    output is zeroed — the slot is frozen bit-exactly, which is the
    `active`-mask contract fixed-shape continuous batching relies on.
    """
    current = jnp.dot(x, w, preferred_element_type=jnp.float32)   # psum (MXU)
    if teach_ref is not None:
        current = current + teach_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    v_new = v + (current - v) * (1.0 / tau_m)   # leaky integration, tau_m = 2
    if spiking:
        spikes = (v_new >= v_th).astype(jnp.float32)
        v_upd = jnp.where(spikes > 0, v_reset, v_new)
    else:                                        # non-spiking leaky readout
        spikes = jnp.tanh(v_new)
        v_upd = v_new
    tpost = tpost_ref[...].astype(jnp.float32)
    tpost_new = trace_decay * tpost + spikes    # Trace Update Unit

    if gate is not None:
        spikes = jnp.where(gate, spikes, jnp.zeros_like(spikes))
        v_upd = jnp.where(gate, v_upd, v)
        tpost_new = jnp.where(gate, tpost_new, tpost)
    s_out[...] = spikes.astype(s_out.dtype)
    v_out[...] = v_upd.astype(v_out.dtype)
    tpost_out[...] = tpost_new.astype(tpost_out.dtype)
    return tpost_new


def _dual_engine_kernel(x_ref, w_ref, v_ref, tpost_ref, *refs,
                        tau_m, v_th, v_reset, trace_decay, w_clip,
                        plastic, spiking, has_teach, batch):
    # Optional operands, in order: theta/tpre (plastic), teach.
    rest = list(refs)
    theta_ref = rest.pop(0) if plastic else None
    tpre_ref = rest.pop(0) if plastic else None
    teach_ref = rest.pop(0) if has_teach else None
    s_out, v_out, tpost_out, w_out = rest

    # ---- Forward Engine ----------------------------------------------------
    x = x_ref[...].astype(jnp.float32)          # (B, N)
    w = w_ref[...].astype(jnp.float32)          # (N, bm)
    tpost_new = _forward_engine(
        x, w, v_ref, tpost_ref, teach_ref, s_out, v_out, tpost_out,
        tau_m=tau_m, v_th=v_th, v_reset=v_reset, trace_decay=trace_decay,
        spiking=spiking)

    # ---- Plasticity Engine (same tiles, still in VMEM) ---------------------
    if plastic:
        th = theta_ref[...].astype(jnp.float32)  # (4, N, bm) single wide fetch
        tpre = tpre_ref[...].astype(jnp.float32)  # (B, N)
        hebb = jnp.dot(tpre.T, tpost_new,
                       preferred_element_type=jnp.float32) / batch
        pre_m = jnp.mean(tpre, axis=0)           # (N,)
        post_m = jnp.mean(tpost_new, axis=0)     # (bm,)
        dw = (th[ALPHA] * hebb + th[BETA] * pre_m[:, None]
              + th[GAMMA] * post_m[None, :] + th[DELTA])
        w_new = jnp.clip(w + dw, -w_clip, w_clip)
        w_out[...] = w_new.astype(w_out.dtype)
    else:
        w_out[...] = w.astype(w_out.dtype)


def dual_engine_step_pallas(x, w, theta, v, trace_pre, trace_post, *,
                            tau_m: float = 2.0, v_th: float = 1.0,
                            v_reset: float = 0.0, trace_decay: float = 0.8,
                            w_clip: float = 4.0, plastic: bool = True,
                            spiking: bool = True, teach=None,
                            block_m: int = 128, interpret: bool = False):
    """Pallas-call wrapper.  Shapes as in ref.dual_engine_step (batched)."""
    b, n = x.shape
    n2, m = w.shape
    assert n == n2, (x.shape, w.shape)
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)
    has_teach = teach is not None

    kernel = functools.partial(
        _dual_engine_kernel, tau_m=tau_m, v_th=v_th, v_reset=v_reset,
        trace_decay=trace_decay, w_clip=w_clip, plastic=plastic,
        spiking=spiking, has_teach=has_teach, batch=b)

    in_specs = [
        pl.BlockSpec((b, n), lambda j: (0, 0)),        # x: full batch/fan-in
        pl.BlockSpec((n, bm), lambda j: (0, j)),       # w tile
        pl.BlockSpec((b, bm), lambda j: (0, j)),       # v tile
        pl.BlockSpec((b, bm), lambda j: (0, j)),       # post trace tile
    ]
    operands = [x, w, v, trace_post]
    if plastic:
        in_specs += [
            pl.BlockSpec((4, n, bm), lambda j: (0, 0, j)),  # packed theta
            pl.BlockSpec((b, n), lambda j: (0, 0)),         # pre trace
        ]
        operands += [theta, trace_pre]
    if has_teach:
        in_specs.append(pl.BlockSpec((b, bm), lambda j: (0, j)))
        operands.append(teach)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((b, bm), lambda j: (0, j)),       # events
            pl.BlockSpec((b, bm), lambda j: (0, j)),       # v out
            pl.BlockSpec((b, bm), lambda j: (0, j)),       # post trace out
            pl.BlockSpec((n, bm), lambda j: (0, j)),       # w out
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), x.dtype),
            jax.ShapeDtypeStruct((b, m), v.dtype),
            jax.ShapeDtypeStruct((b, m), trace_post.dtype),
            jax.ShapeDtypeStruct((n, m), w.dtype),
        ],
        interpret=interpret,
    )(*operands)


def _fleet_kernel(x_ref, w_ref, v_ref, tpost_ref, *refs,
                  tau_m, v_th, v_reset, trace_decay, w_clip,
                  plastic, spiking, has_teach, has_active, telemetry,
                  m_total, bm):
    """One program = one request stream x one postsynaptic tile.

    Per-sample semantics throughout: the Hebbian term is the outer product
    of THIS stream's traces (no batch averaging) and the rewritten weight
    tile belongs to this stream alone.  With ``has_active`` the stream's
    scalar slot flag gates every state write (weights, membrane, traces
    frozen; events zeroed) so vacated fleet slots are true no-ops.

    ``telemetry`` appends a per-tile (1, 1, 3) partial-sums output —
    [sum |events|, sum |dw|, saturated-membrane count], gated like the
    state writes — which the wrapper reduces over tiles to the raw (B, 3)
    row of `obs.telemetry`.  Computed from the already-written output
    tiles while they are still VMEM-resident: the telemetry variant adds
    three register reductions per program, never a second pass over HBM.
    """
    rest = list(refs)
    theta_ref = rest.pop(0) if plastic else None
    tpre_ref = rest.pop(0) if plastic else None
    teach_ref = rest.pop(0) if has_teach else None
    active_ref = rest.pop(0) if has_active else None
    tel_out = rest.pop() if telemetry else None
    s_out, v_out, tpost_out, w_out = rest
    gate = None if active_ref is None else active_ref[0, 0] > 0

    # ---- Forward Engine ----------------------------------------------------
    x = x_ref[...].astype(jnp.float32)           # (1, N) this stream's events
    w = w_ref[0].astype(jnp.float32)             # (N, bm) this stream's tile
    tpost_new = _forward_engine(                 # (1, bm); gated if inactive
        x, w, v_ref, tpost_ref, teach_ref, s_out, v_out, tpost_out,
        tau_m=tau_m, v_th=v_th, v_reset=v_reset, trace_decay=trace_decay,
        spiking=spiking, gate=gate)

    # ---- Plasticity Engine (same stream-resident tiles) --------------------
    if plastic:
        th = theta_ref[...].astype(jnp.float32)   # (4, N, bm) SHARED rule
        tpre = tpre_ref[...].astype(jnp.float32)  # (1, N)
        hebb = tpre[0][:, None] * tpost_new[0][None, :]        # (N, bm) outer
        dw = (th[ALPHA] * hebb + th[BETA] * tpre[0][:, None]
              + th[GAMMA] * tpost_new[0][None, :] + th[DELTA])
        w_new = jnp.clip(w + dw, -w_clip, w_clip)
        if gate is not None:
            w_new = jnp.where(gate, w_new, w)     # dw gated: slot frozen
        w_out[0] = w_new.astype(w_out.dtype)
    else:
        w_new = w
        w_out[0] = w.astype(w_out.dtype)

    if telemetry:
        # Mask columns past M: a ragged final tile's padding lanes hold
        # whatever the pipeline faulted in (NaN under interpret) and must
        # not reach the reductions.
        col_ok = (jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1)
                  + pl.program_id(0) * bm) < m_total
        ev = s_out[...].astype(jnp.float32)       # already gated (zeros)
        vv = v_out[...].astype(jnp.float32)       # frozen old v if inactive
        spike_sum = jnp.sum(jnp.where(col_ok, jnp.abs(ev), 0.0))
        dw_sum = jnp.sum(jnp.where(col_ok, jnp.abs(w_new - w), 0.0))
        sat_cnt = jnp.sum(jnp.where(
            col_ok & (jnp.abs(vv) >= sat_threshold(v_th)), 1.0, 0.0))
        g = jnp.float32(1.0) if gate is None else gate.astype(jnp.float32)
        tel_out[...] = (jnp.stack([spike_sum, dw_sum, sat_cnt])
                        * g).reshape(1, 1, 3)


def dual_engine_fleet_step_pallas(x, w, theta, v, trace_pre, trace_post, *,
                                  tau_m: float = 2.0, v_th: float = 1.0,
                                  v_reset: float = 0.0,
                                  trace_decay: float = 0.8,
                                  w_clip: float = 4.0, plastic: bool = True,
                                  spiking: bool = True, teach=None,
                                  active=None, telemetry: bool = False,
                                  block_m: int = 128,
                                  interpret: bool = False):
    """Fleet pallas-call wrapper.  Shapes as in ref.dual_engine_fleet_step:
    x (B,N), w (B,N,M) per-request, theta (4,N,M) shared, v/traces (B,·),
    active (B,) slot mask (inactive slots frozen bit-exactly, events zero).

    ``telemetry`` appends a raw (B, 3) float32 per-slot sums output (the
    `obs.telemetry` schema): the kernel emits per-tile partials into a
    (B, tiles, 3) buffer — each grid program owns its own block, so no
    cross-program accumulation is assumed — and the wrapper folds the tile
    axis.  A static flag: off-trace is byte-identical to the 4-output
    program."""
    b, n = x.shape
    b2, n2, m = w.shape
    assert (b, n) == (b2, n2), (x.shape, w.shape)
    if teach is not None and teach.ndim == 1:
        # unbatched (M,) teach: same signal to every stream (see ref)
        teach = jnp.broadcast_to(teach, (b, teach.shape[0]))
    if active is not None:
        # (B,) -> (B, 1) so each program reads its stream's scalar flag as a
        # minimal VMEM tile indexed by the stream grid coordinate.
        active = active.reshape(b, 1).astype(jnp.float32)
    bm = min(block_m, m)
    # Streams iterate INNERMOST (grid dim 1): the shared theta block's index
    # map is constant in the stream index, so consecutive grid steps revisit
    # the same coefficient tile and Pallas elides the re-DMA — one theta
    # fetch per tile for the whole fleet.
    grid = (pl.cdiv(m, bm), b)
    has_teach = teach is not None
    has_active = active is not None

    kernel = functools.partial(
        _fleet_kernel, tau_m=tau_m, v_th=v_th, v_reset=v_reset,
        trace_decay=trace_decay, w_clip=w_clip, plastic=plastic,
        spiking=spiking, has_teach=has_teach, has_active=has_active,
        telemetry=telemetry, m_total=m, bm=bm)

    in_specs = [
        pl.BlockSpec((1, n), lambda j, i: (i, 0)),         # this stream's x
        pl.BlockSpec((1, n, bm), lambda j, i: (i, 0, j)),  # per-stream w tile
        pl.BlockSpec((1, bm), lambda j, i: (i, j)),        # v tile
        pl.BlockSpec((1, bm), lambda j, i: (i, j)),        # post trace tile
    ]
    operands = [x, w, v, trace_post]
    if plastic:
        in_specs += [
            # Shared packed theta: every stream's program indexes the SAME
            # (4, N, bm) block — the rule is never materialized per stream
            # (the vmap batching rule broadcasts it to (B, 4, N, M)).
            pl.BlockSpec((4, n, bm), lambda j, i: (0, 0, j)),
            pl.BlockSpec((1, n), lambda j, i: (i, 0)),      # pre trace
        ]
        operands += [theta, trace_pre]
    if has_teach:
        in_specs.append(pl.BlockSpec((1, bm), lambda j, i: (i, j)))
        operands.append(teach)
    if has_active:
        in_specs.append(pl.BlockSpec((1, 1), lambda j, i: (i, 0)))
        operands.append(active)

    out_specs = [
        pl.BlockSpec((1, bm), lambda j, i: (i, j)),        # events
        pl.BlockSpec((1, bm), lambda j, i: (i, j)),        # v out
        pl.BlockSpec((1, bm), lambda j, i: (i, j)),        # post trace
        pl.BlockSpec((1, n, bm), lambda j, i: (i, 0, j)),  # w out
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, m), x.dtype),
        jax.ShapeDtypeStruct((b, m), v.dtype),
        jax.ShapeDtypeStruct((b, m), trace_post.dtype),
        jax.ShapeDtypeStruct((b, n, m), w.dtype),
    ]
    if telemetry:
        # Per-tile partial sums; each program writes its own (i, j) block.
        out_specs.append(pl.BlockSpec((1, 1, 3), lambda j, i: (i, j, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((b, pl.cdiv(m, bm), 3), jnp.float32))

    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    if not telemetry:
        return res
    # Fold the tile axis of the partials -> raw (B, 3) telemetry row.
    return tuple(res[:4]) + (res[4].sum(axis=1),)


# ---- fixed-point (quantized) kernels ---------------------------------------
#
# FPGA-faithful datapath (scheme in quant.py, docstring in ops.py): the
# weight pool stays int8 in HBM and is promoted IN REGISTERS/VMEM only —
# an int8 fleet pool holds ~4x more resident sessions per byte of HBM than
# the float32 pool.  Both quant kernels call the SAME quant.py helpers as
# the oracle, and every reduction is an integer reduction (exact, order
# independent), so xla vs pallas(-interpret) parity is BIT equality on the
# int32/int8 outputs, not an allclose.


def _forward_engine_q(x, w_i32, scale, v_ref, tpost_ref, teach_ref,
                      s_out, v_out, tpost_out, *, qcfg, v_th, v_reset,
                      spiking, gate=None):
    """Quantized Forward Engine body (shared + fleet): integer psum ->
    integer neuron dynamics -> integer trace update.  Returns the fresh
    postsynaptic trace (int32) the Plasticity Engine consumes."""
    acc = jnp.dot(x, w_i32, preferred_element_type=jnp.int32)  # exact psum
    i_fx = Q.current_fx(acc, scale, qcfg)
    if teach_ref is not None:
        i_fx = i_fx + teach_ref[...].astype(jnp.int32)
    v = v_ref[...].astype(jnp.int32)
    events, v_upd = Q.neuron_update_q(v, i_fx, qcfg, v_th, v_reset, spiking)
    tpost = tpost_ref[...].astype(jnp.int32)
    tpost_new = Q.trace_update_q(tpost, events, qcfg)
    if gate is not None:
        events = jnp.where(gate, events, jnp.zeros_like(events))
        v_upd = jnp.where(gate, v_upd, v)
        tpost_new = jnp.where(gate, tpost_new, tpost)
    s_out[...] = events.astype(s_out.dtype)
    v_out[...] = v_upd.astype(v_out.dtype)
    tpost_out[...] = tpost_new.astype(tpost_out.dtype)
    return tpost_new


def _tile_flat_idx(n, bm, j, m_total):
    """Flat (row * M + col) index of this (n, bm) weight tile — the GLOBAL
    per-matrix index the deterministic stochastic round hashes, identical
    to the oracle's full-matrix iota (slot-independent in fleet mode)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, bm), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, bm), 1) + j * bm
    return rows * m_total + cols


def _dual_engine_kernel_q(x_ref, w_ref, scale_ref, v_ref, tpost_ref,
                          seed_ref, *refs, qcfg, v_th, v_reset, w_clip,
                          plastic, spiking, has_teach, batch, m_total, bm):
    rest = list(refs)
    theta_ref = rest.pop(0) if plastic else None
    tpre_ref = rest.pop(0) if plastic else None
    teach_ref = rest.pop(0) if has_teach else None
    s_out, v_out, tpost_out, w_out = rest
    scale = scale_ref[0, 0]
    seed = seed_ref[0, 0]

    x = x_ref[...].astype(jnp.int32)            # (B, N) fixed point
    w_i32 = w_ref[...].astype(jnp.int32)        # (N, bm) int8 -> registers
    tpost_new = _forward_engine_q(
        x, w_i32, scale, v_ref, tpost_ref, teach_ref, s_out, v_out,
        tpost_out, qcfg=qcfg, v_th=v_th, v_reset=v_reset, spiking=spiking)

    if plastic:
        tpre = tpre_ref[...].astype(jnp.int32)  # (B, N)
        hebb_i = jnp.dot(tpre.T, tpost_new,
                         preferred_element_type=jnp.int32)     # exact
        dw = Q.dw_from_int_reductions(
            hebb_i, tpre.sum(0), tpost_new.sum(0),
            theta_ref[...].astype(jnp.float32), batch, qcfg)
        idx = _tile_flat_idx(tpre.shape[1], bm, pl.program_id(0), m_total)
        steps = Q.round_steps(dw / scale, seed, idx, qcfg)
        qmax = Q.qclip(w_clip, scale)
        w_out[...] = jnp.clip(w_i32 + steps, -qmax, qmax).astype(w_out.dtype)
    else:
        w_out[...] = w_i32.astype(w_out.dtype)


def dual_engine_step_q_pallas(x, w, scale, theta, v, trace_pre, trace_post,
                              *, qcfg, v_th: float = 1.0,
                              v_reset: float = 0.0, w_clip: float = 4.0,
                              plastic: bool = True, spiking: bool = True,
                              teach=None, seed=None, block_m: int = 128,
                              interpret: bool = False):
    """Quantized shared-weight pallas-call.  Shapes/dtypes as in
    ref.dual_engine_step_q (batched): x/v/traces int32 fixed point, w int8,
    scale () f32, seed () int32."""
    b, n = x.shape
    n2, m = w.shape
    assert n == n2, (x.shape, w.shape)
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)
    has_teach = teach is not None
    scale = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    seed = jnp.asarray(0 if seed is None else seed, jnp.int32).reshape(1, 1)

    kernel = functools.partial(
        _dual_engine_kernel_q, qcfg=qcfg, v_th=v_th, v_reset=v_reset,
        w_clip=w_clip, plastic=plastic, spiking=spiking,
        has_teach=has_teach, batch=b, m_total=m, bm=bm)

    in_specs = [
        pl.BlockSpec((b, n), lambda j: (0, 0)),        # x: full batch/fan-in
        pl.BlockSpec((n, bm), lambda j: (0, j)),       # int8 w tile
        pl.BlockSpec((1, 1), lambda j: (0, 0)),        # per-tile scale
        pl.BlockSpec((b, bm), lambda j: (0, j)),       # v tile
        pl.BlockSpec((b, bm), lambda j: (0, j)),       # post trace tile
        pl.BlockSpec((1, 1), lambda j: (0, 0)),        # stochastic-round seed
    ]
    operands = [x, w, scale, v, trace_post, seed]
    if plastic:
        in_specs += [
            pl.BlockSpec((4, n, bm), lambda j: (0, 0, j)),  # packed theta
            pl.BlockSpec((b, n), lambda j: (0, 0)),         # pre trace
        ]
        operands += [theta, trace_pre]
    if has_teach:
        in_specs.append(pl.BlockSpec((b, bm), lambda j: (0, j)))
        operands.append(teach)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((b, bm), lambda j: (0, j)),       # events (int32)
            pl.BlockSpec((b, bm), lambda j: (0, j)),       # v out (int32)
            pl.BlockSpec((b, bm), lambda j: (0, j)),       # post trace (int32)
            pl.BlockSpec((n, bm), lambda j: (0, j)),       # w out (int8)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), jnp.int32),
            jax.ShapeDtypeStruct((b, m), jnp.int32),
            jax.ShapeDtypeStruct((b, m), jnp.int32),
            jax.ShapeDtypeStruct((n, m), jnp.int8),
        ],
        interpret=interpret,
    )(*operands)


def _fleet_kernel_q(x_ref, w_ref, scale_ref, v_ref, tpost_ref, seed_ref,
                    *refs, qcfg, v_th, v_reset, w_clip, plastic, spiking,
                    has_teach, has_active, m_total, bm, telemetry):
    """Quantized fleet program: one request stream x one postsynaptic tile.

    The stream's int8 weight tile is promoted to int32 in registers (the
    (B, N, M) pool never leaves HBM as anything but int8); the per-SESSION
    seed drives the stochastic round with the same slot-independent flat
    index the oracle uses, so a session's update stream is invariant to
    which slot it occupies."""
    rest = list(refs)
    theta_ref = rest.pop(0) if plastic else None
    tpre_ref = rest.pop(0) if plastic else None
    teach_ref = rest.pop(0) if has_teach else None
    active_ref = rest.pop(0) if has_active else None
    tel_out = rest.pop() if telemetry else None
    s_out, v_out, tpost_out, w_out = rest
    gate = None if active_ref is None else active_ref[0, 0] > 0
    scale = scale_ref[0, 0]
    seed = seed_ref[0, 0]

    x = x_ref[...].astype(jnp.int32)            # (1, N) this stream's events
    w_i32 = w_ref[0].astype(jnp.int32)          # (N, bm) int8 -> registers
    tpost_new = _forward_engine_q(
        x, w_i32, scale, v_ref, tpost_ref, teach_ref, s_out, v_out,
        tpost_out, qcfg=qcfg, v_th=v_th, v_reset=v_reset, spiking=spiking,
        gate=gate)

    if plastic:
        tpre = tpre_ref[...].astype(jnp.int32)  # (1, N)
        hebb_i = tpre[0][:, None] * tpost_new[0][None, :]   # exact int outer
        dw = Q.dw_from_int_reductions(
            hebb_i, tpre[0], tpost_new[0],
            theta_ref[...].astype(jnp.float32), 1, qcfg)
        idx = _tile_flat_idx(tpre.shape[1], bm, pl.program_id(0), m_total)
        steps = Q.round_steps(dw / scale, seed, idx, qcfg)
        qmax = Q.qclip(w_clip, scale)
        w_new = jnp.clip(w_i32 + steps, -qmax, qmax)
        if gate is not None:
            w_new = jnp.where(gate, w_new, w_i32)   # dw gated: slot frozen
        w_out[0] = w_new.astype(w_out.dtype)
    else:
        w_new = w_i32
        w_out[0] = w_i32.astype(w_out.dtype)

    if telemetry:
        # Raw sums in the SAME units as the float datapath: 0/`one` events
        # divided back to event units, |dw| in int8 grid steps x scale.
        # Ragged-final-tile padding columns are masked out of every
        # reduction (their lanes hold pipeline garbage past M).
        col_ok = (jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1)
                  + pl.program_id(0) * bm) < m_total
        ev = s_out[...].astype(jnp.float32)         # already gated (zeros)
        vv = v_out[...].astype(jnp.int32)           # frozen old v if inactive
        spike_sum = jnp.sum(jnp.where(col_ok, jnp.abs(ev), 0.0)) \
            * (1.0 / qcfg.one)
        dsteps = jnp.abs(w_new - w_i32).astype(jnp.float32)
        dw_sum = jnp.sum(jnp.where(col_ok, dsteps, 0.0)) * scale
        sat_cnt = jnp.sum(jnp.where(
            col_ok & (jnp.abs(vv) >= sat_threshold_q(v_th, qcfg)),
            1.0, 0.0))
        g = jnp.float32(1.0) if gate is None else gate.astype(jnp.float32)
        tel_out[...] = (jnp.stack([spike_sum, dw_sum, sat_cnt])
                        * g).reshape(1, 1, 3)


def dual_engine_fleet_step_q_pallas(x, w, scale, theta, v, trace_pre,
                                    trace_post, *, qcfg, v_th: float = 1.0,
                                    v_reset: float = 0.0, w_clip: float = 4.0,
                                    plastic: bool = True, spiking: bool = True,
                                    teach=None, seed=None, active=None,
                                    telemetry: bool = False,
                                    block_m: int = 128,
                                    interpret: bool = False):
    """Quantized fleet pallas-call.  Shapes as ref.dual_engine_fleet_step_q:
    x (B,N) int32, w (B,N,M) int8 (stays int8 in HBM), scale (B,) f32 per
    slot, theta (4,N,M) f32 shared, v/traces (B,.) int32, seed (B,) int32
    per-session step counters, active (B,) slot mask.  ``telemetry``
    appends the raw (B, 3) float32 per-slot sums (obs.telemetry schema,
    float units) exactly like the float fleet wrapper."""
    b, n = x.shape
    b2, n2, m = w.shape
    assert (b, n) == (b2, n2), (x.shape, w.shape)
    if teach is not None and teach.ndim == 1:
        teach = jnp.broadcast_to(teach, (b, teach.shape[0]))
    if active is not None:
        active = active.reshape(b, 1).astype(jnp.float32)
    scale = jnp.asarray(scale, jnp.float32)
    if scale.ndim == 0:
        scale = jnp.broadcast_to(scale, (b,))      # one scale per slot
    scale = scale.reshape(b, 1)
    if seed is None:
        seed = jnp.zeros((b,), jnp.int32)
    seed = jnp.asarray(seed, jnp.int32)
    if seed.ndim == 0:
        seed = jnp.broadcast_to(seed, (b,))        # one seed per session
    seed = seed.reshape(b, 1)
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm), b)      # streams innermost: theta DMA elided
    has_teach = teach is not None
    has_active = active is not None

    kernel = functools.partial(
        _fleet_kernel_q, qcfg=qcfg, v_th=v_th, v_reset=v_reset,
        w_clip=w_clip, plastic=plastic, spiking=spiking,
        has_teach=has_teach, has_active=has_active, m_total=m, bm=bm,
        telemetry=telemetry)

    in_specs = [
        pl.BlockSpec((1, n), lambda j, i: (i, 0)),         # this stream's x
        pl.BlockSpec((1, n, bm), lambda j, i: (i, 0, j)),  # int8 w tile
        pl.BlockSpec((1, 1), lambda j, i: (i, 0)),         # per-slot scale
        pl.BlockSpec((1, bm), lambda j, i: (i, j)),        # v tile
        pl.BlockSpec((1, bm), lambda j, i: (i, j)),        # post trace tile
        pl.BlockSpec((1, 1), lambda j, i: (i, 0)),         # per-session seed
    ]
    operands = [x, w, scale, v, trace_post, seed]
    if plastic:
        in_specs += [
            pl.BlockSpec((4, n, bm), lambda j, i: (0, 0, j)),  # shared theta
            pl.BlockSpec((1, n), lambda j, i: (i, 0)),         # pre trace
        ]
        operands += [theta, trace_pre]
    if has_teach:
        in_specs.append(pl.BlockSpec((1, bm), lambda j, i: (i, j)))
        operands.append(teach)
    if has_active:
        in_specs.append(pl.BlockSpec((1, 1), lambda j, i: (i, 0)))
        operands.append(active)

    out_specs = [
        pl.BlockSpec((1, bm), lambda j, i: (i, j)),        # events
        pl.BlockSpec((1, bm), lambda j, i: (i, j)),        # v out
        pl.BlockSpec((1, bm), lambda j, i: (i, j)),        # post trace
        pl.BlockSpec((1, n, bm), lambda j, i: (i, 0, j)),  # w out (int8)
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, m), jnp.int32),
        jax.ShapeDtypeStruct((b, m), jnp.int32),
        jax.ShapeDtypeStruct((b, m), jnp.int32),
        jax.ShapeDtypeStruct((b, n, m), jnp.int8),
    ]
    if telemetry:
        out_specs.append(pl.BlockSpec((1, 1, 3), lambda j, i: (i, j, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((b, pl.cdiv(m, bm), 3), jnp.float32))

    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    if not telemetry:
        return res
    return tuple(res[:4]) + (res[4].sum(axis=1),)
