"""Observability: in-band fleet telemetry, host metrics, recompile watchdog.

Three layers, matching how FireFly-P itself is measured (the paper's 8 us /
0.713 W headline numbers come from instrumenting the RUNNING accelerator,
not from offline benchmarks):

  * `obs.telemetry`  — DEVICE-side per-slot fleet telemetry (spike rate,
    mean |dw|, membrane saturation, occupancy) computed INSIDE the fused
    dual-engine programs as extra reduced outputs.  Telemetry is a static
    trace variant (a `telemetry=` flag on `engine.layer_step` /
    `engine.rollout` and the schedulers), never a runtime branch: the
    telemetry-off program is byte-identical to the uninstrumented one and
    telemetry-on adds exactly one stable executable per entry point.
  * `obs.metrics`    — HOST-side counters/gauges/histograms with
    Prometheus-text + JSON snapshot exporters; the serving stack
    (SessionStore, SessionPool, launch/serve.py, scenarios/harness) records
    admit/evict/checkout latencies, warm-cache hit rate, occupancy, and
    tokens/s into per-component registries.
  * `obs.watchdog`   — the RECOMPILE WATCHDOG: a `jax.monitoring` compile
    listener that turns the benchmarks' "zero recompiles after warmup"
    assertion into a runtime monitor (warn + counter + offending program
    name on any unexpected cache miss while armed).

On top of the point-in-time layers, the SESSION-HEALTH subsystem adds
history and action (the detect-and-recover loop):

  * `obs.recorder`   — the device-side FLIGHT RECORDER: a fixed-shape
    ``(B, W, C)`` ring of per-slot telemetry channels updated inside the
    jitted pool-step/decode programs (a `record=` trace variant exactly
    like `telemetry=`; off-path bitwise identity pinned), plus the
    incident dump exporter (`serve.py --flight-dir`).
  * `obs.health`     — streaming anomaly detectors over the channels
    (EWMA z-score, absolute bound, stuck-at, dead-session) folded into
    the same launch, with per-detector hysteresis and latched flags; the
    schedulers' `remediate()` turns the verdict into quarantine →
    `SessionStore` rollback → re-admit.

`benchmarks/obs_overhead.py` gates the cost: telemetry-on fleet stepping
within 5% of telemetry-off at B=256, exactly one extra program per used
entry point, watchdog silent under churn.  `benchmarks/obs_health.py`
gates the health loop: recorder-on within 5% at B=256, injected anomalies
detected per detector, zero false positives on clean churn.
"""
from repro.obs.health import (CHANNELS, DETECTORS, HealthConfig, HealthState,
                              health_update, init_health)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               REGISTRY, phase, serve_metrics)
from repro.obs.recorder import (AdapterFlightRecorder, RecorderState,
                                adapter_weight_norm, dump_incident,
                                init_recorder, network_weight_norm,
                                recorder_update, reset_slot, unroll_ring)
from repro.obs.telemetry import (SAT_FRACTION, FleetTelemetry,
                                 adapter_telemetry, record_fleet_telemetry)
from repro.obs.watchdog import RecompileWatchdog, watchdog

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY", "phase",
    "serve_metrics",
    "SAT_FRACTION", "FleetTelemetry", "adapter_telemetry",
    "record_fleet_telemetry", "RecompileWatchdog", "watchdog",
    "CHANNELS", "DETECTORS", "HealthConfig", "HealthState", "health_update",
    "init_health",
    "AdapterFlightRecorder", "RecorderState", "adapter_weight_norm",
    "dump_incident", "init_recorder", "network_weight_norm",
    "recorder_update", "reset_slot", "unroll_ring",
]
