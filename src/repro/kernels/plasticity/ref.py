"""Pure-jnp oracle for the fused dual-engine step (forward + plasticity).

Semantics of one SNN timestep for one synaptic layer, matching
core/snn.timestep for a spiking layer:

    I        = x @ w                       # psum stage (Forward Engine)
    v_new    = v + (I - v) / tau_m         # neuron dynamics, tau_m = 2
    s        = v_new >= v_th               # spike
    v_out    = v_reset where s else v_new
    tp_new   = lam * trace_post + s        # trace update
    hebb     = trace_pre^T @ tp_new / B    # Plasticity Engine (4 terms)
    dw       = a*hebb + b*mean(pre)[:,N] + g*mean(tp_new)[N,:] + d
    w_new    = clip(w + dw, -clip, clip)

`trace_pre` is the *already-updated* presynaptic trace for this timestep
(the Forward Engine's Trace Update Unit runs upstream of this layer).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.plasticity import ALPHA, BETA, GAMMA, DELTA


def dual_engine_step(x, w, theta, v, trace_pre, trace_post, *,
                     tau_m: float = 2.0, v_th: float = 1.0,
                     v_reset: float = 0.0, trace_decay: float = 0.8,
                     w_clip: float = 4.0, plastic: bool = True):
    """Oracle.  Shapes: x (B,N), w (N,M), theta (4,N,M), v (B,M),
    trace_pre (B,N), trace_post (B,M).

    Returns (spikes (B,M), v_out (B,M), trace_post_new (B,M), w_new (N,M)).
    """
    compute = jnp.float32
    b = x.shape[0]
    current = jnp.dot(x.astype(compute), w.astype(compute))
    v_new = v.astype(compute) + (current - v.astype(compute)) / tau_m
    spikes = (v_new >= v_th).astype(compute)
    v_out = jnp.where(spikes > 0, v_reset, v_new)
    tp_new = trace_decay * trace_post.astype(compute) + spikes

    if plastic:
        th = theta.astype(compute)
        hebb = jnp.dot(trace_pre.astype(compute).T, tp_new) / b
        pre_m = trace_pre.astype(compute).mean(0)
        post_m = tp_new.mean(0)
        dw = (th[ALPHA] * hebb + th[BETA] * pre_m[:, None]
              + th[GAMMA] * post_m[None, :] + th[DELTA])
        w_new = jnp.clip(w.astype(compute) + dw, -w_clip, w_clip)
    else:
        w_new = w.astype(compute)

    return (spikes.astype(x.dtype), v_out.astype(v.dtype),
            tp_new.astype(trace_post.dtype), w_new.astype(w.dtype))
