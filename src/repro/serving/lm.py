"""LM decode pool: continuous batching of plastic language-model streams.

The LM counterpart of `scheduler.FleetScheduler`: a fixed pool of B decode
slots whose session pytree is the WHOLE per-stream decode state —

  * the backbone cache (KV planes / Mamba2 SSM + conv states / zsuper's
    stacked hybrid caches, any `models.factory` layout),
  * a per-slot sequence index (streams admitted at different times sit at
    different lengths),
  * the FireFly-P plastic adapter state: ``W_fast (N, N)`` float32 or int8
    (``cfg.adapter_quant``) with its per-session scale and step counter,
  * the pending next token.

Everything rides the generic `SessionPool` machinery: admission is ONE
traced-slot scatter of a freshly-prefilled (or store-restored) session,
eviction is one gather + write-through `SessionStore` persist, and the pool
decodes as ONE jitted program over all B slots per token (`step`) or per
K-token window (`decode_window` — the windowed path routes the adapter
through `plastic.decode_rollout`, so K plasticity steps for every resident
stream are a single time-fused engine launch).  Occupancy is a runtime
``active (B,)`` operand: churn never retraces, vacant slots are bit-exact
no-ops (the MoE dispatch sentinels their garbage tokens out of expert
capacity, the adapter freezes its synapses, the cache index holds).

`benchmarks/serving_lm.py` pins the contracts: zero recompiles under
churn, and evict -> persist -> re-admit bit-identity mid-generation, per
layout x backend x datapath cell.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import factory, plastic
from repro.models.config import ModelConfig
from repro.models.layers import init_from_plan
from repro.obs import MetricsRegistry, phase
from repro.obs import recorder as _recorder
from repro.obs.health import HealthConfig
from repro.obs.telemetry import (FleetTelemetry, adapter_telemetry,
                                 record_fleet_telemetry)
from repro.serving.scheduler import SessionPool, uniform_axes
from repro.serving.sessions import SessionStore


class LMScheduler(SessionPool):
    """Admit/evict LM user streams into a fixed pool of decode slots.

    Args:
      model:   a `factory.Model` (or anything `factory.build` accepts — a
               ModelConfig or an arch id).  ``cfg.adapter_impl`` picks the
               plastic engine backend for the whole pool;
               ``cfg.adapter_quant`` makes the adapter rows an int8 pool.
      params:  model parameters (shared by every stream — the model is the
               deployment, the session is the user).
      slots:   pool size B; fixes every pool tensor shape forever.
      max_len: cache length ceiling shared by all slots.
      store:   `SessionStore` backing eviction/restore.
      mesh:    optional device mesh (see `SessionPool`): the decode pool —
               KV/SSM planes, adapter rows, sequence indices — shards over
               its slot axes and the decode launches run as sharding-
               constrained jit (GSPMD), NOT shard_map: the MoE capacity/
               cumsum stages reduce ACROSS slots, and GSPMD partitions them
               without changing their semantics, where a manual per-shard
               lowering would.  Token streams are device-count invariant
               (tests/test_distributed.py pins the parity).
    """

    def __init__(self, model, params, slots: int, max_len: int,
                 store: Optional[SessionStore] = None,
                 registry: Optional[MetricsRegistry] = None,
                 mesh=None, health: Optional[HealthConfig] = None):
        if not isinstance(model, factory.Model):
            model = factory.build(model)
        if model.cfg.input_mode != "tokens":
            raise ValueError(
                f"{model.cfg.name}: LMScheduler pools token streams; "
                f"input_mode {model.cfg.input_mode!r} is not poolable")
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.max_len = int(max_len)
        pool = {"cache": model.pool_cache(slots, max_len),
                "tok": jnp.zeros((slots,), jnp.int32)}
        axes = {"cache": model.cache_axes(max_len), "tok": 0}
        super().__init__(pool, axes, slots, store, registry, mesh=mesh,
                         health=health)

        # pin the decode outputs' pool layout (GSPMD would otherwise be
        # free to re-layout the updated cache away from the slot sharding)
        shardings = self._shardings

        def _constrain(new_pool):
            if shardings is None:
                return new_pool
            return jax.tree.map(jax.lax.with_sharding_constraint,
                                new_pool, shardings)

        def _prefill_session(params, prompt):
            # B=1 prompt -> one session row + its first greedy token
            logits, cache = model.prefill(params, prompt[None, :], max_len)
            return {"cache": model.session_from_prefill(cache),
                    "tok": jnp.argmax(logits[0], -1).astype(jnp.int32)}

        def _pool_step(params, pool, active):
            # one greedy decode token for the WHOLE pool; vacant slots are
            # no-ops end to end (cache index held, adapter frozen, pending
            # token carried through)
            logits, cache = model.decode_step(
                params, pool["cache"], pool["tok"][:, None], active=active)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return (_constrain({"cache": cache,
                                "tok": jnp.where(active, nxt, pool["tok"])}),
                    nxt)

        def _pool_window(params, pool, tokens, active):
            # K teacher-forced tokens for the whole pool in ONE launch: the
            # backbone scans token-by-token, the adapter runs K plasticity
            # steps as a single time-fused plastic.decode_rollout
            logits, cache = model.decode_rollout(
                params, pool["cache"], tokens, active=active)
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            return (_constrain({"cache": cache,
                                "tok": jnp.where(active, nxt, pool["tok"])}),
                    logits)

        qcfg = plastic.QUANT if self.cfg.adapter_quant else None

        def _pool_step_tel(params, pool, active):
            # telemetry trace VARIANT: the adapter's decode step is buried
            # inside the backbone's jitted program, so the per-slot health
            # vector is recovered as a pure function of the adapter cache
            # before/after — traced into the SAME launch, no extra pass
            before = pool["cache"]["adapter"]
            new_pool, nxt = _pool_step(params, pool, active)
            tel = adapter_telemetry(before, new_pool["cache"]["adapter"],
                                    active, qcfg=qcfg)
            return new_pool, nxt, tel

        def _pool_window_tel(params, pool, tokens, active):
            before = pool["cache"]["adapter"]
            new_pool, logits = _pool_window(params, pool, tokens, active)
            # window-mean telemetry: the K-step cache delta normalized by
            # the window length (net weight motion, recovered event mass)
            tel = adapter_telemetry(before, new_pool["cache"]["adapter"],
                                    active, qcfg=qcfg)
            k = tokens.shape[1]
            tel = FleetTelemetry(
                spike_rate=tel.spike_rate / k,
                mean_abs_dw=tel.mean_abs_dw / k,
                sat_frac=tel.sat_frac, occupancy=tel.occupancy)
            return new_pool, logits, tel

        hcfg = health
        adapter_quant = bool(self.cfg.adapter_quant)

        def _record(tel, adapter, rec, pos, active):
            # record trace VARIANTS: telemetry channels + adapter weight
            # norm -> flight-recorder ring + streaming detectors, fused
            # into the decode launch (no host sync; the verdict latches
            # on device until flagged_sessions/remediate reads it)
            wnorm = _recorder.adapter_weight_norm(adapter, adapter_quant)
            ch = jnp.stack([tel.spike_rate, tel.mean_abs_dw, tel.sat_frac,
                            wnorm], axis=-1)
            return _recorder.recorder_update(hcfg, rec, ch, pos, active)

        def _pool_step_rec(params, pool, active, rec, pos):
            new_pool, nxt, tel = _pool_step_tel(params, pool, active)
            rec2, verdict = _record(tel, new_pool["cache"]["adapter"],
                                    rec, pos, active)
            return new_pool, nxt, tel, rec2, verdict

        def _pool_window_rec(params, pool, tokens, active, rec, pos):
            new_pool, logits, tel = _pool_window_tel(params, pool, tokens,
                                                     active)
            rec2, verdict = _record(tel, new_pool["cache"]["adapter"],
                                    rec, pos, active)
            return new_pool, logits, tel, rec2, verdict

        # Fixed shapes => one executable per op (per window length for the
        # windowed path); `compiled_programs()` names the per-entry-point
        # totals the churn benchmark and compile audit pin.  Telemetry
        # variants register up-front (untraced => 0 executables) so a
        # telemetry-off run audits them without compiling anything.
        self._prefill = jax.jit(_prefill_session)
        self._step_fn = jax.jit(_pool_step)
        self._window_fn = jax.jit(_pool_window)
        self._step_tel_fn = jax.jit(_pool_step_tel)
        self._window_tel_fn = jax.jit(_pool_window_tel)
        self._step_rec_fn = jax.jit(_pool_step_rec)
        self._window_rec_fn = jax.jit(_pool_window_rec)
        self._jitted.update({
            "prefill": self._prefill,
            "decode_step": self._step_fn,
            "decode_window": self._window_fn,
            "decode_step_telemetry": self._step_tel_fn,
            "decode_window_telemetry": self._window_tel_fn,
            "decode_step_record": self._step_rec_fn,
            "decode_window_record": self._window_rec_fn,
        })

    # ---- session construction --------------------------------------------

    def _session_factory(self):
        # slot 0 of the INITIAL pool, not zeros_like of it: quantized
        # adapter rows carry a non-zero fresh ``w_scale``
        return self._zero_session

    def admit_prompt(self, uid: str, prompt, evict_lru: bool = False) -> int:
        """Prefill `prompt` ((S,) int32) into a fresh session and admit it.

        For a uid the `SessionStore` already knows, the persisted session
        (its cache, adapter memory, and pending token) is restored instead
        and the prompt is ignored — resumption, not re-prefill.  Returns
        the slot index; the stream's first greedy token is `pending(uid)`.
        """
        prompt = jnp.asarray(prompt, jnp.int32)
        if prompt.ndim != 1:
            raise ValueError(f"prompt must be (S,), got {prompt.shape}")
        return self.admit(
            uid, evict_lru=evict_lru,
            factory=lambda: self._prefill(self.params, prompt))

    # ---- inspection -------------------------------------------------------

    def pending(self, uid: str) -> int:
        """The stream's next token (greedy argmax of its last logits)."""
        return int(self.pool["tok"][self.user_slot[uid]])

    def session_view(self, uid: str):
        """Gather `uid`'s session pytree WITHOUT evicting (probe/tests)."""
        return self._take(self.pool, jnp.int32(self.user_slot[uid]))

    # ---- stepping ---------------------------------------------------------

    def _require_adapter(self) -> None:
        if not self.cfg.plastic_adapter:
            raise ValueError(
                f"{self.cfg.name}: telemetry reads the plastic adapter "
                "cache; this model has cfg.plastic_adapter=False")

    def step(self, telemetry: bool = False, record: bool = False):
        """One greedy decode token for every admitted stream (one launch).

        Each stream consumes its pending token and produces the next;
        returns uid -> newly generated token (which is also the new
        pending token).

        ``telemetry=True`` (plastic-adapter models only) dispatches the
        telemetry trace variant — the adapter's per-slot health vector is
        recovered from its cache delta inside the same launch — and
        returns ``(tokens, FleetTelemetry)``, recording summary gauges
        into ``self.metrics`` under the ``adapter_`` prefix.

        ``record=True`` (plastic-adapter models with
        ``health=HealthConfig(...)``) dispatches the record trace variant:
        the same channels plus the adapter weight norm feed the flight
        recorder and the streaming detectors inside the decode launch — no
        host sync; combine with ``telemetry=True`` for the tuple return.
        """
        if record:
            self._require_adapter()
            rec = self._ensure_recorder()
            with phase("lm.decode_step"):
                self.pool, nxt, tel, self._rec, self.last_verdict = \
                    self._step_rec_fn(self.params, self.pool,
                                      self._active_mask(), rec,
                                      jnp.int32(self._rec_pos))
            self._rec_pos += 1
        elif telemetry:
            self._require_adapter()
            with phase("lm.decode_step"):
                self.pool, nxt, tel = self._step_tel_fn(
                    self.params, self.pool, self._active_mask())
        else:
            with phase("lm.decode_step"):
                self.pool, nxt = self._step_fn(self.params, self.pool,
                                               self._active_mask())
        self.advance_steps(1)
        nxt = np.asarray(nxt)
        toks = {uid: int(nxt[slot]) for uid, slot in self.user_slot.items()}
        if not telemetry:
            return toks
        record_fleet_telemetry(self.metrics, tel, prefix="adapter")
        return toks, tel

    def decode_window(self, windows: Mapping[str, jax.Array],
                      telemetry: bool = False, record: bool = False):
        """K teacher-forced tokens per stream, ONE fused launch per window.

        `windows` maps uid -> ``(K,)`` int32 (same K for every stream —
        one executable per window length), covering exactly the admitted
        sessions; ``windows[uid][0]`` is typically the stream's pending
        token (then draft/forced continuations).  Equivalent to K `step`
        calls on those tokens — same cache writes, same K adapter
        plasticity steps (run as one `plastic.decode_rollout` launch), same
        stochastic-round stream in quant mode — and bit-identical to them
        (`tests/test_serving_lm.py` pins it).  Returns uid -> ``(K, V)``
        logits; the new pending token is the last position's argmax.

        ``telemetry=True`` (plastic-adapter models only) returns
        ``(logits, FleetTelemetry)`` with window-normalized adapter health
        (net weight motion / recovered event mass over the K steps),
        recording ``adapter_*`` gauges into ``self.metrics``.

        ``record=True`` (with ``health=HealthConfig(...)``) records the
        window's normalized channels as ONE flight-recorder observation
        and one detector update inside the same launch.
        """
        missing = [u for u in self.user_slot if u not in windows]
        extra = [u for u in windows if u not in self.user_slot]
        if missing or extra:
            raise ValueError(
                f"windows must cover exactly the admitted sessions; "
                f"missing {missing}, not admitted {extra}")
        ks = {int(np.asarray(w).shape[0]) for w in windows.values()}
        if len(ks) > 1:
            raise ValueError(f"all windows must share one length, got {ks}")
        k = ks.pop() if ks else 1
        tokens = np.zeros((self.slots, k), np.int32)
        for uid, w in windows.items():
            tokens[self.user_slot[uid]] = np.asarray(w, np.int32)
        if record:
            self._require_adapter()
            rec = self._ensure_recorder()
            with phase("lm.decode_window"):
                self.pool, logits, tel, self._rec, self.last_verdict = \
                    self._window_rec_fn(self.params, self.pool,
                                        jnp.asarray(tokens),
                                        self._active_mask(), rec,
                                        jnp.int32(self._rec_pos))
            self._rec_pos += 1
        elif telemetry:
            self._require_adapter()
            with phase("lm.decode_window"):
                self.pool, logits, tel = self._window_tel_fn(
                    self.params, self.pool, jnp.asarray(tokens),
                    self._active_mask())
        else:
            with phase("lm.decode_window"):
                self.pool, logits = self._window_fn(
                    self.params, self.pool, jnp.asarray(tokens),
                    self._active_mask())
        self.advance_steps(k)
        out = {uid: logits[slot] for uid, slot in self.user_slot.items()}
        if not telemetry:
            return out
        record_fleet_telemetry(self.metrics, tel, prefix="adapter")
        return out, tel


class AdapterPool(SessionPool):
    """Adapter-state-only pool: the batch rows of `launch/serve.py`.

    The classic batched-serving driver decodes a fixed batch in lockstep
    (one shared scalar cache index), so only the plastic adapter rows —
    each user's learned ``W_fast`` + membranes/traces/step counter (+ scale
    when ``cfg.adapter_quant``) — are session state.  This pool IS the
    ``cache["adapter"]`` pytree: admit users before `generate`, install
    `pool.pool` as the cache's adapter entry, and evict afterwards to
    persist what each stream learned.
    """

    def __init__(self, cfg: ModelConfig, slots: int,
                 store: Optional[SessionStore] = None,
                 registry: Optional[MetricsRegistry] = None,
                 mesh=None, health: Optional[HealthConfig] = None):
        if not cfg.plastic_adapter:
            raise ValueError(f"{cfg.name}: AdapterPool needs "
                             "cfg.plastic_adapter=True")
        self.cfg = cfg
        pool = init_from_plan(plastic.plan_cache(cfg, slots),
                              jax.random.PRNGKey(0))
        super().__init__(pool, uniform_axes(pool), slots, store, registry,
                         mesh=mesh, health=health)

    def _session_factory(self):
        # fresh sessions keep plan inits (quant rows: non-zero w_scale)
        return self._zero_session
