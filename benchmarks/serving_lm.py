"""Plastic LM serving under churn: tokens/s through the LMScheduler pool.

Sweeps layout (dense GQA / Mamba2 SSM / MoE) x engine backend (xla /
pallas-interpret) x adapter datapath (float32 / int8) and, per cell, drives
Poisson admissions + geometric departures against a fixed `LMScheduler`
slot pool while every resident stream decodes greedily AND learns online
(its own W_fast rewritten by the fused plastic engine every token).  The
windowed path is exercised in the same loop: periodically each stream
advances K teacher-forced tokens through `decode_window` (the backbone
scans, the adapter runs K plasticity steps as ONE `plastic.decode_rollout`
launch).

Per cell, measured AND asserted:

  * tokens/s under churn (sequential) and through the windowed path,
  * recompiles after warm-up — PINNED AT ZERO: pool shapes are fixed, slot
    indices traced, occupancy a runtime ``active`` mask; admissions,
    evictions, and mixed occupancy never retrace anything,
  * evict -> persist -> re-admit bit-identity MID-GENERATION: a probe
    stream's greedy tokens and final session pytree (backbone cache,
    adapter W_fast/traces, step counter, pending token) are bit-equal
    whether or not the stream was evicted at token 3, displaced by a rival,
    and re-admitted into a DIFFERENT slot — probed inside the SAME
    scheduler instance, so it also proves zero probe-induced recompiles,
  * vacant-slot freeze: an evicted slot's entire session row is
    bit-unchanged after further pool steps.

The MoE cells pin the capacity no-op contract: expert capacity is raised
so no token ever drops, making cross-row capacity coupling inert — the one
place a neighbour could legitimately alter an active stream's output.

    PYTHONPATH=src python benchmarks/serving_lm.py [--smoke] [--impl ...]

Writes benchmarks/results/serving_lm.json (or _smoke.json under --smoke so
CI never clobbers the checked-in artifact; the run.py drift gate requires
the smoke sweep to keep covering every layout/impl/datapath cell of the
checked-in one).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.models import factory
from repro.serving import LMScheduler, SessionStore

RESULTS = os.path.join(os.path.dirname(__file__), "results")

LAYOUT_ARCH = {"dense": "qwen3-4b", "ssm": "mamba2-1.3b",
               "moe": "deepseek-moe-16b"}


def build_model(layout: str, impl: str, datapath: str, neurons: int):
    cfg = factory.build(LAYOUT_ARCH[layout], smoke=True).cfg
    if cfg.moe is not None:
        # capacity >= every token any full pool can route: drops become
        # impossible, so the only cross-row interaction in the decode path
        # (capacity coupling) is inert and bit-identity is well-defined
        cfg = cfg.with_(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    cfg = cfg.with_(plastic_adapter=True, adapter_neurons=neurons,
                    adapter_impl=impl, adapter_quant=(datapath == "int8"))
    model = factory.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params["adapter"]["scale"] = jax.numpy.float32(0.5)
    return model, params


def prompt_for(uid: str, length: int, vocab: int) -> np.ndarray:
    rng = np.random.RandomState(abs(hash(uid)) % (2 ** 31))
    return rng.randint(0, vocab, size=length).astype(np.int32)


def _probe_trajectory(sched, uid, prompt, n_tokens, interrupt_at=None,
                      rival_prompt=None):
    """Greedy-decode `uid` for `n_tokens` inside the CURRENT scheduler;
    optionally evict mid-generation, let a rival displace the slot, and
    re-admit (store restore) into a DIFFERENT slot."""
    sched.admit_prompt(uid, prompt)
    toks = [sched.pending(uid)]
    for t in range(n_tokens):
        if interrupt_at is not None and t == interrupt_at:
            sched.evict(uid)                     # persist mid-generation
            sched.store._warm.pop(uid, None)     # force the archive path
            sched.admit_prompt("rival", rival_prompt)  # takes the old slot
            sched.step()
            slot = sched.admit_prompt(uid, prompt)     # restored, NEW slot
            assert sched.user_slot["rival"] != slot
            sched.evict("rival")
        toks.append(sched.step()[uid])
    sess = jax.tree.map(np.asarray, sched.session_view(uid))
    sched.evict(uid)
    return toks, sess


def bench_cell(layout: str, impl: str, datapath: str, *, slots: int,
               steps: int, window: int, prompt_len: int, neurons: int,
               arrival: float = 0.4, depart: float = 0.1,
               seed: int = 0) -> dict:
    model, params = build_model(layout, impl, datapath, neurons)
    vocab = model.cfg.vocab
    max_len = prompt_len + steps + 4 * window + 8
    sched = LMScheduler(model, params, slots=slots, max_len=max_len,
                        store=SessionStore())

    # ---- warm-up: touch every jitted program once ------------------------
    sched.admit_prompt("warm", prompt_for("warm", prompt_len, vocab))
    sched.step()
    sched.decode_window({"warm": np.full((window,), sched.pending("warm"),
                                         np.int32)})
    sched.evict("warm")
    sched.admit_prompt("warm", prompt_for("warm", prompt_len, vocab))
    sched.step()
    sched.evict("warm")
    warm_compiles = sched.compile_count()

    # ---- churn loop ------------------------------------------------------
    rng = np.random.default_rng(seed)
    user_pool = [f"u{i:02d}" for i in range(3 * slots)]
    next_uid = 0
    seq_tokens = win_tokens = 0
    seq_wall = win_wall = 0.0
    for t in range(steps):
        for _ in range(int(rng.poisson(arrival))):
            uid = user_pool[next_uid % len(user_pool)]
            next_uid += 1
            if uid in sched.user_slot:
                continue
            sched.admit_prompt(uid, prompt_for(uid, prompt_len, vocab),
                               evict_lru=True)
        for uid in list(sched.active_users):
            if rng.random() < depart:
                sched.evict(uid)
        occ = len(sched.user_slot)
        if occ == 0:
            continue
        if window > 1 and t % 4 == 3:
            # windowed path: each stream advances `window` teacher-forced
            # tokens (its pending token + forced continuations) in ONE
            # fused launch
            wins = {u: np.concatenate(
                [[sched.pending(u)],
                 rng.integers(0, vocab, window - 1)]).astype(np.int32)
                for u in sched.active_users}
            t0 = time.perf_counter()
            out = sched.decode_window(wins)
            jax.tree.leaves(out)[0].block_until_ready()
            win_wall += time.perf_counter() - t0
            win_tokens += occ * window
        else:
            t0 = time.perf_counter()
            out = sched.step()
            seq_wall += time.perf_counter() - t0
            seq_tokens += occ

    recompiles = sched.compile_count() - warm_compiles
    assert recompiles == 0, (
        f"{layout}/{impl}/{datapath}: churn caused {recompiles} recompiles "
        "— the fixed-shape contract is broken")

    # ---- vacant-slot freeze ---------------------------------------------
    for uid in list(sched.active_users):
        sched.evict(uid)
    sched.admit_prompt("holder", prompt_for("holder", prompt_len, vocab))
    vacant = sched.slot_user.index(None)
    import jax.numpy as jnp
    before = jax.tree.map(np.asarray,
                          sched._take(sched.pool, jnp.int32(vacant)))
    for _ in range(5):
        sched.step()
    after = jax.tree.map(np.asarray,
                         sched._take(sched.pool, jnp.int32(vacant)))
    idle_frozen = all(np.array_equal(a, b) for a, b in
                      zip(jax.tree.leaves(before), jax.tree.leaves(after)))
    assert idle_frozen, (f"{layout}/{impl}/{datapath}: vacant slot drifted "
                         "— the active-mask no-op contract is broken")
    sched.evict("holder")

    # ---- evict -> persist -> re-admit bit-identity mid-generation --------
    probe_prompt = prompt_for("probe", prompt_len, vocab)
    rival_prompt = prompt_for("rival", prompt_len, vocab)
    ref_toks, ref_sess = _probe_trajectory(sched, "probe_ref", probe_prompt,
                                           8)
    int_toks, int_sess = _probe_trajectory(sched, "probe_int", probe_prompt,
                                           8, interrupt_at=3,
                                           rival_prompt=rival_prompt)
    bit_identical = ref_toks == int_toks and all(
        np.array_equal(a, b) for a, b in
        zip(jax.tree.leaves(ref_sess), jax.tree.leaves(int_sess)))
    assert bit_identical, (
        f"{layout}/{impl}/{datapath}: evict -> persist -> re-admit diverged "
        f"mid-generation ({ref_toks} vs {int_toks})")
    probe_recompiles = sched.compile_count() - warm_compiles
    assert probe_recompiles == 0, (
        f"{layout}/{impl}/{datapath}: the probe retraced "
        f"{probe_recompiles} programs")

    return {
        "layout": layout, "arch": model.cfg.name, "impl": impl,
        "datapath": datapath, "slots": slots, "steps": steps,
        "window": window, "adapter_neurons": neurons,
        "tokens_per_s": seq_tokens / seq_wall if seq_wall else 0.0,
        "window_tokens_per_s": win_tokens / win_wall if win_wall else 0.0,
        "seq_tokens": seq_tokens, "window_tokens": win_tokens,
        "evictions": sched.evictions,
        "pool_mbytes": sched.pool_nbytes() / 1e6,
        "compiled_programs": warm_compiles,
        "recompiles_after_warmup": int(recompiles),
        "idle_slot_frozen": bool(idle_frozen),
        "evict_readmit_bit_identical": bool(bit_identical),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny cells for CI (seconds per cell)")
    ap.add_argument("--impl", default=None,
                    choices=["xla", "pallas", "pallas-interpret"],
                    help="restrict to one backend (default: xla and "
                         "pallas-interpret)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        name = "serving_lm_smoke.json" if args.smoke else "serving_lm.json"
        args.out = os.path.join(RESULTS, name)

    impls = [args.impl] if args.impl else ["xla", "pallas-interpret"]
    layouts = ["dense", "ssm", "moe"]
    datapaths = ["float32", "int8"]
    knobs = (dict(slots=3, steps=12, window=3, prompt_len=4, neurons=8)
             if args.smoke else
             dict(slots=8, steps=48, window=4, prompt_len=8, neurons=32))

    sweep = []
    print("layout,impl,datapath,tokens_per_s,window_tokens_per_s,"
          "recompiles,bit_identical")
    for layout in layouts:
        for impl in impls:
            for dp in datapaths:
                row = bench_cell(layout, impl, dp, **knobs)
                sweep.append(row)
                print(f"{layout},{impl},{dp},{row['tokens_per_s']:.1f},"
                      f"{row['window_tokens_per_s']:.1f},"
                      f"{row['recompiles_after_warmup']},"
                      f"{row['evict_readmit_bit_identical']}")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"impls": impls, "layouts": layouts,
                   "datapaths": datapaths, "smoke": bool(args.smoke),
                   "sweep": sweep}, f, indent=1)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
