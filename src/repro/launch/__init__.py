# launch layer: mesh construction, input specs, step builders, dry-run CLI,
# end-to-end train/serve drivers.  Import nothing heavy at package level so
# `import repro.launch.dryrun` can set XLA_FLAGS before jax initializes.
