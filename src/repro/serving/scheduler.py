"""Session-pytree slot pools: continuous batching into fixed-shape tensors.

The fleet tensor (PR 2) gives B per-request weight sets one fused launch per
layer; this module decides WHICH users occupy those B slots over time.  A
pool is ANY pytree of fixed-shape arrays in which each leaf either carries a
slot axis (one row per resident session) or is shared pool state (a clock).
Slots are never added or removed, so every jitted program (the pool step and
the gather/scatter swaps) compiles exactly once per shape and the compile
count is pinned (`compile_count()`; asserted by benchmarks/serving_churn.py
and benchmarks/serving_lm.py).

Two pools ride the same machinery:

  * `FleetScheduler` — the SNN controller fleet: a `NetworkState` of shape
    ``(B, N, M)`` stepped through the `engine.layer_step`/`engine.rollout`
    fleet path.
  * `serving.lm.LMScheduler` — the LM decode pool: KV/SSM caches
    ``(L, B, S, ...)``, per-slot sequence indices ``(B,)``, and the plastic
    adapter's ``W_fast (B, N, N)`` (float32 or int8), all one session
    pytree.

Mechanics per scheduling event (`SessionPool`):

  * ``admit(uid)``  — `SessionStore.checkout` (warm hit / durable restore /
    fresh state), then swap-in: one jitted per-leaf scatter along each
    leaf's slot axis, with the slot index TRACED so any slot reuses the
    same executable.
  * ``evict(uid)``  — swap-out (jitted per-leaf gather), a subclass
    finalize hook (e.g. stamping the session's step counter), and
    `SessionStore.checkin` (write-through persist); the vacated slot is
    scatter-cleared to zeros for hygiene.
  * stepping        — subclass-owned: ONE fused program over all B slots
    with the ``active (B,)`` mask gating vacant slots into true no-ops
    (state frozen bit-exactly, outputs zero/ignored).  Occupancy changes
    never retrace: the mask is a runtime operand, not a shape.

Because slot rows are mutually independent and the active mask freezes
state bit-exactly, a session's trajectory is invariant to WHICH slot it
occupies, to its neighbours, and to evict -> persist -> re-admit
round-trips — the bit-identity contract `tests/test_serving.py` and
`tests/test_serving_lm.py` pin on the xla and pallas-interpret backends.

SESSION HEALTH (opt-in via ``health=HealthConfig(...)``): pools carry a
device-side flight recorder + streaming detectors (`obs.recorder` /
`obs.health`) as a third static trace variant (``record=``, exactly like
``telemetry=``), and the base class turns the latched verdict into action:
`flagged_sessions` → `quarantine` (the slot joins the same runtime-mask
freeze vacant and lost slots use) → `rollback` (re-admit from the last
healthy `SessionStore` checkpoint — `health_checkpoint` rides the
`persist_resident` path) → bit-identical continuation.  `remediate()` runs
the whole loop, optionally dumping a flight-recorder incident bundle per
casualty first.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, snn
from repro.core.engine import NetworkState
from repro.obs import MetricsRegistry, phase
from repro.obs import recorder as _recorder
from repro.obs.health import HealthConfig
from repro.obs.watchdog import watchdog as _compile_watchdog
from repro.obs.telemetry import FleetTelemetry, record_fleet_telemetry
from repro.serving.sessions import SessionStore

# Axis sentinel: a pool leaf marked SHARED has no slot rows — it is pool-
# global state (e.g. the fleet clock `NetworkState.t`).  Swap-in carries it
# through untouched; swap-out returns zeros (the scheduler stamps the
# session's true host-side value in `_finalize_session`).
SHARED = "shared"


# ---- generic slot gather/scatter (any pytree of leading-slot-rank leaves) --

@functools.partial(jax.jit, donate_argnums=(0,))
def slot_put(pool, slot, user):
    """Scatter `user` (pytree of unbatched leaves) into `pool[slot]`."""
    return jax.tree.map(
        lambda p, u: p.at[slot].set(u.astype(p.dtype)), pool, user)


@jax.jit
def slot_take(pool, slot):
    """Gather slot `slot` of every pool leaf as an unbatched pytree."""
    return jax.tree.map(lambda p: p[slot], pool)


def _put_leaf(p, u, ax, slot):
    if ax == SHARED:
        return p
    idx = (slice(None),) * ax + (slot,)
    return p.at[idx].set(u.astype(p.dtype))


def _take_leaf(p, ax, slot):
    if ax == SHARED:
        return jnp.zeros_like(p)
    return jnp.take(p, slot, axis=ax)


def make_slot_ops(axes, shardings=None):
    """Jitted (put, take) for a pool whose per-leaf slot axes are `axes`.

    `axes` is a pytree matching the pool structure whose leaves are either
    an int (the axis carrying slot rows in that leaf) or `SHARED`.  The
    slot index is traced, so every slot reuses one executable per op.

    `shardings` (a NamedSharding pytree matching the pool, from
    `distributed.sharding.pool_shardings`) pins the scatter's OUTPUT layout
    on a meshed pool: without the constraint GSPMD is free to gather the
    donated pool onto one device and the slot -> device placement would
    silently dissolve on the first admission.
    """
    def put(pool, slot, user):
        out = jax.tree.map(
            lambda p, u, ax: _put_leaf(p, u, ax, slot), pool, user, axes)
        if shardings is not None:
            out = jax.tree.map(
                jax.lax.with_sharding_constraint, out, shardings)
        return out

    def take(pool, slot):
        return jax.tree.map(
            lambda p, ax: _take_leaf(p, ax, slot), pool, axes)

    return (jax.jit(put, donate_argnums=(0,)), jax.jit(take))


def uniform_axes(tree, axis=0):
    """Axes pytree assigning one slot `axis` to every leaf of `tree`."""
    return jax.tree.map(lambda _: axis, tree)


# ---- the generic pool ------------------------------------------------------


class SessionPool:
    """Admit/evict user sessions into a fixed-shape slot pool (base class).

    Subclasses provide the pool pytree + its slot-axes pytree and own the
    stepping programs; this base owns occupancy bookkeeping, LRU admission,
    the jitted traced-slot swaps, per-session step counters, and the
    `SessionStore` round-trip.

    Args:
      pool:  the pool pytree (must start ZEROED in its slot rows — the
             vacated-slot hygiene scatter reuses slot 0 of this initial
             pool as the zero template).
      axes:  pytree matching `pool`: per-leaf slot axis (int) or `SHARED`.
      slots: pool size B; fixes every pool tensor shape forever.
      store: `SessionStore` backing eviction/restore; a private in-RAM
             store is created if omitted.
      mesh:  optional `jax.sharding.Mesh` with a ``"data"`` axis (see
             `distributed.sharding.fleet_mesh`).  The pool pytree is placed
             with `NamedSharding` over its slot axes — device d owns the
             contiguous slot block ``[d*B/D, (d+1)*B/D)`` — and every slot
             op pins that layout, so admissions/evictions/steps run on a
             D-device fleet with the SAME executables-per-entry-point
             counts as the single-device pool (zero recompiles under
             churn).  ``slots`` must divide evenly by the device count.
      health: optional `obs.health.HealthConfig` enabling the session-
             health subsystem: subclasses gain ``record=True`` stepping
             (flight recorder + on-device detectors fused into the pool
             step), and this base gains `flagged_sessions` / `quarantine` /
             `rollback` / `remediate`.  Without it, recording raises and
             the pool is byte-for-byte the pre-health pool.
    """

    def __init__(self, pool, axes, slots: int,
                 store: Optional[SessionStore] = None,
                 registry: Optional[MetricsRegistry] = None,
                 mesh=None, health: Optional[HealthConfig] = None):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        self.slots = slots
        self.mesh = mesh
        self._shardings = None
        self.num_devices = 1
        if mesh is not None:
            if "data" not in mesh.axis_names:
                raise ValueError(
                    f"pool mesh needs a 'data' axis (the slot axis); got "
                    f"axes {mesh.axis_names} — build it with "
                    "distributed.sharding.fleet_mesh()")
            self.num_devices = int(mesh.shape["data"])
            if slots % self.num_devices != 0:
                raise ValueError(
                    f"slots={slots} must divide evenly over the "
                    f"{self.num_devices}-device 'data' axis (every device "
                    "owns the same number of slot rows; pad the pool or "
                    "shrink the mesh)")
            from repro.distributed import sharding as _sharding
            self._shardings = _sharding.pool_shardings(mesh, axes)
            pool = jax.device_put(pool, self._shardings)
        self.metrics = registry if registry is not None else MetricsRegistry()
        self.store = (store if store is not None
                      else SessionStore(registry=self.metrics))
        self.pool = pool
        self._axes = axes
        self._put, self._take = make_slot_ops(axes, self._shardings)
        # round-trip the zero template through host memory so it is an
        # UNCOMMITTED device array, exactly like an admitted payload
        # (store restores are numpy -> jnp.asarray): on a meshed pool a
        # committed gather output would key separate slot_put cache
        # entries for admission vs the vacated-slot hygiene scatter
        self._zero_session = jax.tree.map(
            lambda a: jnp.asarray(np.asarray(jax.device_get(a))),
            self._take(pool, jnp.int32(0)))
        # the pool-mode session template (abstract): what every admitted
        # payload must look like, passed to `SessionStore.checkout` so
        # admission never has to eval_shape the factory (a jitted prefill
        # factory would grow a trace-cache entry per admission otherwise)
        self._template = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            self._zero_session)
        self.slot_user: list = [None] * slots        # slot -> uid | None
        self.user_slot: Dict[str, int] = {}          # uid -> slot
        self._steps = np.zeros(slots, np.int64)      # per-session step count
        self._admit_seq = np.zeros(slots, np.int64)  # admission order (LRU)
        self._seq = 0
        self.evictions = 0
        # fault tolerance: slots whose device shard is marked lost.  Lost
        # slots never admit, never count active, and refuse evict (their
        # rows are garbage) — `drain_failed` re-homes their sessions.
        self._lost_slots: set = set()
        self._poison_session = None                  # built on first failure
        # session health: quarantined slots are occupied-but-frozen (same
        # runtime-mask freeze as vacant/lost); the flight recorder state is
        # built lazily on the first record= step so a health-enabled pool
        # that never records allocates nothing
        self.health_cfg = health
        self._quarantined: set = set()
        self._rec = None                             # obs.recorder state
        self._rec_pos = 0                            # global ring cursor
        self._rec_shardings = None
        self.last_verdict = None                     # (B,) bool, last record

        def _rec_reset(rec, slot):
            out = _recorder.reset_slot(rec, slot)
            if self._rec_shardings is not None:
                out = jax.tree.map(
                    jax.lax.with_sharding_constraint, out,
                    self._rec_shardings)
            return out

        # traced slot index -> one executable clears any slot's history
        self._reset_rec = jax.jit(_rec_reset, donate_argnums=(0,))
        # compile_count sources, keyed by entry-point name so the compile
        # audit (`compiled_programs`) can name the program that drifted
        self._jitted: Dict[str, Any] = {
            "slot_put": self._put, "slot_take": self._take,
            "recorder_reset": self._reset_rec}
        self._m_admit = self.metrics.histogram(
            "pool_admit_seconds", "admit latency (checkout + swap-in)")
        self._m_evict = self.metrics.histogram(
            "pool_evict_seconds", "evict latency (swap-out + persist)")
        self._m_occupancy = self.metrics.gauge(
            "pool_occupancy", "admitted sessions / pool slots")
        self._m_admissions = self.metrics.counter(
            "pool_admissions_total", "sessions admitted")
        self._m_evictions = self.metrics.counter(
            "pool_evictions_total", "sessions evicted")
        self._m_failures = self.metrics.counter(
            "pool_device_failures_total", "device shards marked lost")
        self._m_drained = self.metrics.counter(
            "pool_drained_sessions_total",
            "sessions re-homed off a lost shard")
        self._m_drain = self.metrics.histogram(
            "pool_drain_seconds", "drain latency (restore + re-admit, per "
            "drain_failed call)")
        self._m_quarantined = self.metrics.counter(
            "pool_quarantined_total", "sessions quarantined as unhealthy")
        self._m_rollbacks = self.metrics.counter(
            "pool_rollbacks_total",
            "quarantined sessions rolled back to their last healthy "
            "checkpoint")
        self._m_health_ckpts = self.metrics.counter(
            "pool_health_checkpoints_total",
            "health_checkpoint() sweeps (rollback restore points)")

    # ---- occupancy -------------------------------------------------------

    @property
    def active_users(self) -> list:
        return [u for u in self.slot_user if u is not None]

    @property
    def free_slots(self) -> int:
        return sum(1 for s, u in enumerate(self.slot_user)
                   if u is None and s not in self._lost_slots)

    @property
    def lost_slots(self) -> frozenset:
        """Slots whose device shard has been marked lost."""
        return frozenset(self._lost_slots)

    def slot_device(self, slot: int) -> int:
        """Device index owning `slot` under the mesh placement (0 unmeshed).

        NamedSharding over the length-D ``"data"`` axis places contiguous
        blocks: device d owns slots ``[d*B/D, (d+1)*B/D)``."""
        return slot * self.num_devices // self.slots

    def device_slots(self, device: int) -> range:
        """The contiguous slot block owned by `device`."""
        per = self.slots // self.num_devices
        if not 0 <= device < self.num_devices:
            raise ValueError(f"device must be in [0, {self.num_devices}), "
                             f"got {device}")
        return range(device * per, (device + 1) * per)

    def _active_mask(self) -> jax.Array:
        # lost AND quarantined slots are masked out like vacant ones: a
        # stranded session is frozen until drain_failed re-homes it, an
        # unhealthy one until rollback restores it — the mask is a runtime
        # operand, so neither failure nor quarantine ever recompiles
        mask = np.zeros(self.slots, np.bool_)
        for s, u in enumerate(self.slot_user):
            mask[s] = (u is not None and s not in self._lost_slots
                       and s not in self._quarantined)
        return jnp.asarray(mask)

    def compiled_programs(self) -> Dict[str, int]:
        """Per-entry-point executable counts: {name: compiled programs}.

        EVERY jitted entry point the pool owns is audited here (the
        telemetry step variants included) — `tests/test_serving_lm.py`
        pins the exact expected dict per (layout x datapath), so adding a
        jitted program without registering it in ``_jitted`` fails the
        audit rather than silently escaping the no-recompile gates.
        """
        return {name: int(f._cache_size())
                for name, f in self._jitted.items()}

    def compile_count(self) -> int:
        """Total executables compiled by the pool's jitted programs."""
        return sum(self.compiled_programs().values())

    def pool_nbytes(self) -> int:
        """Resident bytes of the pool pytree (all leaves).

        The quantized-pool headline: int8 weight planes instead of float32
        mean the same HBM holds ~4x more resident sessions (weights
        dominate the session footprint).
        """
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.pool))

    # ---- session template hooks -----------------------------------------

    def _session_factory(self):
        """Fresh (zero) session for a brand-new user; subclasses may
        override with richer construction (e.g. an LM prefill)."""
        return jax.tree.map(jnp.zeros_like, self._zero_session)

    def _finalize_session(self, user, step: int):
        """Hook: adjust a just-gathered session before persisting it
        (e.g. stamp the host-side step counter into a SHARED leaf)."""
        return user

    # ---- admission / eviction -------------------------------------------

    def admit(self, uid: str, evict_lru: bool = False, factory=None) -> int:
        """Place `uid` into a free slot (restoring persisted state if any).

        Returns the slot index.  With ``evict_lru=True`` a full pool evicts
        its least-recently-admitted session to make room; otherwise a full
        pool raises RuntimeError.  `factory` overrides the fresh-session
        constructor for THIS admission (it is also the `SessionStore`
        validation template, so it must build the session pytree the pool
        expects).
        """
        if uid in self.user_slot:
            raise ValueError(f"session {uid!r} is already in slot "
                             f"{self.user_slot[uid]}")
        healthy = [s for s in range(self.slots) if s not in self._lost_slots]
        free = [s for s in healthy if self.slot_user[s] is None]
        if not free:
            # quarantined residents are not LRU-evictable: evicting one
            # would persist its diverged state over the healthy checkpoint
            candidates = [s for s in healthy if self.slot_user[s] is not None
                          and s not in self._quarantined]
            if not evict_lru or not candidates:
                lost = (f" ({len(self._lost_slots)} slots lost to device "
                        "failure)" if self._lost_slots else "")
                raise RuntimeError(
                    f"pool is full ({self.slots} slots{lost}); pass "
                    "evict_lru=True or evict a session first")
            lru = min(candidates, key=lambda s: self._admit_seq[s])
            self.evict(self.slot_user[lru])
            free = [lru]
        slot = free[0]
        with self._m_admit.time(), phase("pool.admit"):
            state, step = self.store.checkout(
                uid, self._session_factory if factory is None else factory,
                template=self._template)
            # normalize to device arrays: a store restore hands back HOST
            # (numpy) leaves, and numpy arguments key a SEPARATE jit cache
            # entry — without this, the first restore-admission after warm-up
            # would read as a recompile under the pinned-zero churn gate
            state = jax.tree.map(jnp.asarray, state)
            with phase("pool.swap_in"):
                self.pool = self._put(self.pool, jnp.int32(slot), state)
        self.slot_user[slot] = uid
        self.user_slot[uid] = slot
        self._steps[slot] = step
        self._admit_seq[slot] = self._seq
        self._seq += 1
        # the slot's flight-recorder history belongs to the PREVIOUS tenant;
        # clear it so detectors baseline on this session from step 0
        if self._rec is not None:
            self._rec = self._reset_rec(self._rec, jnp.int32(slot))
        self._m_admissions.inc()
        self._m_occupancy.set(len(self.user_slot) / self.slots)
        return slot

    def evict(self, uid: str) -> None:
        """Swap `uid` out, persist it durably, and clear its slot."""
        slot = self.user_slot.get(uid)
        if slot is None:
            raise KeyError(f"session {uid!r} is not in the pool")
        if slot in self._lost_slots:
            raise RuntimeError(
                f"session {uid!r} sits in lost slot {slot} (device "
                f"{self.slot_device(slot)}); its rows are gone — recover it "
                "with drain_failed(), which restores the last durable "
                "checkpoint, instead of evicting garbage")
        if slot in self._quarantined:
            raise RuntimeError(
                f"session {uid!r} in slot {slot} is quarantined as "
                "unhealthy; evicting would persist its diverged state over "
                "the last healthy checkpoint — recover it with rollback() "
                "or remediate() instead")
        self.user_slot.pop(uid)
        with self._m_evict.time(), phase("pool.evict"):
            with phase("pool.swap_out"):
                user = self._take(self.pool, jnp.int32(slot))
            user = self._finalize_session(user, int(self._steps[slot]))
            self.store.checkin(uid, user, int(self._steps[slot]))
            self.slot_user[slot] = None
            # hygiene: scatter zeros over the vacated slot so no stale user
            # data lingers in the pool tensor (the mask already freezes it)
            self.pool = self._put(self.pool, jnp.int32(slot),
                                  self._zero_session)
        self._steps[slot] = 0
        if self._rec is not None:
            self._rec = self._reset_rec(self._rec, jnp.int32(slot))
        self.evictions += 1
        self._m_evictions.inc()
        self._m_occupancy.set(len(self.user_slot) / self.slots)

    def advance_steps(self, k: int) -> None:
        """Advance every admitted session's host-side step counter by k."""
        for slot in self.user_slot.values():
            self._steps[slot] += k

    # ---- device-loss recovery (distributed/ft.py posture) ----------------

    def persist_resident(self) -> int:
        """Durably snapshot every resident session WITHOUT evicting it.

        The periodic drain-safety checkpoint: `drain_failed` recovers a
        lost shard's sessions from their last durable snapshot, so steps
        taken since it are the blast radius of a device loss.  Gathers each
        healthy resident session (lost slots are skipped — their rows are
        gone) and writes it through `SessionStore.persist`; the warm cache
        is untouched (resident uids are checked out, never warm).  Returns
        the number of sessions persisted.
        """
        n = 0
        for uid, slot in list(self.user_slot.items()):
            # quarantined rows are diverged state — persisting one would
            # clobber the very checkpoint rollback needs
            if slot in self._lost_slots or slot in self._quarantined:
                continue
            user = self._take(self.pool, jnp.int32(slot))
            user = self._finalize_session(user, int(self._steps[slot]))
            self.store.persist(uid, user, int(self._steps[slot]))
            n += 1
        return n

    def stranded_sessions(self) -> list:
        """Uids resident in lost slots, awaiting `drain_failed`."""
        return [u for u, s in self.user_slot.items()
                if s in self._lost_slots]

    def _poison(self):
        if self._poison_session is None:
            def leaf(z):
                if jnp.issubdtype(z.dtype, jnp.floating):
                    return jnp.full_like(z, jnp.nan)
                if jnp.issubdtype(z.dtype, jnp.integer):
                    return jnp.full_like(z, jnp.iinfo(z.dtype).max)
                return jnp.ones_like(z)
            self._poison_session = jax.tree.map(leaf, self._zero_session)
        return self._poison_session

    def fail_slots(self, slots, poison: bool = True) -> list:
        """Failure injection: mark `slots` lost; returns the stranded uids.

        With ``poison=True`` (the default) the rows are overwritten with
        sentinel garbage (NaN float planes, saturated integer planes) —
        recovery tests that pass with poison on PROVE the drain path reads
        only `SessionStore` checkpoints, never the dead shard, and that the
        active mask isolates the garbage from surviving slots' math.
        """
        slots = sorted(set(int(s) for s in slots))
        for s in slots:
            if not 0 <= s < self.slots:
                raise ValueError(f"slot {s} out of range [0, {self.slots})")
        self._lost_slots.update(slots)
        if poison:
            for s in slots:
                self.pool = self._put(self.pool, jnp.int32(s),
                                      self._poison())
        return self.stranded_sessions()

    def fail_device(self, device: int, poison: bool = True) -> list:
        """Mark one device's whole slot shard lost (see `fail_slots`).

        The injection hook the multi-device recovery tests and the drain-
        latency benchmark drive: everything device `device` owned — resident
        sessions included — is gone; follow with `drain_failed()` to re-home
        its sessions onto the surviving shards.
        """
        stranded = self.fail_slots(self.device_slots(device), poison=poison)
        self._m_failures.inc()
        return stranded

    def drain_failed(self, evict_lru: bool = False) -> list:
        """Re-home every stranded session onto surviving shards.

        For each uid resident in a lost slot: drop the dead occupancy (the
        shard is gone — nothing is gathered or persisted from it), then
        `admit` the uid normally, which restores its last durable snapshot
        from the `SessionStore`.  Admission only considers healthy slots,
        so the session lands on a SURVIVING device — and because a session's
        trajectory is slot- and neighbour-invariant (the pool contract),
        its continuation is bit-identical to an uninterrupted run from that
        snapshot.  Steps taken after the last `persist_resident`/evict are
        lost; each report row says how many.

        Returns a list of dicts: ``{uid, from_slot, to_slot, from_device,
        to_device, steps_lost}``.  With ``evict_lru=True`` a full pool
        evicts least-recently-admitted survivors to make room.
        """
        report = []
        with self._m_drain.time(), phase("pool.drain"):
            for uid in self.stranded_sessions():
                old_slot = self.user_slot.pop(uid)
                self.slot_user[old_slot] = None
                steps_at_fail = int(self._steps[old_slot])
                self._steps[old_slot] = 0
                # hygiene (simulation-only: a real dead device is not
                # writable, but the injected one is): clear the poison so
                # the checkpointed pool keeps the slots-are-zero-when-
                # vacant invariant
                self.pool = self._put(self.pool, jnp.int32(old_slot),
                                      self._zero_session)
                new_slot = self.admit(uid, evict_lru=evict_lru)
                self._m_drained.inc()
                report.append({
                    "uid": uid,
                    "from_slot": old_slot, "to_slot": new_slot,
                    "from_device": self.slot_device(old_slot),
                    "to_device": self.slot_device(new_slot),
                    "steps_lost": steps_at_fail - int(self._steps[new_slot]),
                })
        self._m_occupancy.set(len(self.user_slot) / self.slots)
        return report

    # ---- session health: detect -> quarantine -> rollback ----------------

    @property
    def quarantined_slots(self) -> frozenset:
        """Slots frozen by `quarantine` (occupied, masked out, awaiting
        rollback)."""
        return frozenset(self._quarantined)

    def _ensure_recorder(self):
        """Build the flight-recorder state on first use (meshed pools place
        it with the same contiguous slot-block `NamedSharding` as the pool
        itself, so the record-variant step needs no resharding)."""
        if self.health_cfg is None:
            raise ValueError(
                "this pool was built without health=HealthConfig(...); "
                "recording and remediation are unavailable")
        if self._rec is None:
            rec = _recorder.init_recorder(self.health_cfg, self.slots)
            if self.mesh is not None:
                from repro.distributed import sharding as _sharding
                self._rec_shardings = _sharding.pool_shardings(
                    self.mesh, jax.tree.map(lambda _: 0, rec))
                rec = jax.device_put(rec, self._rec_shardings)
            self._rec = rec
        return self._rec

    def health_checkpoint(self) -> int:
        """Durably snapshot every HEALTHY resident session — the restore
        point `rollback` recovers to.  Rides `persist_resident` (lost and
        quarantined slots are skipped), so the cadence/cost profile is the
        drain-safety checkpoint's; steps since the last call are the blast
        radius of an incident.  Returns the number persisted."""
        n = self.persist_resident()
        self._m_health_ckpts.inc()
        return n

    def flagged_sessions(self) -> list:
        """Uids whose latched device-side verdict is unhealthy (slot order).

        The one host read of the health loop: a single ``(B, D)`` bool
        gather, on demand — never per step.  Lost and already-quarantined
        slots are excluded (they are some OTHER remediation's business).
        """
        if self._rec is None:
            return []
        flags = np.asarray(
            jax.device_get(self._rec.health.flagged)).any(axis=-1)
        return [u for s, u in enumerate(self.slot_user)
                if u is not None and flags[s]
                and s not in self._lost_slots
                and s not in self._quarantined]

    def quarantine(self, uid: str) -> int:
        """Freeze `uid`'s slot via the runtime active mask (no recompiles,
        no data movement): its state stops evolving bit-exactly, exactly
        like a vacant slot's, until `rollback` re-homes it.  Returns the
        quarantined slot index."""
        slot = self.user_slot.get(uid)
        if slot is None:
            raise KeyError(f"session {uid!r} is not in the pool")
        if slot in self._lost_slots:
            raise RuntimeError(
                f"session {uid!r} sits in LOST slot {slot}; use "
                "drain_failed(), not quarantine")
        self._quarantined.add(slot)
        self._m_quarantined.inc()
        return slot

    def rollback(self, uid: str, evict_lru: bool = False) -> dict:
        """Re-admit a quarantined session from its last healthy checkpoint.

        Mirrors the device-loss drain, and deliberately shares its
        machinery: drop the diverged occupancy (nothing is gathered or
        persisted from it), zero the slot, clear its flight-recorder rows,
        then `admit(uid)` — which restores the last durable snapshot from
        the `SessionStore`, so the continuation is bit-identical to a
        manual evict-before-incident -> re-admit of the same checkpoint
        (the incident drill `tests/test_health.py` pins).  Steps since the
        last `health_checkpoint`/evict are lost; the report says how many.

        Returns ``{uid, from_slot, to_slot, steps_lost}``.
        """
        slot = self.user_slot.get(uid)
        if slot is None:
            raise KeyError(f"session {uid!r} is not in the pool")
        if slot not in self._quarantined:
            raise RuntimeError(
                f"session {uid!r} (slot {slot}) is not quarantined; "
                "rollback only recovers quarantined sessions — call "
                "quarantine(uid) first (or remediate(), which does both)")
        steps_at_flag = int(self._steps[slot])
        self.user_slot.pop(uid)
        self.slot_user[slot] = None
        self._steps[slot] = 0
        self.pool = self._put(self.pool, jnp.int32(slot),
                              self._zero_session)
        self._quarantined.discard(slot)
        if self._rec is not None:
            self._rec = self._reset_rec(self._rec, jnp.int32(slot))
        new_slot = self.admit(uid, evict_lru=evict_lru)
        self._m_rollbacks.inc()
        return {"uid": uid, "from_slot": slot, "to_slot": new_slot,
                "steps_lost": steps_at_flag - int(self._steps[new_slot])}

    def remediate(self, evict_lru: bool = False,
                  flight_dir: Optional[str] = None) -> list:
        """The automated health loop: quarantine every flagged session,
        optionally dump its flight-recorder incident bundle, and roll it
        back to the last healthy checkpoint.  Returns one `rollback`
        report per casualty (with an ``"incident"`` path when dumping).
        Safe to call at any cadence — flags latch on device, and a clean
        pool is a no-op."""
        reports = []
        for uid in self.flagged_sessions():
            slot = self.quarantine(uid)
            incident = None
            if flight_dir is not None:
                incident = _recorder.dump_incident(
                    flight_dir, uid=uid, slot=slot, rec=self._rec,
                    cfg=self.health_cfg, pos=self._rec_pos,
                    registry=self.metrics, watchdog=_compile_watchdog)
            report = self.rollback(uid, evict_lru=evict_lru)
            if incident is not None:
                report["incident"] = incident
            reports.append(report)
        return reports

    # ---- whole-pool checkpointing (elastic re-mesh) ----------------------

    def save_pool(self, directory: str) -> str:
        """Checkpoint the WHOLE pool — resident sessions in place — plus the
        occupancy bookkeeping, in the standard `checkpoint.manager` layout.

        Leaves are stored unsharded, so the checkpoint is topology-free: a
        pool saved at D devices restores at any D' via `load_pool` (the
        `distributed.ft.elastic_restore` path).  Stranded sessions must be
        drained first — their rows are garbage and checkpointing garbage as
        state would be silent corruption.
        """
        stranded = self.stranded_sessions()
        if stranded:
            raise RuntimeError(
                f"cannot checkpoint a pool with stranded sessions "
                f"{stranded}; run drain_failed() first")
        sick = [u for u, s in self.user_slot.items()
                if s in self._quarantined]
        if sick:
            # load_pool restarts with an empty quarantine set, which would
            # silently unfreeze diverged state as healthy
            raise RuntimeError(
                f"cannot checkpoint a pool with quarantined sessions "
                f"{sick}; run remediate() first")
        from repro.checkpoint.manager import save_checkpoint
        extra = {
            "slots": self.slots,
            "slot_user": list(self.slot_user),
            "steps": [int(s) for s in self._steps],
            "admit_seq": [int(s) for s in self._admit_seq],
            "seq": int(self._seq),
        }
        return save_checkpoint(directory, int(self._seq), self.pool,
                               extra=extra)

    def load_pool(self, directory: str, step: Optional[int] = None) -> None:
        """Resume a `save_pool` checkpoint INTO this pool, re-laid-out on
        this pool's mesh.

        The elastic re-mesh path: construct the scheduler at the NEW
        topology (any device count whose shard evenly divides ``slots``,
        including unmeshed) and load a checkpoint taken at the old one —
        leaves are stored unsharded, so restore is a pure device_put onto
        the new `NamedSharding`s (`distributed.ft.elastic_restore`).
        Occupancy, per-session step counters, and LRU order resume exactly;
        all slots come back healthy.
        """
        if self.mesh is not None:
            from repro.distributed import ft as _ft
            from repro.distributed import sharding as _sharding
            tree, _, extra = _ft.elastic_restore(
                directory, self.pool, self.mesh,
                lambda mesh: _sharding.pool_shardings(mesh, self._axes),
                step=step)
        else:
            from repro.checkpoint.manager import load_checkpoint
            tree, _, extra = load_checkpoint(directory, self.pool, step=step)
        if int(extra["slots"]) != self.slots:
            raise ValueError(
                f"checkpointed pool has {extra['slots']} slots; this pool "
                f"has {self.slots} (elastic restore re-meshes devices, not "
                "the slot count)")
        self.pool = tree
        self.slot_user = list(extra["slot_user"])
        self.user_slot = {u: s for s, u in enumerate(self.slot_user)
                          if u is not None}
        self._steps = np.asarray(extra["steps"], np.int64).copy()
        self._admit_seq = np.asarray(extra["admit_seq"], np.int64).copy()
        self._seq = int(extra["seq"])
        self._lost_slots = set()
        self._poison_session = None
        # recorder state is not checkpointed (detector baselines are cheap
        # to rebuild and meaningless across a re-mesh): restart clean
        self._quarantined = set()
        self._rec = None
        self._rec_pos = 0
        self.last_verdict = None
        self._m_occupancy.set(len(self.user_slot) / self.slots)


# ---- the SNN controller fleet ---------------------------------------------


def _network_axes(fleet: NetworkState) -> NetworkState:
    """Slot axes of a fleet NetworkState: every leaf carries slot rows on
    axis 0 except the shared pool clock `t`.  In a quantized pool the
    per-layer ``w_scale`` rows are slot state like everything else — a
    restored session brings its own scale into whatever slot it lands in
    (the int8 payload is meaningless without it)."""
    return NetworkState(
        w=tuple(0 for _ in fleet.w),
        v=tuple(0 for _ in fleet.v),
        trace=tuple(0 for _ in fleet.trace),
        t=SHARED,
        w_scale=tuple(0 for _ in fleet.w_scale))


class FleetScheduler(SessionPool):
    """Admit/evict user sessions into a fixed-shape controller slot pool.

    Args:
      cfg:    `snn.SNNConfig` of the controller (``cfg.impl`` picks the
              engine backend for the whole pool; ``cfg.quant`` — see
              `snn.quant_config` — makes it a QUANTIZED pool: int8 weight
              slots with per-slot scales, int32 membrane/trace slots,
              ~4x more resident sessions per byte, and per-session step
              counters driving the deterministic stochastic round so
              evict -> re-admit stays bit-identical).
      theta:  per-layer packed rule coefficients (shared by every session —
              the rule is the deployment, the weights are the user).
      slots:  pool size B; fixes the fleet tensor shape forever.
      store:  `SessionStore` backing eviction/restore; a private in-RAM
              store is created if omitted.
      mesh:   optional device mesh (see `SessionPool`): the fleet tensors
              shard over their slot axis and every step/rollout launch
              lowers under `engine.fleet_spmd` (shard_map) — each device
              runs the identical engine program on its B/D local slots, so
              the meshed pool is bit-identical to the unmeshed one on every
              backend and datapath (tests/test_distributed.py pins it).
    """

    def __init__(self, cfg: snn.SNNConfig, theta, slots: int,
                 store: Optional[SessionStore] = None,
                 registry: Optional[MetricsRegistry] = None,
                 mesh=None, health: Optional[HealthConfig] = None):
        self.cfg = cfg
        self.theta = theta
        fleet = snn.init_state(cfg, batch=slots, fleet=True)
        super().__init__(fleet, _network_axes(fleet), slots, store, registry,
                         mesh=mesh, health=health)

        def _pool_step(fleet, drive, active, teach, seeds):
            # `seeds` are the PER-SESSION step counters (host bookkeeping
            # scattered to device each step): in a quantized pool they
            # drive the deterministic stochastic round, so a session's
            # update stream follows the session across evictions and slot
            # changes — never the shared pool clock.  Float pools ignore
            # them (same jitted signature either way).
            return snn.timestep(cfg, fleet, theta, drive, teach=teach,
                                active=active, seed=seeds)

        def _pool_rollout(fleet, window, active, teach, seeds):
            # K fused pool timesteps in ONE engine launch (the rollout
            # megakernel): same per-session seed semantics as _pool_step —
            # step k of the window draws from seeds + k, exactly the
            # sequence K single steps would draw.
            return snn.rollout_window(cfg, fleet, theta, window, teach=teach,
                                      active=active, seed=seeds)

        def _pool_step_tel(fleet, drive, active, teach, seeds):
            # the telemetry trace VARIANT of _pool_step: `telemetry` is a
            # static flag, so this is a second stable program per entry
            # point (compiled once, never per step), not a runtime branch
            return snn.timestep(cfg, fleet, theta, drive, teach=teach,
                                active=active, seed=seeds, telemetry=True)

        def _pool_rollout_tel(fleet, window, active, teach, seeds):
            return snn.rollout_window(cfg, fleet, theta, window, teach=teach,
                                      active=active, seed=seeds,
                                      telemetry=True)

        quant = cfg.quant is not None
        hcfg = health

        def _record(ns, res_tail, rec, pos, active):
            # shared tail of the record trace VARIANTS: telemetry channels
            # + weight norm -> flight-recorder ring + streaming detectors,
            # all fused into the same program (no extra launch, no host
            # sync — the verdict stays on device until the host asks)
            tel = res_tail[-1]
            wnorm = _recorder.network_weight_norm(ns, quant)
            ch = jnp.stack([tel.spike_rate, tel.mean_abs_dw, tel.sat_frac,
                            wnorm], axis=-1)
            rec2, verdict = _recorder.recorder_update(hcfg, rec, ch, pos,
                                                      active)
            return rec2, verdict

        def _pool_step_rec(fleet, drive, active, teach, seeds, rec, pos):
            res = snn.timestep(cfg, fleet, theta, drive, teach=teach,
                               active=active, seed=seeds, telemetry=True)
            rec2, verdict = _record(res[0], res, rec, pos, active)
            return res + (rec2, verdict)

        def _pool_rollout_rec(fleet, window, active, teach, seeds, rec, pos):
            res = snn.rollout_window(cfg, fleet, theta, window, teach=teach,
                                     active=active, seed=seeds,
                                     telemetry=True)
            rec2, verdict = _record(res[0], res, rec, pos, active)
            return res + (rec2, verdict)

        def _meshed(core, *, window: bool, tel: bool):
            # Lower `core` under shard_map over the slot axis
            # (`engine.fleet_spmd`): the NetworkState is flattened into its
            # slot-mapped fields; the pool clock `t` rides in REPLICATED
            # (shard_map with check_rep=False cannot return an unmapped
            # output, and Pallas carries no replication rule) and advances
            # OUTSIDE the mapped region — bit-exactly what the unmeshed
            # step computes, since `t` only feeds the t+k bump here (the
            # quant rounding streams draw from the per-session seeds).
            def body(w, v, tr, scl, t, x, active, teach, seeds):
                st = NetworkState(w=w, v=v, trace=tr, t=t, w_scale=scl)
                res = core(st, x, active, teach, seeds)
                ns = res[0]
                return (ns.w, ns.v, ns.trace, ns.w_scale) + tuple(res[1:])

            x_ax = 1 if window else 0          # (K, B, n) windows vs (B, n)
            mapped = engine.fleet_spmd(
                body, mesh,
                in_axes=(0, 0, 0, 0, None, x_ax, 0, 0, 0),
                out_axes=(0, 0, 0, 0, x_ax) + ((0,) if tel else ()))

            def run(fleet, x, active, teach, seeds):
                out = mapped(fleet.w, fleet.v, fleet.trace, fleet.w_scale,
                             fleet.t, x, active, teach, seeds)
                k = x.shape[0] if window else 1
                ns = NetworkState(w=out[0], v=out[1], trace=out[2],
                                  t=fleet.t + k, w_scale=out[3])
                return (ns,) + tuple(out[4:])

            return run

        def _meshed_rec(core, *, window: bool):
            # the record variants mesh like the telemetry ones: every
            # RecorderState leaf is slot-major (axis 0), so the whole rec
            # pytree rides one mapped arg; the ring cursor `pos` is
            # replicated like the clock (all slots record in lockstep)
            def body(w, v, tr, scl, t, x, active, teach, seeds, rec, pos):
                st = NetworkState(w=w, v=v, trace=tr, t=t, w_scale=scl)
                res = core(st, x, active, teach, seeds, rec, pos)
                ns = res[0]
                return (ns.w, ns.v, ns.trace, ns.w_scale) + tuple(res[1:])

            x_ax = 1 if window else 0
            mapped = engine.fleet_spmd(
                body, mesh,
                in_axes=(0, 0, 0, 0, None, x_ax, 0, 0, 0, 0, None),
                out_axes=(0, 0, 0, 0, x_ax, 0, 0, 0))

            def run(fleet, x, active, teach, seeds, rec, pos):
                out = mapped(fleet.w, fleet.v, fleet.trace, fleet.w_scale,
                             fleet.t, x, active, teach, seeds, rec, pos)
                k = x.shape[0] if window else 1
                ns = NetworkState(w=out[0], v=out[1], trace=out[2],
                                  t=fleet.t + k, w_scale=out[3])
                return (ns,) + tuple(out[4:])

            return run

        if mesh is not None:
            _pool_step = _meshed(_pool_step, window=False, tel=False)
            _pool_rollout = _meshed(_pool_rollout, window=True, tel=False)
            _pool_step_tel = _meshed(_pool_step_tel, window=False, tel=True)
            _pool_rollout_tel = _meshed(_pool_rollout_tel, window=True,
                                        tel=True)
            _pool_step_rec = _meshed_rec(_pool_step_rec, window=False)
            _pool_rollout_rec = _meshed_rec(_pool_rollout_rec, window=True)

        # Fixed shapes everywhere => each of these traces exactly once per
        # signature; `compiled_programs()` exposes the per-entry-point
        # executable counts the churn benchmark and compile audit pin.
        # The telemetry variants are registered up-front: an untraced jit
        # reports _cache_size() == 0, so a telemetry-off run still audits
        # them (as zeros) without compiling anything extra.
        self._step = jax.jit(_pool_step)
        self._rollout = jax.jit(_pool_rollout)
        self._step_tel = jax.jit(_pool_step_tel)
        self._rollout_tel = jax.jit(_pool_rollout_tel)
        # NOTE: the recorder buffer is NOT donated even though the caller's
        # copy is dead after every record step — on backends without
        # donation support (CPU) an unusable donation forces defensive
        # copies that cost more than the recorder itself (~+10% per call
        # at B=256, measured by benchmarks/obs_health.py)
        self._step_rec = jax.jit(_pool_step_rec)
        self._rollout_rec = jax.jit(_pool_rollout_rec)
        self._jitted.update({
            "pool_step": self._step,
            "pool_rollout": self._rollout,
            "pool_step_telemetry": self._step_tel,
            "pool_rollout_telemetry": self._rollout_tel,
            "pool_step_record": self._step_rec,
            "pool_rollout_record": self._rollout_rec,
        })

    # the historical attribute name: the pool pytree IS the fleet state
    @property
    def fleet(self) -> NetworkState:
        return self.pool

    @fleet.setter
    def fleet(self, value: NetworkState) -> None:
        self.pool = value

    def _session_factory(self):
        return snn.init_state(self.cfg)

    def _finalize_session(self, user: NetworkState, step: int) -> NetworkState:
        # the generic swap-out zeroes the SHARED pool clock; stamp the
        # session's true host-side step count before it is persisted
        return dataclasses.replace(
            user, t=jnp.asarray(step, jnp.int32))

    # ---- stepping --------------------------------------------------------

    def _gather_rows(self, drives: Mapping[str, jax.Array],
                     teach: Optional[Mapping[str, jax.Array]]
                     ) -> tuple[jax.Array, Optional[jax.Array]]:
        """Validate uid coverage and pack per-session rows into slot order."""
        missing = [u for u in self.user_slot if u not in drives]
        extra = [u for u in drives if u not in self.user_slot]
        if missing or extra:
            raise ValueError(
                f"drives must cover exactly the admitted sessions; missing "
                f"{missing}, not admitted {extra}")
        n_in = self.cfg.layer_sizes[0]
        drive = np.zeros((self.slots, n_in), np.float32)
        for uid, row in drives.items():
            drive[self.user_slot[uid]] = np.asarray(row, np.float32)
        tarr = None
        if teach is not None:
            ghosts = [u for u in teach if u not in self.user_slot]
            if ghosts:
                raise ValueError(
                    f"teach signals for sessions not in the pool: {ghosts}")
            m_out = self.cfg.layer_sizes[-1]
            tarr = np.zeros((self.slots, m_out), np.float32)
            for uid, row in teach.items():
                tarr[self.user_slot[uid]] = np.asarray(row, np.float32)
            tarr = jnp.asarray(tarr)
        return jnp.asarray(drive), tarr

    def step(self, drives: Mapping[str, jax.Array],
             teach: Optional[Mapping[str, jax.Array]] = None,
             telemetry: bool = False, record: bool = False):
        """One fused SNN timestep for the WHOLE pool.

        `drives` maps uid -> input drive ``(obs_dim,)`` (already encoded;
        the pool is deterministic, matching ``encoding="current"``).  Every
        admitted session must receive a drive.  Vacant slots get zero drive
        and are frozen by the active mask.  Returns uid -> readout row.

        ``telemetry=True`` dispatches the telemetry trace variant instead
        (one extra stable program, compiled on first use) and returns
        ``(outputs, FleetTelemetry)``; fleet-level summary gauges are
        recorded into ``self.metrics``.

        ``record=True`` (needs ``health=HealthConfig(...)``) dispatches the
        RECORD trace variant: the same telemetry channels plus the weight
        norm feed the flight-recorder ring and the streaming detectors
        inside the one program — still no host sync per step; the latched
        verdict waits on device for `flagged_sessions`/`remediate`.  Pass
        ``telemetry=True`` too to ALSO get the host-side tuple return and
        summary gauges (same single program either way).
        """
        drive, tarr = self._gather_rows(drives, teach)
        if record:
            rec = self._ensure_recorder()
            with phase("pool.step"):
                res = self._step_rec(
                    self.fleet, drive, self._active_mask(), tarr,
                    jnp.asarray(self._steps.astype(np.int32)),
                    rec, jnp.int32(self._rec_pos))
            self.fleet, out = res[0], res[1]
            self._rec, self.last_verdict = res[3], res[4]
            self._rec_pos += 1
        else:
            fn = self._step_tel if telemetry else self._step
            with phase("pool.step"):
                res = fn(self.fleet, drive, self._active_mask(), tarr,
                         jnp.asarray(self._steps.astype(np.int32)))
            self.fleet, out = res[0], res[1]
        self.advance_steps(1)
        outputs = {uid: out[slot] for uid, slot in self.user_slot.items()}
        if not telemetry:
            return outputs
        tel: FleetTelemetry = res[2]
        record_fleet_telemetry(self.metrics, tel)
        return outputs, tel

    def pool_step(self, drives: Mapping[str, jax.Array],
                  timesteps: Optional[int] = None,
                  teach: Optional[Mapping[str, jax.Array]] = None,
                  telemetry: bool = False, record: bool = False):
        """K fused SNN timesteps for the WHOLE pool in ONE engine launch.

        The time-fused form of calling `step` K times on held drives: the
        whole (K timesteps x layers x slots) window runs as a single
        `engine.rollout` launch (one `pallas_call` on the Pallas backends),
        with per-session step counters seeding each step of the window
        exactly as K single steps would.  ``timesteps`` defaults to
        ``cfg.timesteps``; occupancy is frozen across the window
        (admissions/evictions happen between windows, which is already the
        scheduler's contract — they are host-side events).

        Returns uid -> (K, act_dim) readout WINDOW (callers reduce:
        `control_step` takes the mean).

        ``telemetry=True`` dispatches the telemetry trace variant (one
        extra stable program) and returns ``(outputs, FleetTelemetry)``
        with window-averaged per-slot rates, recording fleet summary
        gauges into ``self.metrics``.

        ``record=True`` (needs ``health=HealthConfig(...)``) dispatches the
        record trace variant: the window's (averaged) telemetry channels
        write ONE flight-recorder row and one detector update per call —
        a recorded window is one observation, matching the per-step path's
        cadence in recorded samples per launch.
        """
        k = self.cfg.timesteps if timesteps is None else int(timesteps)
        if k < 1:
            raise ValueError(f"pool_step needs timesteps >= 1, got {k}")
        drive, tarr = self._gather_rows(drives, teach)
        n_in = self.cfg.layer_sizes[0]
        window = jnp.broadcast_to(drive[None], (k, self.slots, n_in))
        if record:
            rec = self._ensure_recorder()
            with phase("pool.rollout"):
                res = self._rollout_rec(
                    self.fleet, window, self._active_mask(), tarr,
                    jnp.asarray(self._steps.astype(np.int32)),
                    rec, jnp.int32(self._rec_pos))
            self.fleet, outs = res[0], res[1]
            self._rec, self.last_verdict = res[3], res[4]
            self._rec_pos += 1
        else:
            fn = self._rollout_tel if telemetry else self._rollout
            with phase("pool.rollout"):
                res = fn(self.fleet, window, self._active_mask(), tarr,
                         jnp.asarray(self._steps.astype(np.int32)))
            self.fleet, outs = res[0], res[1]
        self.advance_steps(k)
        outputs = {uid: outs[:, slot] for uid, slot in self.user_slot.items()}
        if not telemetry:
            return outputs
        tel: FleetTelemetry = res[2]
        record_fleet_telemetry(self.metrics, tel)
        return outputs, tel

    def control_step(self, obs: Mapping[str, jax.Array]
                     ) -> Dict[str, jax.Array]:
        """One CONTROL step = ``cfg.timesteps`` pool timesteps on held
        observations (mirrors `snn.controller_step`: mean readout over the
        window, tanh-squashed unless the readout spikes).  The window runs
        as ONE fused `pool_step` launch instead of ``timesteps`` separate
        pool steps."""
        outs = self.pool_step(obs)
        actions = {}
        for uid, window in outs.items():
            a = window.mean(axis=0)
            actions[uid] = a if self.cfg.spiking_readout else jnp.tanh(a)
        return actions
