"""Perturbation schedules: domain randomization as *data*.

A `Perturbation` is a small frozen spec (what happens, when, to which
fraction of the fleet).  `compile_schedule` turns a tuple of specs into a
`Schedule` — a pytree of ``(K, B, ...)`` arrays with one row per spec and
per-slot randomization already drawn (which actuator fails in which slot,
each slot's onset jitter, its parameter multiplier, its switched goal).
Applying the schedule at step ``t`` is nothing but ``jnp.where(t >= onset,
value, neutral)`` reductions, so a whole closed-loop rollout — including
every perturbation event — is ONE jitted `lax.scan` that never recompiles:
changing the schedule (severity, onset, victims) changes operand *values*,
never shapes or the program.

Spec kinds:

  * `ActuatorDropout` — zero ``k`` random actuators per affected slot (or an
    explicit mask), composing multiplicatively with the base mask.
  * `SensorNoise`     — additive white noise (std) and a fixed per-slot
    bias on the observation vector.
  * `ParamShift`      — multiply/add one named dynamics parameter
    (`Env.PARAM_NAMES`), with optional per-slot spread.
  * `GoalSwitch`      — mid-episode task replacement (resampled per slot
    from the env's eval tasks, or an explicit task array).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.envs.base import Env
from repro.scenarios.vector_env import VecEnvState

NEVER = jnp.iinfo(jnp.int32).max  # onset for slots a spec does not hit


@dataclasses.dataclass(frozen=True)
class Perturbation:
    """Base spec: onset step, affected fleet fraction, per-slot onset jitter."""

    step: int = 0
    frac: float = 1.0   # fraction of slots hit (per-slot Bernoulli)
    jitter: int = 0     # per-slot onset delay drawn uniform in [0, jitter]


@dataclasses.dataclass(frozen=True)
class ActuatorDropout(Perturbation):
    k: int = 1                                   # actuators killed per slot
    mask: Optional[tuple] = None                 # explicit mask overrides k


@dataclasses.dataclass(frozen=True)
class SensorNoise(Perturbation):
    std: float = 0.1    # white-noise std added to every obs channel
    bias: float = 0.0   # per-slot fixed bias drawn uniform in [-bias, bias]


@dataclasses.dataclass(frozen=True)
class ParamShift(Perturbation):
    param: str = "gain"
    scale: float = 1.0  # multiplier on the named parameter
    add: float = 0.0    # additive shift (applied after the multiplier)
    spread: float = 0.0  # per-slot relative jitter on scale/add (uniform +-)


@dataclasses.dataclass(frozen=True)
class GoalSwitch(Perturbation):
    source: str = "eval"                         # "eval" | "train"
    tasks: Optional[tuple] = None                # explicit (task_dim,) task


class Schedule(NamedTuple):
    """Compiled perturbation rows: K specs x B slots, all neutral-padded."""

    onset: jax.Array      # (K, B) int32; NEVER where the spec misses a slot
    act_mask: jax.Array   # (K, B, A) multiplicative mask (neutral 1)
    obs_std: jax.Array    # (K, B) additive obs noise std (neutral 0)
    obs_bias: jax.Array   # (K, B, O) additive obs bias (neutral 0)
    p_mul: jax.Array      # (K, B, P) param multiplier (neutral 1)
    p_add: jax.Array      # (K, B, P) param additive shift (neutral 0)
    task: jax.Array       # (K, B, T) replacement task
    task_on: jax.Array    # (K, B) 1 where the row switches the task

    @property
    def num_events(self) -> int:
        return self.onset.shape[0]


def empty_schedule(env: Env, batch: int) -> Schedule:
    """A K=0 schedule (the no-perturbation rollout, same program shape-wise
    for a fixed K; used as the neutral base the compiler fills in)."""
    return _neutral(env, 0, batch)


def _neutral(env: Env, k: int, batch: int) -> Schedule:
    a, o = env.act_dim, env.obs_dim
    p = len(env.PARAM_NAMES)
    t_dim = env.train_tasks().shape[1]
    return Schedule(
        onset=jnp.full((k, batch), NEVER, jnp.int32),
        act_mask=jnp.ones((k, batch, a), jnp.float32),
        obs_std=jnp.zeros((k, batch), jnp.float32),
        obs_bias=jnp.zeros((k, batch, o), jnp.float32),
        p_mul=jnp.ones((k, batch, p), jnp.float32),
        p_add=jnp.zeros((k, batch, p), jnp.float32),
        task=jnp.zeros((k, batch, t_dim), jnp.float32),
        task_on=jnp.zeros((k, batch), jnp.float32))


def compile_schedule(env: Env, perts, key: jax.Array, batch: int) -> Schedule:
    """Draw every spec's per-slot randomization; returns the array schedule.

    Deterministic in (perts, key, batch): the same inputs give the same
    victims/onsets/magnitudes, so a scenario is reproducible data.
    """
    perts = tuple(perts)
    sched = _neutral(env, len(perts), batch)
    rows = {f: [getattr(sched, f)[i] for i in range(len(perts))]
            for f in Schedule._fields}
    for i, pert in enumerate(perts):
        k_hit, k_jit, k_body = jax.random.split(jax.random.fold_in(key, i), 3)
        hit = (jax.random.uniform(k_hit, (batch,)) < pert.frac)
        onset = pert.step + (
            jax.random.randint(k_jit, (batch,), 0, pert.jitter + 1)
            if pert.jitter else jnp.zeros((batch,), jnp.int32))
        rows["onset"][i] = jnp.where(hit, onset.astype(jnp.int32), NEVER)

        if isinstance(pert, ActuatorDropout):
            if pert.mask is not None:
                m = jnp.broadcast_to(
                    jnp.asarray(pert.mask, jnp.float32),
                    (batch, env.act_dim))
            else:
                # k distinct victims per slot: zero the first k entries of a
                # per-slot permutation of the actuator indices
                def one_mask(k_slot):
                    perm = jax.random.permutation(k_slot, env.act_dim)
                    return jnp.where(
                        jnp.isin(jnp.arange(env.act_dim), perm[:pert.k]),
                        0.0, 1.0)
                m = jax.vmap(one_mask)(jax.random.split(k_body, batch))
            rows["act_mask"][i] = m.astype(jnp.float32)
        elif isinstance(pert, SensorNoise):
            rows["obs_std"][i] = jnp.full((batch,), pert.std, jnp.float32)
            if pert.bias:
                rows["obs_bias"][i] = jax.random.uniform(
                    k_body, (batch, env.obs_dim), jnp.float32,
                    -pert.bias, pert.bias)
        elif isinstance(pert, ParamShift):
            idx = env.param_index(pert.param)
            if pert.spread:
                u = jax.random.uniform(k_body, (batch,), jnp.float32,
                                       1.0 - pert.spread, 1.0 + pert.spread)
            else:
                u = jnp.ones((batch,), jnp.float32)
            rows["p_mul"][i] = rows["p_mul"][i].at[:, idx].set(
                pert.scale * u)
            rows["p_add"][i] = rows["p_add"][i].at[:, idx].set(
                pert.add * u)
        elif isinstance(pert, GoalSwitch):
            if pert.tasks is not None:
                task = jnp.broadcast_to(
                    jnp.asarray(pert.tasks, jnp.float32),
                    (batch, rows["task"][i].shape[-1]))
            else:
                pool = (env.eval_tasks() if pert.source == "eval"
                        else env.train_tasks())
                pick = jax.random.randint(k_body, (batch,), 0, pool.shape[0])
                task = pool[pick].astype(jnp.float32)
            rows["task"][i] = task
            rows["task_on"][i] = jnp.ones((batch,), jnp.float32)
        else:
            raise TypeError(f"unknown perturbation spec {pert!r}")
    return Schedule(**{f: jnp.stack(rows[f]) if perts else getattr(sched, f)
                       for f in Schedule._fields})


# ---- application (pure, called inside the rollout scan) --------------------

def _active(schedule: Schedule, t: jax.Array) -> jax.Array:
    """(K, B) float gate: 1 where row k has fired for slot b by step t."""
    return (t >= schedule.onset).astype(jnp.float32)


def effective_state(schedule: Schedule, state: VecEnvState,
                    t: jax.Array) -> VecEnvState:
    """The env state with every fired perturbation row folded in.

    Pure data: masks compose multiplicatively, param shifts compose as
    (mul, add), the LAST fired goal switch wins.  Idempotent given the BASE
    state (the harness always applies it to the un-perturbed carry).
    """
    if schedule.num_events == 0:
        return state
    g = _active(schedule, t)                                   # (K, B)
    mask = state.actuator_mask * jnp.prod(
        jnp.where(g[:, :, None] > 0, schedule.act_mask, 1.0), axis=0)
    params = state.params * jnp.prod(
        jnp.where(g[:, :, None] > 0, schedule.p_mul, 1.0), axis=0)
    params = params + jnp.sum(g[:, :, None] * schedule.p_add, axis=0)
    task = state.task
    for k in range(schedule.num_events):                       # K is static
        on = (g[k] * schedule.task_on[k])[:, None] > 0
        task = jnp.where(on, schedule.task[k], task)
    return state._replace(actuator_mask=mask, params=params, task=task)


def transform_obs(schedule: Schedule, obs: jax.Array, t: jax.Array,
                  key: jax.Array) -> jax.Array:
    """Sensor-fault model: obs + per-slot bias + white noise, where fired."""
    if schedule.num_events == 0:
        return obs
    g = _active(schedule, t)
    bias = jnp.sum(g[:, :, None] * schedule.obs_bias, axis=0)
    std = jnp.sum(g * schedule.obs_std, axis=0)                # (B,)
    noise = jax.random.normal(jax.random.fold_in(key, t), obs.shape,
                              jnp.float32)
    return obs + bias + std[:, None] * noise
