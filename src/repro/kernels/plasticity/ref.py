"""Pure-jnp oracle for the fused dual-engine step (forward + plasticity).

Semantics of one SNN timestep for one synaptic layer — the single source of
truth the engine's ``impl="xla"`` backend runs and the Pallas kernel is
validated against:

    I        = x @ w (+ teach)             # psum stage (Forward Engine)
    v_new    = v + (I - v) * (1/tau_m)     # neuron dynamics, tau_m = 2
    spiking:   s = v_new >= v_th ; v_out = v_reset where s else v_new
    readout:   s = tanh(v_new)   ; v_out = v_new       (leaky integrator)
    tp_new   = lam * trace_post + s        # trace update
    hebb     = trace_pre^T @ tp_new / B    # Plasticity Engine (4 terms)
    dw       = a*hebb + b*mean(pre)[:,N] + g*mean(tp_new)[N,:] + d
    w_new    = clip(w + dw, -clip, clip)

`trace_pre` is the *already-updated* presynaptic trace for this timestep
(the Forward Engine's Trace Update Unit runs upstream of this layer).

Inputs may be unbatched ``(N,)`` or batched ``(B, N)``; shared weights
batch-average the update, matching ``core.plasticity.delta_w``.

`dual_engine_fleet_step` is the FLEET variant: weights carry a leading
request-stream rank ``(B, N, M)`` and every stream rewrites its own synapses
with a per-sample dw (no batch averaging) under one shared rule theta —
exactly ``vmap`` of the unbatched step over (x, w, v, traces).  On the xla
backend that vmap IS the best batched lowering (XLA turns it into batched
contractions), so the fleet oracle is defined as the vmap itself —
bit-identical to per-sample semantics by construction; the Pallas fleet
kernel re-expresses the same program as ONE launch over a (tiles, B) grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.plasticity import ALPHA, BETA, GAMMA, DELTA
from repro.kernels.plasticity import quant as Q
from repro.obs.telemetry import sat_threshold, sat_threshold_q


def _fleet_telemetry_raw(events, v_out, w_old, w_new, active, *,
                         v_th, scale=None, qcfg=None):
    """Raw per-slot telemetry sums ``(B, 3) float32`` (obs.telemetry schema).

    Computed from the already-gated fleet outputs: col 0 = sum |events|
    (event units — the fixed-point 0/``one`` grid is divided out), col 1 =
    sum |dw| in float weight units (int8 grid steps x per-slot scale on the
    quantized path), col 2 = count of membranes at >= SAT_FRACTION of
    threshold.  The whole row is multiplied by the active mask: events and
    dw are already zero for vacant slots (events gated, w frozen), but the
    frozen membrane of a vacant slot may well sit near threshold — without
    the gate col 2 would leak stale state.
    """
    if qcfg is not None:
        spike_sum = jnp.sum(jnp.abs(events), axis=1).astype(jnp.float32) \
            / qcfg.one
        dsteps = jnp.abs(w_new.astype(jnp.int32) - w_old.astype(jnp.int32))
        abs_dw = jnp.sum(dsteps, axis=(1, 2)).astype(jnp.float32) \
            * jnp.asarray(scale, jnp.float32).reshape(-1)
        sat = jnp.abs(v_out) >= sat_threshold_q(v_th, qcfg)
    else:
        spike_sum = jnp.sum(jnp.abs(events), axis=1).astype(jnp.float32)
        abs_dw = jnp.sum(
            jnp.abs(w_new.astype(jnp.float32) - w_old.astype(jnp.float32)),
            axis=(1, 2))
        sat = jnp.abs(v_out) >= sat_threshold(v_th)
    sat_cnt = jnp.sum(sat, axis=1).astype(jnp.float32)
    raw = jnp.stack([spike_sum, abs_dw, sat_cnt], axis=1)
    if active is not None:
        raw = raw * active.reshape(-1, 1).astype(jnp.float32)
    return raw


def dual_engine_step(x, w, theta, v, trace_pre, trace_post, *,
                     tau_m: float = 2.0, v_th: float = 1.0,
                     v_reset: float = 0.0, trace_decay: float = 0.8,
                     w_clip: float = 4.0, plastic: bool = True,
                     spiking: bool = True, teach=None):
    """Oracle.  Shapes: x (B,N)|(N,), w (N,M), theta (4,N,M)|None,
    v (B,M)|(M,), trace_pre (B,N)|(N,), trace_post (B,M)|(M,),
    teach (B,M)|(M,)|None.

    Returns (events, v_out, trace_post_new, w_new) with batch rank preserved.
    """
    compute = jnp.float32
    current = jnp.dot(x.astype(compute), w.astype(compute))
    if teach is not None:
        current = current + teach.astype(compute)
    v32 = v.astype(compute)
    v_new = v32 + (current - v32) * (1.0 / tau_m)
    if spiking:
        spikes = (v_new >= v_th).astype(compute)
        v_out = jnp.where(spikes > 0, v_reset, v_new)
    else:
        spikes = jnp.tanh(v_new)
        v_out = v_new
    tp_new = trace_decay * trace_post.astype(compute) + spikes

    if plastic:
        tpre = trace_pre.astype(compute)
        tpo = tp_new
        if tpre.ndim == 1:
            tpre, tpo = tpre[None], tpo[None]
        b = tpre.shape[0]
        th = theta.astype(compute)
        hebb = jnp.einsum("bi,bj->ij", tpre, tpo) / b
        pre_m = tpre.mean(0)
        post_m = tpo.mean(0)
        dw = (th[ALPHA] * hebb + th[BETA] * pre_m[:, None]
              + th[GAMMA] * post_m[None, :] + th[DELTA])
        w_new = jnp.clip(w.astype(compute) + dw, -w_clip, w_clip)
    else:
        w_new = w.astype(compute)

    return (spikes.astype(x.dtype), v_out.astype(v.dtype),
            tp_new.astype(trace_post.dtype), w_new.astype(w.dtype))


def dual_engine_fleet_step(x, w, theta, v, trace_pre, trace_post, *,
                           tau_m: float = 2.0, v_th: float = 1.0,
                           v_reset: float = 0.0, trace_decay: float = 0.8,
                           w_clip: float = 4.0, plastic: bool = True,
                           spiking: bool = True, teach=None, active=None,
                           telemetry: bool = False):
    """Fleet oracle: per-request weights, per-sample dw, shared rule.

    Shapes: x (B,N), w (B,N,M), theta (4,N,M)|None, v (B,M),
    trace_pre (B,N), trace_post (B,M), teach (B,M)|None, active (B,)|None.

    Returns (events, v_out, trace_post_new, w_new) with w_new (B,N,M).
    Defined as ``vmap(dual_engine_step)`` over the leading rank with theta
    closed over (shared, unmapped) — per-sample semantics bit-identical to
    B independent unbatched steps, and the fastest XLA lowering measured
    on CPU (hand-written batched einsums were up to 2x slower).

    ``active`` is the slot mask of the session-serving subsystem: a stream
    whose slot is inactive is a TRUE no-op — its weights, membrane, and
    postsynaptic trace come back bit-identical (the dw is gated, not merely
    small) and its output events are zero.  This is what makes continuous
    batching into a fixed-shape fleet tensor semantically correct: padded /
    vacated slots cannot drift between swap-out and the next swap-in.
    """
    assert w.ndim == 3 and x.ndim == 2, (x.shape, w.shape)
    if teach is not None and teach.ndim == 1:
        # Unbatched (M,) teaching current: same signal to every stream.
        # Without this, vmap would consume the class axis as the stream
        # axis — silently wrong whenever M == B.
        teach = jnp.broadcast_to(teach, (x.shape[0], teach.shape[0]))
    step = functools.partial(
        dual_engine_step, tau_m=tau_m, v_th=v_th, v_reset=v_reset,
        trace_decay=trace_decay, w_clip=w_clip, plastic=plastic,
        spiking=spiking)
    if teach is None:
        out = jax.vmap(
            lambda xb, wb, vb, tpb, tqb:
                step(xb, wb, theta, vb, tpb, tqb)
        )(x, w, v, trace_pre, trace_post)
    else:
        out = jax.vmap(
            lambda xb, wb, vb, tpb, tqb, tb:
                step(xb, wb, theta, vb, tpb, tqb, teach=tb)
        )(x, w, v, trace_pre, trace_post, teach)
    if active is not None:
        # Slot gating: select the OLD value wholesale for inactive streams
        # (the same computed-then-selected structure the Pallas kernel
        # uses), so the frozen state is bit-identical, not
        # recomputed-and-close.
        events, v_out, tp_new, w_new = out
        a = active.reshape(-1).astype(bool)
        assert a.shape[0] == x.shape[0], (active.shape, x.shape)
        events = jnp.where(a[:, None], events, jnp.zeros_like(events))
        v_out = jnp.where(a[:, None], v_out, v.astype(v_out.dtype))
        tp_new = jnp.where(a[:, None], tp_new,
                           trace_post.astype(tp_new.dtype))
        w_new = jnp.where(a[:, None, None], w_new, w.astype(w_new.dtype))
        out = (events, v_out, tp_new, w_new)
    if not telemetry:
        return out
    tel = _fleet_telemetry_raw(out[0], out[1], w, out[3], active, v_th=v_th)
    return out + (tel,)


# ---- fixed-point (quantized) oracle ----------------------------------------

def dual_engine_step_q(x, w, scale, theta, v, trace_pre, trace_post, *,
                       qcfg: Q.QuantConfig, v_th: float = 1.0,
                       v_reset: float = 0.0, w_clip: float = 4.0,
                       plastic: bool = True, spiking: bool = True,
                       teach=None, seed=None):
    """Fixed-point oracle (FPGA-faithful datapath; see quant.py for scheme).

    Shapes as the float oracle, but dtypes carry the mode: x (B,N)|(N,)
    int32 fixed point, w (N,M) int8, scale () f32 per-tile weight scale,
    v/traces int32 fixed point, theta (4,N,M) f32, teach int32 fixed point,
    seed () int32 (the session step counter driving the deterministic
    stochastic round).  Returns (events, v_out, trace_post_new, w_new) with
    events/v/trace int32 and w_new int8.

    Every reduction is integer (exact), every float op elementwise — this is
    what the Pallas quant kernel must (and does) match BIT-for-bit.
    """
    scale = jnp.asarray(scale, jnp.float32)
    seed = jnp.asarray(0 if seed is None else seed, jnp.int32)
    acc = jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32))   # exact psum
    i_fx = Q.current_fx(acc, scale, qcfg)
    if teach is not None:
        i_fx = i_fx + teach.astype(jnp.int32)
    events, v_out = Q.neuron_update_q(v.astype(jnp.int32), i_fx, qcfg,
                                      v_th, v_reset, spiking)
    tp_new = Q.trace_update_q(trace_post.astype(jnp.int32), events, qcfg)

    if plastic:
        tpre, tpo = trace_pre.astype(jnp.int32), tp_new
        if tpre.ndim == 1:
            tpre, tpo = tpre[None], tpo[None]
        b = tpre.shape[0]
        hebb_i = jnp.dot(tpre.T, tpo)                         # exact int32
        dw = Q.dw_from_int_reductions(hebb_i, tpre.sum(0), tpo.sum(0),
                                      theta, b, qcfg)
        n, m = w.shape
        idx = (jax.lax.broadcasted_iota(jnp.int32, (n, m), 0) * m
               + jax.lax.broadcasted_iota(jnp.int32, (n, m), 1))
        steps = Q.round_steps(dw / scale, seed, idx, qcfg)
        qmax = Q.qclip(w_clip, scale)
        w_new = jnp.clip(w.astype(jnp.int32) + steps,
                         -qmax, qmax).astype(jnp.int8)
    else:
        w_new = w

    return events, v_out, tp_new, w_new


def dual_engine_fleet_step_q(x, w, scale, theta, v, trace_pre, trace_post, *,
                             qcfg: Q.QuantConfig, v_th: float = 1.0,
                             v_reset: float = 0.0, w_clip: float = 4.0,
                             plastic: bool = True, spiking: bool = True,
                             teach=None, seed=None, active=None,
                             telemetry: bool = False):
    """Fixed-point fleet oracle: int8 per-request weights, per-slot scale.

    Shapes: x (B,N) int32, w (B,N,M) int8, scale (B,) f32, theta (4,N,M)
    f32 shared, v/traces (B,.) int32, teach (B,M)|(M,) int32 | None,
    seed (B,) int32 per-SESSION step counters (slot-independent — the
    stochastic-round stream belongs to the session, which is what makes
    evict -> re-admit-into-any-slot bit-identical), active (B,) | None.

    Defined as vmap of the unbatched quantized step (per-sample dw, shared
    theta), exactly like the float fleet oracle; inactive slots select OLD
    integer state wholesale (bit-frozen trivially — these are ints).
    """
    assert w.ndim == 3 and x.ndim == 2, (x.shape, w.shape)
    b = x.shape[0]
    scale = jnp.asarray(scale, jnp.float32)
    if scale.ndim == 0:
        scale = jnp.broadcast_to(scale, (b,))      # one scale per slot
    seed = (jnp.zeros((b,), jnp.int32) if seed is None
            else jnp.asarray(seed, jnp.int32))
    if seed.ndim == 0:
        seed = jnp.broadcast_to(seed, (b,))        # one seed per session
    if teach is not None and teach.ndim == 1:
        teach = jnp.broadcast_to(teach, (b, teach.shape[0]))
    step = functools.partial(
        dual_engine_step_q, qcfg=qcfg, v_th=v_th, v_reset=v_reset,
        w_clip=w_clip, plastic=plastic, spiking=spiking)
    if teach is None:
        out = jax.vmap(
            lambda xb, wb, sb, vb, tpb, tqb, sd:
                step(xb, wb, sb, theta, vb, tpb, tqb, seed=sd)
        )(x, w, scale, v, trace_pre, trace_post, seed)
    else:
        out = jax.vmap(
            lambda xb, wb, sb, vb, tpb, tqb, sd, tb:
                step(xb, wb, sb, theta, vb, tpb, tqb, seed=sd, teach=tb)
        )(x, w, scale, v, trace_pre, trace_post, seed, teach)
    if active is not None:
        events, v_out, tp_new, w_new = out
        a = active.reshape(-1).astype(bool)
        assert a.shape[0] == b, (active.shape, x.shape)
        events = jnp.where(a[:, None], events, jnp.zeros_like(events))
        v_out = jnp.where(a[:, None], v_out, v.astype(v_out.dtype))
        tp_new = jnp.where(a[:, None], tp_new,
                           trace_post.astype(tp_new.dtype))
        w_new = jnp.where(a[:, None, None], w_new, w)
        out = (events, v_out, tp_new, w_new)
    if not telemetry:
        return out
    tel = _fleet_telemetry_raw(out[0], out[1], w, out[3], active,
                               v_th=v_th, scale=scale, qcfg=qcfg)
    return out + (tel,)
