"""Setpoint-stabilizer task (cartpole-style regulation with redundancy).

A 1-D cart holding a setpoint against drag and a wind force, driven by TWO
redundant bidirectional thrusters (net drive = their mean).  The redundancy
makes single-thruster dropout a recoverable authority loss (the remaining
thruster must double its effort), and the ``wind`` parameter makes dynamics
shifts a *persistent* disturbance: under constant wind a proportional
controller holds a steady-state offset, so only a controller that keeps
adapting (growing its effective gain / integrating the error) regains the
setpoint — the textbook scenario separating plastic from frozen control.

Task protocol mirrors the other envs: 8 training setpoints, 72 unseen.

Perturbable dynamics params (`PARAM_NAMES`): mass, gain, drag, wind.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.envs.base import Env, EnvState


@dataclasses.dataclass(frozen=True)
class StabilizerEnv(Env):
    episode_len: int = 150
    dt: float = 0.05
    obs_dim: int = 6      # err, v, err - v, |err|, setpoint, 1
    act_dim: int = 2      # redundant thrusters; net drive = mean
    mass: float = 1.0
    gain: float = 4.0
    drag: float = 1.5
    spring: float = 1.0   # restoring pull toward x = 0 (bounds wind drift;
                          # holding any nonzero setpoint needs standing force)
    wind: float = 0.0     # constant force on the cart (dynamics shift)

    PARAM_NAMES: tuple = ("mass", "gain", "drag", "spring", "wind")

    def init_phys(self, key: jax.Array) -> jax.Array:
        # phys = [x, v]
        x0 = 0.2 * jax.random.normal(key, ())
        return jnp.stack([x0, jnp.zeros(())])

    def dynamics(self, phys: jax.Array, force: jax.Array,
                 params: Optional[jax.Array] = None) -> jax.Array:
        p = self.default_params() if params is None else params
        mass, gain, drag, spring, wind = p[0], p[1], p[2], p[3], p[4]
        x, v = phys[0], phys[1]
        drive = gain * force.mean()
        a = (drive + wind - spring * x - drag * v) / mass
        v = v + self.dt * a
        x = x + self.dt * v
        return jnp.stack([x, v])

    def observe(self, state: EnvState) -> jax.Array:
        x, v = state.phys[0], state.phys[1]
        sp = state.task[0]
        err = sp - x
        return jnp.stack([err, v, err - v, jnp.abs(err), sp,
                          jnp.ones(())])

    def reward(self, state: EnvState, action: jax.Array,
               new_phys: jax.Array) -> jax.Array:
        err = state.task[0] - new_phys[0]
        ctrl = 0.01 * jnp.sum(action ** 2)
        return -jnp.abs(err) - 0.02 * new_phys[1] ** 2 - ctrl

    def train_tasks(self) -> jax.Array:
        return jnp.linspace(-1.0, 1.0, 8)[:, None]

    def eval_tasks(self) -> jax.Array:
        # interleaved with / beyond the training grid, never colliding
        return jnp.linspace(-1.02, 1.02, 72)[:, None]
