"""Environment interface: pure reset/step functions over a pytree state."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class EnvState(NamedTuple):
    phys: jax.Array        # flat physics state vector
    task: jax.Array        # task parameter (direction / velocity / goal)
    actuator_mask: jax.Array  # (act_dim,) 1 = healthy, 0 = failed
    t: jax.Array           # step counter


@dataclasses.dataclass(frozen=True)
class Env:
    """Subclasses define obs_dim/act_dim/episode_len and _dynamics."""

    episode_len: int = 200
    dt: float = 0.05

    # --- to override -------------------------------------------------------
    obs_dim: int = 0
    act_dim: int = 0

    def init_phys(self, key: jax.Array) -> jax.Array:
        raise NotImplementedError

    def dynamics(self, phys: jax.Array, force: jax.Array) -> jax.Array:
        raise NotImplementedError

    def observe(self, state: EnvState) -> jax.Array:
        raise NotImplementedError

    def reward(self, state: EnvState, action: jax.Array,
               new_phys: jax.Array) -> jax.Array:
        raise NotImplementedError

    def train_tasks(self) -> jax.Array:
        raise NotImplementedError

    def eval_tasks(self) -> jax.Array:
        raise NotImplementedError

    # --- common ------------------------------------------------------------
    def reset(self, key: jax.Array, task: jax.Array,
              actuator_mask: jax.Array | None = None) -> EnvState:
        if actuator_mask is None:
            actuator_mask = jnp.ones((self.act_dim,))
        return EnvState(phys=self.init_phys(key), task=task,
                        actuator_mask=actuator_mask,
                        t=jnp.zeros((), jnp.int32))

    def step(self, state: EnvState, action: jax.Array) -> tuple[EnvState, jax.Array]:
        """Returns (new_state, reward).  Actions in [-1, 1]."""
        act = jnp.clip(action, -1.0, 1.0) * state.actuator_mask
        new_phys = self.dynamics(state.phys, act)
        new_state = EnvState(phys=new_phys, task=state.task,
                             actuator_mask=state.actuator_mask, t=state.t + 1)
        return new_state, self.reward(state, act, new_phys)
