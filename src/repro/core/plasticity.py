"""Four-term parametric synaptic plasticity rule (FireFly-P, Sec. II-A).

The paper's core algorithmic contribution::

    dw_ij = alpha_ij * S_j(t) * S_i(t)   (associative potentiation, Hebbian)
          + beta_ij  * S_j(t)            (presynaptic depression)
          + gamma_ij * S_i(t)            (postsynaptic homeostasis)
          + delta_ij                     (synaptic regularization / decay)

with exponentially decaying spike traces ``S(t) = lam * S(t-1) + s(t)``.

Hardware mapping note (DESIGN.md Sec. 2): the FPGA packs {alpha,beta,gamma,
delta} into one wide word so the Plasticity Engine fetches all four with a
single memory access.  We mirror that by storing theta as ONE packed array of
shape ``(4, n_pre, n_post)`` — a single HBM->VMEM DMA per tile streams every
coefficient plane (see kernels/plasticity).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# Indices into the packed theta array — keep in sync with kernels/plasticity.
ALPHA, BETA, GAMMA, DELTA = 0, 1, 2, 3
NUM_TERMS = 4


@dataclasses.dataclass(frozen=True)
class PlasticityConfig:
    """Static configuration of the plasticity rule for one synaptic layer."""

    n_pre: int
    n_post: int
    trace_decay: float = 0.8          # lam in S(t) = lam S(t-1) + s(t)
    w_clip: Optional[float] = 4.0     # |w| clamp; None disables (paper relies
                                      # on the delta term for boundedness, the
                                      # clip is an fp16-overflow guard)
    per_synapse: bool = True          # paper: theta is per-synapse (theta_ij)
    dtype: jnp.dtype = jnp.float32    # bf16/fp16 supported (paper uses fp16)

    @property
    def theta_shape(self):
        if self.per_synapse:
            return (NUM_TERMS, self.n_pre, self.n_post)
        return (NUM_TERMS,)


def init_theta(cfg: PlasticityConfig, key: jax.Array, scale: float = 0.01) -> jax.Array:
    """Initial plasticity coefficients (the object the offline ES optimizes)."""
    return (scale * jax.random.normal(key, cfg.theta_shape)).astype(cfg.dtype)


def init_traces(cfg: PlasticityConfig, batch: Optional[int] = None) -> tuple[jax.Array, jax.Array]:
    """Zeroed (pre, post) spike traces."""
    pre_shape = (cfg.n_pre,) if batch is None else (batch, cfg.n_pre)
    post_shape = (cfg.n_post,) if batch is None else (batch, cfg.n_post)
    return jnp.zeros(pre_shape, cfg.dtype), jnp.zeros(post_shape, cfg.dtype)


def update_trace(trace: jax.Array, spikes: jax.Array, decay: float) -> jax.Array:
    """S(t) = lam * S(t-1) + s(t).  (Sec. II-A, trace update.)"""
    return (decay * trace + spikes.astype(trace.dtype)).astype(trace.dtype)


def delta_w(theta: jax.Array, s_pre: jax.Array, s_post: jax.Array) -> jax.Array:
    """Evaluate the four-term rule.

    Args:
      theta:  packed ``(4, n_pre, n_post)`` (or ``(4,)`` scalar-rule) coeffs.
      s_pre:  pre-synaptic traces ``(n_pre,)`` or batched ``(B, n_pre)``.
      s_post: post-synaptic traces ``(n_post,)`` or batched ``(B, n_post)``.

    Returns:
      ``(n_pre, n_post)`` weight update (batch-averaged when inputs are
      batched — each agent in a batch is an independent plastic network only
      when vmapped; a shared-weight batch averages, as in batched MNIST
      online learning).
    """
    if s_pre.ndim == 1:
        s_pre = s_pre[None]
        s_post = s_post[None]
    b = s_pre.shape[0]
    compute = jnp.promote_types(theta.dtype, jnp.float32)
    sp = s_pre.astype(compute)
    so = s_post.astype(compute)
    th = theta.astype(compute)
    # Hebbian outer product, batch-averaged: (n_pre, n_post)
    hebb = jnp.einsum("bi,bj->ij", sp, so) / b
    pre_m = jnp.mean(sp, axis=0)    # (n_pre,)
    post_m = jnp.mean(so, axis=0)   # (n_post,)
    # Same contraction for the per-synapse (4, n_pre, n_post) and the
    # scalar-rule (4,) theta: broadcasting handles both.
    dw = (th[ALPHA] * hebb
          + th[BETA] * pre_m[:, None]
          + th[GAMMA] * post_m[None, :]
          + th[DELTA])
    return dw.astype(theta.dtype)


def apply_plasticity(w: jax.Array,
                     theta: jax.Array,
                     s_pre: jax.Array,
                     s_post: jax.Array,
                     cfg: PlasticityConfig) -> jax.Array:
    """w <- clip(w + dw).  One online plasticity step for one layer."""
    w_new = w + delta_w(theta, s_pre, s_post).astype(w.dtype)
    if cfg.w_clip is not None:
        w_new = jnp.clip(w_new, -cfg.w_clip, cfg.w_clip)
    return w_new


# ---------------------------------------------------------------------------
# Surrogate-spike plasticity for non-spiking layers (LM plastic adapters).
# The trace algebra is identical; the event source is a thresholded
# activation instead of a LIF spike (DESIGN.md Sec. 4).
# ---------------------------------------------------------------------------

def spikify(x: jax.Array, threshold: float = 0.0) -> jax.Array:
    """Binary surrogate spikes from continuous activations."""
    return (x > threshold).astype(x.dtype)
