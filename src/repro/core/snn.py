"""LIF spiking network with online plasticity (FireFly-P forward engine).

The network is a generic N-layer stack iterated through the backend-
dispatched PlasticEngine (`core.engine.layer_step`): every layer timestep —
psum matmul, neuron dynamics, trace update, AND the four-term plasticity
update — executes as ONE fused program per layer, on whichever backend
``SNNConfig.impl`` selects ("xla" oracle, "pallas" TPU kernel,
"pallas-interpret" CPU validation of the TPU kernel).

Forward Engine semantics (paper Sec. III-B):

  * psum stage:     I(t) = W^T s_in(t)              (matmul)
  * neuron stage:   V(t) = V(t-1) + (I - V(t-1))/tau_m,  tau_m = 2
                    s(t) = V(t) >= V_th ; hard reset on spike
  * trace stage:    S(t) = lam S(t-1) + s(t)

and the Scheduler's main-loop dataflow (Sec. III-C): within a timestep, layer
L's plasticity update consumes the *current* timestep's (pre, post) traces
while layer L+1's forward pass consumes layer L's fresh spikes.  On the FPGA
these overlap in time; functionally the per-layer `engine.layer_step` calls
below are exactly the data dependence the write-priority scheme enforces
(forward always reads up-to-date weights: w_{t+1} = w_t + dw_t threaded
through the scan carry).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core import plasticity as P
from repro.core.engine import NetworkState
from repro.kernels.plasticity import quant as Q
from repro.kernels.plasticity.quant import QuantConfig


@dataclasses.dataclass(frozen=True)
class LIFConfig:
    tau_m: float = 2.0        # paper: tau_m = 2 -> multiplier-free on FPGA
    v_threshold: float = 1.0
    v_reset: float = 0.0      # hard reset (see DESIGN.md Sec. 8)
    dtype: jnp.dtype = jnp.float32


def lif_step(v: jax.Array, current: jax.Array, cfg: LIFConfig) -> tuple[jax.Array, jax.Array]:
    """One LIF update.  Returns (v_new, spikes)."""
    v = v + (current.astype(v.dtype) - v) * (1.0 / cfg.tau_m)
    spikes = (v >= cfg.v_threshold).astype(v.dtype)
    v = jnp.where(spikes > 0, cfg.v_reset, v)
    return v, spikes


def leaky_readout(v: jax.Array, current: jax.Array, cfg: LIFConfig) -> jax.Array:
    """Non-spiking leaky-integrator readout (continuous actions)."""
    return v + (current.astype(v.dtype) - v) * (1.0 / cfg.tau_m)


@dataclasses.dataclass(frozen=True)
class SNNConfig:
    """Fully-connected plastic controller (paper Sec. IV-A).

    layer_sizes = (obs_dim, *hidden..., act_dim); the stack depth is generic
    — (16, 128, 8) is the paper's control net, (784, 1024, 10) MNIST.
    ``impl`` selects the PlasticEngine backend every layer step runs on.

    ``quant`` switches the whole network onto the FPGA-faithful fixed-point
    datapath (int8 weights + per-tile scale, int32 membrane/trace, integer
    weight updates — scheme in kernels/plasticity/ops.py).  Quant configs
    must set ``trace_decay`` to the power-of-two decay the hardware
    implements (``QuantConfig().decay`` = 0.75) — the engine raises a loud
    ValueError otherwise; use `quant_config()` to get a consistent pair.
    """
    layer_sizes: Sequence[int] = (16, 128, 8)
    timesteps: int = 4                      # SNN timesteps per control step
    trace_decay: float = 0.8
    lif: LIFConfig = LIFConfig()
    encoding: str = "current"               # "current" | "rate"
    spiking_readout: bool = False           # True for classification (spike counts)
    w_clip: float = 4.0
    dtype: jnp.dtype = jnp.float32
    plastic: bool = True                    # False => fixed (weight-trained) SNN
    impl: str = "xla"                       # engine backend (see engine.IMPLS)
    block_m: int = 128                      # Pallas postsynaptic tile width
    quant: Optional[QuantConfig] = None     # fixed-point mode (None = float32)
    unroll_k: int = 1                       # fused-rollout time-loop chunking
    block_b: int = 8                        # fused-rollout streams per program

    @property
    def num_layers(self) -> int:
        return len(self.layer_sizes) - 1

    def layer_plasticity_cfg(self, i: int) -> P.PlasticityConfig:
        return P.PlasticityConfig(
            n_pre=self.layer_sizes[i], n_post=self.layer_sizes[i + 1],
            trace_decay=self.trace_decay, w_clip=self.w_clip, dtype=self.dtype)

    def engine_params(self, i: int) -> engine.EngineParams:
        """Static PlasticEngine parameters for layer i."""
        last = i == self.num_layers - 1
        return engine.EngineParams(
            tau_m=self.lif.tau_m, v_th=self.lif.v_threshold,
            v_reset=self.lif.v_reset, trace_decay=self.trace_decay,
            w_clip=self.w_clip, plastic=self.plastic,
            spiking=(not last) or self.spiking_readout, block_m=self.block_m,
            quant=self.quant)


def quant_config(base: Optional[SNNConfig] = None,
                 qc: Optional[QuantConfig] = None, **overrides) -> SNNConfig:
    """An `SNNConfig` consistently switched onto the fixed-point datapath.

    Sets ``quant`` and snaps ``trace_decay``/``lif.tau_m`` to the power-of-
    two dynamics the hardware implements (the engine refuses silently
    mismatched float params).  ``base`` defaults to ``SNNConfig()``;
    ``overrides`` are forwarded to `dataclasses.replace`.
    """
    base = SNNConfig() if base is None else base
    qc = QuantConfig() if qc is None else qc
    return dataclasses.replace(
        base, quant=qc, trace_decay=qc.decay,
        lif=dataclasses.replace(base.lif, tau_m=qc.tau_m), **overrides)


def init_state(cfg: SNNConfig, batch: Optional[int] = None,
               fleet: bool = False) -> NetworkState:
    """Network state: per-layer membrane V, per-population traces, weights.

    Phase-2 deployment starts from ZERO weights (paper Sec. II-B): the rule,
    not the initialization, builds the connectivity.

    ``batch`` batches membranes/traces over B streams with SHARED weights
    (plasticity batch-averages the dw).  ``fleet=True`` additionally gives
    every stream its OWN weights ``(B, N, M)`` — B independent controllers
    stepped as one NetworkState, each request rewriting its own synapses in
    a single fused launch per layer (`engine.layer_step` fleet mode).
    """
    if fleet and batch is None:
        raise ValueError("fleet=True requires batch (one weight set per "
                         "request stream)")

    qc = cfg.quant
    w_dtype = jnp.int8 if qc is not None else cfg.dtype
    s_dtype = jnp.int32 if qc is not None else cfg.dtype

    def z(*shape, dtype=s_dtype):
        s = shape if batch is None else (batch, *shape)
        return jnp.zeros(s, dtype)

    wz = ((lambda *shape: z(*shape, dtype=w_dtype)) if fleet
          else (lambda *shape: jnp.zeros(shape, w_dtype)))
    if qc is None:
        w_scale = ()
    elif fleet:
        # per-SLOT weight scale: travels with the session through
        # gather/scatter, persistence, and restore
        w_scale = tuple(jnp.full((batch,), qc.w_scale, jnp.float32)
                        for _ in range(cfg.num_layers))
    else:
        w_scale = tuple(jnp.float32(qc.w_scale)
                        for _ in range(cfg.num_layers))
    sizes = cfg.layer_sizes
    return NetworkState(
        w=tuple(wz(sizes[i], sizes[i + 1]) for i in range(cfg.num_layers)),
        v=tuple(z(sizes[i + 1]) for i in range(cfg.num_layers)),
        trace=tuple(z(sizes[i]) for i in range(len(sizes))),
        t=jnp.zeros((), jnp.int32),
        w_scale=w_scale,
    )


def quantize_state(cfg: SNNConfig, state: NetworkState) -> NetworkState:
    """Migrate a float `NetworkState` onto the fixed-point representation.

    The sanctioned path for admitting a float32 session into an int8 pool
    (SessionStore.checkout REFUSES silently casting one): weights land on
    the int8 grid ``2**-w_frac_bits`` via `optim.compression.compress_int8`
    with that FIXED scale; membranes/traces go to int32 fixed point.
    Lossy by exactly one rounding, like any hardware deployment.
    """
    qc = cfg.quant
    if qc is None:
        raise ValueError("quantize_state needs cfg.quant set (see "
                         "snn.quant_config)")
    from repro.optim.compression import compress_int8
    leading = state.w[0].ndim == 3       # fleet pool: scale per slot
    w_q, scales = [], []
    for w in state.w:
        q, s = compress_int8(w, scale=qc.w_scale)
        w_q.append(q)
        scales.append(jnp.full((w.shape[0],), s, jnp.float32) if leading
                      else s)
    return NetworkState(
        w=tuple(w_q),
        v=tuple(Q.to_fixed(v, qc) for v in state.v),
        trace=tuple(Q.to_fixed(tr, qc) for tr in state.trace),
        t=state.t, w_scale=tuple(scales))


def init_theta(cfg: SNNConfig, key: jax.Array, scale: float = 0.01):
    keys = jax.random.split(key, cfg.num_layers)
    return [P.init_theta(cfg.layer_plasticity_cfg(i), keys[i], scale)
            for i in range(cfg.num_layers)]


def theta_size(cfg: SNNConfig) -> int:
    return sum(P.NUM_TERMS * cfg.layer_sizes[i] * cfg.layer_sizes[i + 1]
               for i in range(cfg.num_layers))


def flatten_theta(theta) -> jax.Array:
    return jnp.concatenate([t.reshape(-1) for t in theta])


def unflatten_theta(cfg: SNNConfig, flat: jax.Array):
    out, off = [], 0
    for i in range(cfg.num_layers):
        shape = (P.NUM_TERMS, cfg.layer_sizes[i], cfg.layer_sizes[i + 1])
        n = shape[0] * shape[1] * shape[2]
        out.append(flat[off:off + n].reshape(shape).astype(cfg.dtype))
        off += n
    return out


def _check_encode_key(cfg: SNNConfig, key: Optional[jax.Array]) -> None:
    """Entry-level guard: stochastic rate encoding needs a PRNG key.

    Without this, ``jax.random.fold_in(None, t)`` fails deep inside the
    scan body with an opaque error."""
    if cfg.encoding == "rate" and key is None:
        raise ValueError(
            'encoding="rate" draws Bernoulli spike trains and requires a '
            "PRNG key; pass key=jax.random.PRNGKey(...) to this call "
            '(or use encoding="current" for deterministic analog drive)')


def encode(cfg: SNNConfig, obs: jax.Array, key: Optional[jax.Array], t: jax.Array) -> jax.Array:
    """Observation -> input drive for one timestep."""
    if cfg.encoding == "rate":
        _check_encode_key(cfg, key)
        p = jnp.clip(jnp.abs(obs), 0.0, 1.0)
        u = jax.random.uniform(jax.random.fold_in(key, t), obs.shape)
        return (u < p).astype(cfg.dtype) * jnp.sign(obs).astype(cfg.dtype)
    return obs.astype(cfg.dtype)  # analog current injection


def timestep(cfg: SNNConfig, state: NetworkState, theta, drive: jax.Array,
             teach: Optional[jax.Array] = None,
             active: Optional[jax.Array] = None,
             seed: Optional[jax.Array] = None,
             telemetry: bool = False
             ) -> tuple[NetworkState, jax.Array]:
    """One SNN timestep: every layer routed through the PlasticEngine.

    Mirrors the Scheduler main loop: each layer's fused `engine.layer_step`
    consumes the fresh spikes of its predecessor; its plasticity update
    consumes the traces of the *current* timestep (Phase A/B of Sec. III-C
    collapsed to dataflow).  Returns (new_state, output) where output is the
    readout activity (spikes, or membrane potential for the leaky readout).

    `teach`: optional teaching current injected into the OUTPUT layer
    (supervised online learning — drives the postsynaptic trace so the
    Hebbian term binds features to the labelled class, the standard
    supervised-STDP protocol used for the paper's MNIST task).

    Fleet states (``init_state(batch=B, fleet=True)``: per-request weights
    ``(B, N, M)``) take the same code path — the engine detects the weight
    rank and runs all B controllers as one fused launch per layer.

    `active`: optional fleet-only ``(B,)`` slot mask (session serving).
    Inactive streams are frozen bit-exactly through EVERY layer — the input
    trace update here is gated the same way the engine gates each layer's
    state writes — so a vacated slot of a fixed-shape pool cannot drift
    between swap-out and the next swap-in.  ``state.t`` is the shared pool
    clock and still advances; per-session step counts are the scheduler's
    (host-side) bookkeeping.

    `seed` (fixed-point mode): the step counter driving the deterministic
    stochastic round of dw — scalar, or ``(B,)`` per-SESSION counters in
    fleet serving (the scheduler passes its per-slot step counts, so a
    session's rounding stream follows the session, not the pool clock).
    Defaults to the shared ``state.t``.  Float mode ignores it.

    In quant mode `drive`/`teach` are ordinary floats — quantized to the
    fixed-point event bus here — and the returned output is dequantized
    back to float, so callers (controller_step, classify_window, the
    scheduler) are representation-agnostic.

    `telemetry` (fleet-only, static): also return a network-level
    `FleetTelemetry` — per-layer engine telemetry averaged over the
    layers (spike rate / saturation over all layers, |dw| over the
    plastic ones) — as a third element.  Off (the default) leaves the
    traced program byte-identical to the uninstrumented build.
    """
    qc = cfg.quant
    w, v, tr = list(state.w), list(state.v), list(state.trace)
    if qc is not None:
        x = Q.to_fixed(drive, qc)
        teach = None if teach is None else Q.to_fixed(teach, qc)
        base_seed = (jnp.asarray(seed, jnp.int32) if seed is not None
                     else state.t.astype(jnp.int32))
        # input trace: integer decay + accumulate (same datapath as layers)
        tr0_new = Q.trace_update_q(tr[0], x, qc)
    else:
        x = drive
        base_seed = None
        # input trace: input drive acts as the presynaptic event for L1
        tr0_new = P.update_trace(tr[0], x, cfg.trace_decay)
    if active is not None:
        tr0_new = jnp.where(active.astype(bool)[:, None], tr0_new, tr[0])
    tr[0] = tr0_new
    out = None
    tels = []
    for i in range(cfg.num_layers):
        last = i == cfg.num_layers - 1
        layer = engine.LayerState(
            w=w[i], v=v[i], trace_pre=tr[i], trace_post=tr[i + 1],
            theta=theta[i] if cfg.plastic else None,
            w_scale=state.w_scale[i] if state.w_scale else None)
        res = engine.layer_step(
            layer, x, params=cfg.engine_params(i), impl=cfg.impl,
            teach=teach if last else None, active=active,
            seed=None if base_seed is None else Q.fold_seed(base_seed, i),
            telemetry=telemetry)
        layer, out = res[0], res[1]
        if telemetry:
            tels.append(res[2])
        w[i], v[i], tr[i + 1] = layer.w, layer.v, layer.trace_post
        x = out
    if qc is not None:
        out = Q.from_fixed(out, qc)
    new_state = NetworkState(w=tuple(w), v=tuple(v), trace=tuple(tr),
                             t=state.t + 1, w_scale=state.w_scale)
    if not telemetry:
        return new_state, out
    nl = float(cfg.num_layers)
    tel = engine.FleetTelemetry(
        spike_rate=sum(t.spike_rate for t in tels) / nl,
        mean_abs_dw=(sum(t.mean_abs_dw for t in tels) / nl
                     if cfg.plastic else jnp.zeros_like(tels[0].spike_rate)),
        sat_frac=sum(t.sat_frac for t in tels) / nl,
        occupancy=tels[0].occupancy)
    return new_state, out, tel


def rollout_window(cfg: SNNConfig, state: NetworkState, theta,
                   drives: jax.Array,
                   teach: Optional[jax.Array] = None,
                   active: Optional[jax.Array] = None,
                   seed: Optional[jax.Array] = None,
                   telemetry: bool = False
                   ) -> tuple[NetworkState, jax.Array]:
    """K SNN timesteps as ONE fused engine launch (`engine.rollout`).

    The time-fused counterpart of K `timestep` calls: on the Pallas
    backends the whole (K timesteps x num_layers) window runs as a single
    `pallas_call` with membranes, traces, and weights VMEM-resident across
    the window; on ``impl="xla"`` it scans the per-step oracle, so swapping
    a timestep loop for `rollout_window` never changes the bits.

    ``drives`` is time-major — (K, N_in) or (K, B, N_in) — already encoded
    (see `encode`).  `teach`/`active`/`seed`/`telemetry` follow the
    `timestep` contracts (telemetry: fleet-only, window-averaged
    `FleetTelemetry` as a third element); ``teach`` may be one held
    signal or a per-step (K, ...) window (rank-dispatched by
    `engine.rollout`).  Like `timestep`, in
    quant mode `drives`/`teach` are ordinary floats quantized to the
    fixed-point event bus here and the returned outputs are dequantized,
    so callers stay representation-agnostic.
    """
    qc = cfg.quant
    if qc is not None:
        drives = Q.to_fixed(drives, qc)
        teach = None if teach is None else Q.to_fixed(teach, qc)
    params = [cfg.engine_params(i) for i in range(cfg.num_layers)]
    th = [theta[i] if cfg.plastic else None for i in range(cfg.num_layers)]
    res = engine.rollout(
        state, th, drives, params=params, impl=cfg.impl, teach=teach,
        active=active, seed=seed, unroll_k=cfg.unroll_k, block_b=cfg.block_b,
        telemetry=telemetry)
    state, outs = res[0], res[1]
    if qc is not None:
        outs = Q.from_fixed(outs, qc)
    if telemetry:
        return state, outs, res[2]
    return state, outs


def encode_window(cfg: SNNConfig, obs: jax.Array, key: Optional[jax.Array],
                  t0: jax.Array, k: Optional[int] = None) -> jax.Array:
    """Encode a held observation into a time-major (K, ...) drive window.

    Reproduces exactly the per-step `encode(cfg, obs, key, t)` sequence a
    timestep loop would draw for t = t0, t0+1, ..., so precomputing the
    window for `rollout_window` is bit-neutral (rate encoding folds the
    same per-step counters into the PRNG key)."""
    k = cfg.timesteps if k is None else k
    ts = t0 + jnp.arange(k)
    return jax.vmap(lambda t: encode(cfg, obs, key, t))(ts)


def controller_step(cfg: SNNConfig, state: NetworkState, theta, obs: jax.Array,
                    key: Optional[jax.Array] = None) -> tuple[NetworkState, jax.Array]:
    """One control step = cfg.timesteps SNN timesteps on a held observation.

    The whole window runs as one fused `rollout_window` launch (a single
    `pallas_call` on the Pallas backends).  Returns (state, action) with
    action = mean readout over the window.
    """
    _check_encode_key(cfg, key)
    drives = encode_window(cfg, obs, key, state.t)
    state, outs = rollout_window(cfg, state, theta, drives)
    action = outs.mean(axis=0)
    if not cfg.spiking_readout:
        action = jnp.tanh(action)
    return state, action


def classify_window(cfg: SNNConfig, state: NetworkState, theta, x: jax.Array,
                    key: Optional[jax.Array] = None,
                    teach: Optional[jax.Array] = None) -> tuple[NetworkState, jax.Array]:
    """Present x for cfg.timesteps; return (state, class scores = spike counts).

    With `teach` (e.g. `label_onehot * amplitude`) the output population is
    driven toward the labelled class during the window, so the plasticity
    rule performs supervised online learning.  The window is one fused
    `rollout_window` launch with the teaching current held across it."""
    _check_encode_key(cfg, key)
    drives = encode_window(cfg, x, key, state.t)
    state, outs = rollout_window(cfg, state, theta, drives, teach=teach)
    return state, outs.sum(axis=0)
