"""Forward Engine Pallas kernel: psum-stationary blocked matmul + LIF + trace.

Unlike kernels/plasticity (which holds the whole fan-in per tile), this
kernel demonstrates the paper's psum-stationary dataflow literally: the grid
walks (m, k) tiles with k innermost; an fp32 VMEM scratch accumulator plays
the role of the PE psum registers — input current accumulates locally and
only touches the output (neuron state) once, after the last k tile, exactly
like the FPGA's "accumulate in PE registers to minimize on-chip memory
access".  Neuron dynamics + trace update fire on the epilogue tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lif_kernel(x_ref, w_ref, v_ref, tr_ref, s_out, v_out, tr_out, acc_ref,
                *, tau_m, v_th, v_reset, trace_decay, n_k):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # psum-stationary accumulation (PE-register analogue)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        current = acc_ref[...]
        v = v_ref[...].astype(jnp.float32)
        v_new = v + (current - v) * (1.0 / tau_m)
        spikes = (v_new >= v_th).astype(jnp.float32)
        v_upd = jnp.where(spikes > 0, v_reset, v_new)
        s_out[...] = spikes.astype(s_out.dtype)
        v_out[...] = v_upd.astype(v_out.dtype)
        tr_out[...] = (trace_decay * tr_ref[...].astype(jnp.float32)
                       + spikes).astype(tr_out.dtype)


def lif_forward_pallas(x, w, v, trace, *, tau_m: float = 2.0,
                       v_th: float = 1.0, v_reset: float = 0.0,
                       trace_decay: float = 0.8, block_m: int = 128,
                       block_k: int = 128, interpret: bool = False):
    b, kdim = x.shape
    _, m = w.shape
    bm, bk = min(block_m, m), min(block_k, kdim)
    # Pad the contraction dim to a block multiple: out-of-bounds tile reads
    # are undefined (NaN in interpret mode) and K-padding feeds the psum.
    k_pad = (-kdim) % bk
    if k_pad:
        x = jnp.pad(x, ((0, 0), (0, k_pad)))
        w = jnp.pad(w, ((0, k_pad), (0, 0)))
        kdim += k_pad
    n_k = pl.cdiv(kdim, bk)
    grid = (pl.cdiv(m, bm), n_k)  # k innermost => acc persists across k tiles

    kernel = functools.partial(
        _lif_kernel, tau_m=tau_m, v_th=v_th, v_reset=v_reset,
        trace_decay=trace_decay, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, bk), lambda j, k: (0, k)),
            pl.BlockSpec((bk, bm), lambda j, k: (k, j)),
            pl.BlockSpec((b, bm), lambda j, k: (0, j)),
            pl.BlockSpec((b, bm), lambda j, k: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((b, bm), lambda j, k: (0, j)),
            pl.BlockSpec((b, bm), lambda j, k: (0, j)),
            pl.BlockSpec((b, bm), lambda j, k: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), x.dtype),
            jax.ShapeDtypeStruct((b, m), v.dtype),
            jax.ShapeDtypeStruct((b, m), trace.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((b, bm), jnp.float32)],
        interpret=interpret,
    )(x, w, v, trace)
