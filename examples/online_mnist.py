"""Online learning on streaming digits via the dual-engine pipeline.

    PYTHONPATH=src python examples/online_mnist.py

The paper's Table II scenario: the 784-1024-10 network processes a digit
stream while its synapses update online — forward and plasticity execute
as ONE fused program per timestep (the dual-engine overlap), so learning
adds no separate pass over the weights.
"""
import time

import jax
import jax.numpy as jnp

from repro.core import plasticity as P, snn
from repro.data import mnist_batch, spike_encode
from repro.kernels import dual_engine_step

CFG = snn.SNNConfig(layer_sizes=(784, 256, 10), timesteps=6,
                    spiking_readout=True)


@jax.jit
def fused_timestep(carry, x):
    w1, w2, th1, th2, v1, v2, tr0, tr1, tr2 = carry
    tr0 = P.update_trace(tr0, x, CFG.trace_decay)
    s1, v1, tr1, w1 = dual_engine_step(x, w1, th1, v1, tr0, tr1)
    s2, v2, tr2, w2 = dual_engine_step(s1, w2, th2, v2, tr1, tr2)
    return (w1, w2, th1, th2, v1, v2, tr0, tr1, tr2), s2


def main():
    key = jax.random.PRNGKey(0)
    state = snn.init_state(CFG, batch=1)
    theta = snn.init_theta(CFG, key, scale=0.05)
    carry = (state["w"][0], state["w"][1], theta[0], theta[1],
             state["v"][0], state["v"][1], *state["trace"])

    imgs, labels = mnist_batch(key, 32)
    t0 = time.time()
    frames = 0
    drift = []
    for i in range(imgs.shape[0]):
        sp = spike_encode(jax.random.fold_in(key, i), imgs[i], CFG.timesteps)
        counts = jnp.zeros((10,))
        w_before = carry[0]
        for t in range(CFG.timesteps):
            carry, s2 = fused_timestep(carry, sp[t][None])
            counts = counts + s2[0]
        drift.append(float(jnp.abs(carry[0] - w_before).mean()))
        frames += 1
    dt = time.time() - t0
    print(f"processed {frames} digits in {dt:.2f}s "
          f"({frames/dt:.1f} FPS end-to-end incl. learning, CPU)")
    print(f"mean |dW| per frame (online plasticity active): "
          f"{sum(drift)/len(drift):.5f}")
    print("weights started at zero; the stream itself built the synapses.")


if __name__ == "__main__":
    main()
