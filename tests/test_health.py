"""Session-health pins: flight recorder, streaming detectors, quarantine ->
rollback remediation (src/repro/obs/health.py, obs/recorder.py, and the
schedulers' ``record=`` trace variants).

The contracts this file locks down (DESIGN.md §Health):

  1. DETECTOR ORACLES — each of the four streaming detectors (ewma_z,
     bound, stuck, dead) fires exactly at its hysteresis count, LATCHES
     once flagged, respects warmup gating (bound alone fires cold), and
     holds inactive slots' state bit-exactly with streaks reset.  The EWMA
     baseline is WINSORIZED-robust: a z-firing sample teaches it only a
     clipped ±z_threshold·sigma deviation, so a sustained fault cannot
     drag the mean under itself within a hysteresis streak, while a
     recurring clean burst re-teaches the variance and stops firing.
  2. RECORDER MECHANICS — the (B, W, C) ring wraps and unrolls
     oldest->newest, wnorm0 latches at a slot's FIRST ACTIVE step (drift
     channel starts at exactly 0), `reset_slot` zeroes one slot's rows
     only, and inactive slots record exact zeros.
  3. RECORD IS FREE WHEN OFF — ``record=True`` pool stepping leaves the
     fleet state and outputs BITWISE identical to ``record=False`` on xla
     AND pallas-interpret, float32 AND int8; without ``health=`` it raises.
  4. THE INCIDENT DRILL (the headline): clean warmup -> health_checkpoint
     -> injected drive blowout -> flagged within the hysteresis budget ->
     remediate (quarantine + incident dump + rollback) -> the session's
     continuation is BITWISE identical to a manual evict-before-incident /
     re-admit control run — with ZERO recompiles under the armed watchdog
     and the compile-audit dict pinned exactly.
  5. QUARANTINE SEMANTICS — a quarantined slot is bit-frozen like a vacant
     one; evict/save_pool/LRU-admission refuse quarantined sessions;
     rollback demands a prior quarantine; lost slots are drain_failed's
     business, not quarantine's.
  6. LM POOL PARITY — quarantine/rollback on the decode pool: frozen
     decode steps leave the session row bit-unchanged and the rolled-back
     stream's tokens match the manual-control run exactly.
  7. PLUMBING — `serve_metrics` serves real HTTP (prom text + JSON + 404),
     anomaly presets are deterministic and validated, and the
     fault-tolerant runner's registry counters reconcile with its events.
"""
import dataclasses
import json
import os
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import snn
from repro.distributed.ft import FaultTolerantRunner
from repro.kernels.plasticity import quant as Q
from repro.models import factory
from repro.obs import MetricsRegistry, serve_metrics
from repro.obs.health import (CHANNELS, DETECTORS, HealthConfig, HealthState,
                              health_update, init_health)
from repro.obs.recorder import (init_recorder, recorder_update, reset_slot,
                                unroll_ring)
from repro.obs.watchdog import watchdog as watch
from repro.scenarios import ANOMALIES, AnomalyPreset, inject_anomaly
from repro.serving import FleetScheduler
from repro.serving.lm import LMScheduler

IMPLS = ["xla", "pallas-interpret"]
DATAPATHS = ["float32", "int8"]

_OFF = 1e9      # an "effectively disabled" threshold / corridor edge
_NEVER = 9999   # an "effectively disabled" hysteresis count


def _np(x):
    return np.asarray(jax.device_get(x))


def _trees_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(_np(x), _np(y)),
                 a, b)


def _hcfg(**kw):
    """HealthConfig with every detector disabled; kwargs turn them on."""
    base = dict(window=8, warmup=0, z_threshold=_OFF,
                bounds=((-_OFF, _OFF),) * 4, dead_floor=-1.0,
                hysteresis=(_NEVER,) * 4)
    base.update(kw)
    return HealthConfig(**base)


# ---------------------------------------------------------------------------
# 1. detector oracles (pure health_update)
# ---------------------------------------------------------------------------

class TestHealthConfigValidation:
    def test_rejects_bad_shapes(self):
        with pytest.raises(ValueError):
            HealthConfig(window=0)
        with pytest.raises(ValueError):
            HealthConfig(bounds=((0.0, 1.0),) * 3)
        with pytest.raises(ValueError):
            HealthConfig(hysteresis=(1, 1, 1))
        with pytest.raises(ValueError):
            HealthConfig(hysteresis=(1, 1, 1, 0))


def _x(rows):
    return jnp.asarray(rows, jnp.float32)


class TestHealthUpdate:
    def test_hysteresis_counts_consecutive_fires_only(self):
        """bound must fire hysteresis=3 CONSECUTIVE steps: two fires, a
        clean step (streak resets), two more fires -> still unflagged;
        the third consecutive fire flags."""
        cfg = _hcfg(bounds=((0.0, 1.0),) + ((-_OFF, _OFF),) * 3,
                    hysteresis=(_NEVER, 3, _NEVER, _NEVER))
        h = init_health(cfg, 2)
        act = jnp.ones(2)
        bad = _x([[2.0, 0, 0, 0], [0.5, 0, 0, 0]])
        ok = _x([[0.5, 0, 0, 0], [0.5, 0, 0, 0]])
        for xs in (bad, bad, ok, bad, bad):
            h, verdict = health_update(cfg, h, xs, act)
            assert not _np(verdict).any()
        h, verdict = health_update(cfg, h, bad, act)
        assert _np(verdict).tolist() == [True, False]
        assert _np(h.flagged)[0, DETECTORS.index("bound")]

    def test_flags_latch_after_signal_normalizes(self):
        cfg = _hcfg(bounds=((0.0, 1.0),) + ((-_OFF, _OFF),) * 3,
                    hysteresis=(_NEVER, 1, _NEVER, _NEVER))
        h = init_health(cfg, 1)
        h, verdict = health_update(cfg, h, _x([[2.0, 0, 0, 0]]),
                                   jnp.ones(1))
        assert _np(verdict).all()
        for _ in range(5):
            h, verdict = health_update(cfg, h, _x([[0.5, 0, 0, 0]]),
                                       jnp.ones(1))
            assert _np(verdict).all()
            assert _np(h.streaks)[0, DETECTORS.index("bound")] == 0

    def test_warmup_gates_z_stuck_dead_but_not_bound(self):
        """Before ``warmup`` recorded steps only the absolute corridor may
        fire; once warm, the same frozen/dead/anomalous sample trips
        stuck, dead, and ewma_z too."""
        cfg = _hcfg(warmup=3,
                    bounds=((-_OFF, _OFF), (0.0, 1.0)) + ((-_OFF, _OFF),) * 2,
                    z_threshold=6.0, dead_floor=1e-5,
                    hysteresis=(1, 1, 1, 1))
        h = init_health(cfg, 1)
        xs = _x([[0.0, 2.0, 0, 0]])  # 0 spike rate, dw out of corridor, frozen
        for step in range(6):
            h, _ = health_update(cfg, h, xs, jnp.ones(1))
            flags = {d for i, d in enumerate(DETECTORS)
                     if _np(h.flagged)[0, i]}
            if step < 2:            # stuck needs one prior sample anyway
                assert flags == {"bound"}, (step, flags)
        assert flags == set(DETECTORS), flags

    def test_inactive_slots_hold_state_bit_exactly(self):
        cfg = _hcfg(warmup=0, hysteresis=(2, 2, 2, 2))
        h = init_health(cfg, 2)
        rng = np.random.RandomState(0)
        for _ in range(4):
            h, _ = health_update(cfg, h, _x(rng.rand(2, 4)), jnp.ones(2))
        before = jax.device_get(h)
        # slot 1 goes inactive; its sample arrives as exact zeros (the
        # recorder's gating) and must teach/fire nothing
        h, verdict = health_update(
            cfg, h, _x(np.stack([rng.rand(4), np.zeros(4)])),
            jnp.asarray([1.0, 0.0]))
        after = jax.device_get(h)
        for field in ("ewma_mean", "ewma_var", "last", "flagged", "steps"):
            np.testing.assert_array_equal(
                getattr(before, field)[1], getattr(after, field)[1])
        assert after.streaks[1].tolist() == [0, 0, 0, 0]
        assert not _np(verdict)[1]

    def test_winsorized_baseline_bounds_anomaly_chase(self):
        """A z-firing sample still teaches the EWMA, but only a clipped
        ±z_threshold·sigma deviation: each step's mean move is EXACTLY
        alpha·z_threshold·sigma (never the naive alpha·d chase), so the
        z-score stays above threshold for the whole hysteresis streak and
        the flag latches before the baseline reaches the anomaly."""
        cfg = _hcfg(z_threshold=3.0, warmup=2,
                    hysteresis=(4, _NEVER, _NEVER, _NEVER))
        h = init_health(cfg, 1)
        clean = _x([[1.0, 1.0, 1.0, 1.0]])
        for _ in range(10):
            h, _ = health_update(cfg, h, clean, jnp.ones(1))
        anom = _x([[5.0, 5.0, 5.0, 5.0]])
        a, k = cfg.ewma_alpha, cfg.z_threshold
        for step in range(4):
            mean_pre = _np(h.ewma_mean).copy()
            sigma_pre = np.sqrt(_np(h.ewma_var) + cfg.z_floor ** 2)
            # the sample fires on every step of the streak...
            assert (5.0 - mean_pre > k * sigma_pre).all()
            h, verdict = health_update(cfg, h, anom, jnp.ones(1))
            # ...so the update is the exact winsorized step, not naive EWMA
            np.testing.assert_allclose(
                _np(h.ewma_mean), mean_pre + a * k * sigma_pre, rtol=1e-5)
            assert bool(_np(verdict)[0]) == (step == 3)
        assert _np(h.flagged)[0, DETECTORS.index("ewma_z")]
        # naive chasing would have the mean at ~3.3 by now
        assert (_np(h.ewma_mean) < 2.5).all()

    def test_winsorized_baseline_absorbs_recurring_bursts(self):
        """The flip side of winsorization: a legitimately bimodal channel
        (quiet baseline with recurring bursts — e.g. a tiny adapter's
        quantized spike rate jumping 0 <-> 0.25) fires ewma_z at most a
        couple of consecutive steps before the grown variance absorbs the
        burst; with hysteresis 3 it never flags.  A hard robust gate
        (firing samples never teach) latches here forever."""
        cfg = _hcfg(z_threshold=6.0, warmup=4,
                    hysteresis=(3, _NEVER, _NEVER, _NEVER))
        h = init_health(cfg, 1)
        quiet = _x([[0.0, 0.0, 0.0, 0.0]])
        burst = _x([[0.25, 0.1, 0.875, 0.5]])
        for _ in range(8):
            h, _ = health_update(cfg, h, quiet, jnp.ones(1))
        for cyc in range(6):
            for xs in (burst, burst, burst, quiet, quiet):
                h, verdict = health_update(cfg, h, xs, jnp.ones(1))
                assert not _np(verdict)[0], cyc
        assert not _np(h.flagged).any()


# ---------------------------------------------------------------------------
# 2. recorder mechanics
# ---------------------------------------------------------------------------

class TestRecorder:
    def test_ring_wraps_and_unrolls_oldest_to_newest(self):
        cfg = _hcfg(window=4)
        rec = init_recorder(cfg, 1)
        for t in range(6):
            # last column is the raw weight norm; keep it constant so the
            # drift channel stays 0 and channel 0 carries the step stamp
            ch = _x([[float(t + 1), 0.0, 0.0, 5.0]])
            rec, _ = recorder_update(cfg, rec, ch, jnp.int32(t), jnp.ones(1))
        hist = unroll_ring(_np(rec.ring[0]), pos=6, window=4)
        assert hist.shape == (4, len(CHANNELS))
        np.testing.assert_array_equal(hist[:, 0], [3.0, 4.0, 5.0, 6.0])
        # partial fill: only pos rows exist; empty before any write
        short = unroll_ring(_np(rec.ring[0]), pos=2, window=4)
        assert short.shape == (2, len(CHANNELS))
        assert unroll_ring(_np(rec.ring[0]), pos=0, window=4).shape[0] == 0

    def test_wnorm0_latches_at_first_active_step(self):
        cfg = _hcfg()
        rec = init_recorder(cfg, 2)
        # slot 1 inactive on the first step: no latch, row records zeros
        rec, _ = recorder_update(cfg, rec, _x([[0.1, 0, 0, 3.0],
                                               [0.9, 0, 0, 9.0]]),
                                 jnp.int32(0), jnp.asarray([1.0, 0.0]))
        assert _np(rec.wnorm0).tolist() == [3.0, 0.0]
        np.testing.assert_array_equal(_np(rec.ring)[1, 0], np.zeros(4))
        # drift channel is |wnorm - wnorm0| -> exactly 0 at the latch step
        assert _np(rec.ring)[0, 0, CHANNELS.index("wnorm_drift")] == 0.0
        # slot 1's first ACTIVE step latches ITS norm; slot 0 drifts
        rec, _ = recorder_update(cfg, rec, _x([[0.1, 0, 0, 3.5],
                                               [0.9, 0, 0, 7.0]]),
                                 jnp.int32(1), jnp.ones(2))
        assert _np(rec.wnorm0).tolist() == [3.0, 7.0]
        drift = _np(rec.ring)[:, 1, CHANNELS.index("wnorm_drift")]
        np.testing.assert_allclose(drift, [0.5, 0.0], atol=1e-7)

    def test_reset_slot_zeroes_one_row_only(self):
        cfg = _hcfg()
        rec = init_recorder(cfg, 2)
        for t in range(3):
            rec, _ = recorder_update(cfg, rec,
                                     _x(np.full((2, 4), t + 1.0)),
                                     jnp.int32(t), jnp.ones(2))
        keep = jax.tree.map(lambda a: _np(a)[1].copy(), rec)
        rec2 = reset_slot(rec, jnp.int32(0))
        for leaf in jax.tree.leaves(jax.tree.map(lambda a: _np(a)[0], rec2)):
            assert not np.any(leaf)
        _trees_equal(keep, jax.tree.map(lambda a: _np(a)[1], rec2))


# ---------------------------------------------------------------------------
# fleet fixtures
# ---------------------------------------------------------------------------

def _sched(impl="xla", datapath="float32", slots=4, health=None):
    quant = datapath == "int8"
    cfg = snn.SNNConfig(layer_sizes=(8, 12, 4), timesteps=3, plastic=True,
                        encoding="current", impl=impl,
                        trace_decay=0.75 if quant else 0.8,
                        quant=Q.QuantConfig() if quant else None)
    theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.05)
    return FleetScheduler(cfg, theta, slots=slots, health=health)


def _clean_drive(uid: str, t: int = 0) -> np.ndarray:
    """Per-user clean drive, CONSTANT across steps (like the obs_health
    benchmark's): on this tiny discrete-spiking net a per-step-varying
    drive makes the telemetry channels jump between quantized levels,
    which is exactly the kind of shift ewma_z exists to flag — a held
    drive keeps the clean baseline stationary."""
    seed = (sum(ord(c) for c in uid) * 131) & 0x7FFFFFFF
    rng = np.random.RandomState(seed)
    return (0.5 * rng.standard_normal(8)).astype(np.float32)


def _own_step_drives(sched, anomalous=None, preset=None):
    """Clean drives keyed on each session's OWN step counter (so a rolled-
    back session replays the same stream its control twin sees)."""
    drives = {}
    for uid, slot in sched.user_slot.items():
        t = int(sched._steps[slot])
        d = _clean_drive(uid, t)
        if uid == anomalous:
            d = inject_anomaly(preset, d, t)
        drives[uid] = d
    return drives


# ---------------------------------------------------------------------------
# 3. record= is a free static variant
# ---------------------------------------------------------------------------

class TestRecordVariant:
    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("datapath", DATAPATHS)
    def test_record_off_bitwise_identical(self, impl, datapath):
        """record=True must not perturb the computation: per-step outputs
        and the final fleet state are BITWISE equal to record=False."""
        a = _sched(impl, datapath, health=HealthConfig())
        b = _sched(impl, datapath, health=HealthConfig())
        for s in (a, b):
            s.admit("u0")
            s.admit("u1")
        for t in range(4):
            drives = {u: _clean_drive(u, t) for u in ("u0", "u1")}
            off = a.step(drives)
            on = b.step(drives, record=True)
            for u in off:
                np.testing.assert_array_equal(_np(off[u]), _np(on[u]))
        # the windowed path too (one fused rollout launch per pool_step)
        drives = {u: _clean_drive(u, 99) for u in ("u0", "u1")}
        off = a.pool_step(drives)
        on = b.pool_step(drives, record=True)
        for u in off:
            np.testing.assert_array_equal(_np(off[u]), _np(on[u]))
        _trees_equal(a.fleet, b.fleet)
        assert b.last_verdict is not None and a.last_verdict is None
        assert a.compiled_programs()["pool_step_record"] == 0
        assert b.compiled_programs()["pool_step_record"] == 1
        assert b.compiled_programs()["pool_rollout_record"] == 1

    def test_record_without_health_raises(self):
        sched = _sched()
        sched.admit("u0")
        with pytest.raises(ValueError, match="health=HealthConfig"):
            sched.step({"u0": _clean_drive("u0", 0)}, record=True)


# ---------------------------------------------------------------------------
# 4. the incident drill
# ---------------------------------------------------------------------------

# dead_floor sits two decades under the clean spike rates (~0.3-0.6) but
# above the int8 pool's stochastic-rounding noise floor (~1.5e-3 — rare
# quantization-dither spikes keep the rate from reaching exactly 0)
DRILL_HCFG = HealthConfig(warmup=8, z_threshold=_OFF,
                          bounds=((0.0, _OFF),) * 4, dead_floor=1e-2,
                          hysteresis=(_NEVER, _NEVER, _NEVER, 2))
WARM, CONT = 12, 6


class TestIncidentDrill:
    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("datapath", DATAPATHS)
    def test_flag_quarantine_rollback_bit_identity(self, impl, datapath,
                                                   tmp_path):
        """The end-to-end incident drill: clean recorded warmup ->
        health_checkpoint -> an injected dead input collapses the
        session's spike rate and flags it within its hysteresis budget -> remediate (quarantine + flight
        dump + rollback) -> the session's continuation is bitwise
        identical to a manual evict-at-checkpoint control run, with zero
        recompiles under the armed watchdog and the compile audit pinned.
        """
        users = ["u0", "sick", "u2"]
        a = _sched(impl, datapath, health=DRILL_HCFG)
        for u in users:
            a.admit(u)
        for _ in range(WARM):
            a.pool_step(_own_step_drives(a), record=True)
        # pre-warm the recorder-reset program (a steady-state pool has
        # churned at least once since recording began)
        a.admit("tmp")
        a.evict("tmp")
        assert a.flagged_sessions() == []          # clean warmup: no flags
        assert a.health_checkpoint() == len(users)

        preset = AnomalyPreset("dead_input")
        watch.install()
        watch.reset()
        with watch.armed():
            n_anom = 0
            for _ in range(12):
                a.pool_step(_own_step_drives(a, "sick", preset),
                            record=True)
                n_anom += 1
                if "sick" in a.flagged_sessions():
                    break
            assert a.flagged_sessions() == ["sick"]
            # residual membrane/trace activity takes a few windows to decay
            # before the rate crosses dead_floor; then the 2-window streak
            # completes — well inside the 12-window budget either way
            assert n_anom <= 10, n_anom
            flags = _np(a._rec.health.flagged)[a.user_slot["sick"]]
            assert flags[DETECTORS.index("dead")]

            reports = a.remediate(flight_dir=str(tmp_path))
            assert len(reports) == 1
            assert reports[0]["uid"] == "sick"
            assert reports[0]["steps_lost"] == a.cfg.timesteps * n_anom
            assert a.flagged_sessions() == []
            assert a.quarantined_slots == frozenset()

            a_outs = []
            for _ in range(CONT):
                a_outs.append(a.pool_step(_own_step_drives(a),
                                          record=True)["sick"])
        assert watch.violations == 0, watch.violation_signatures
        assert a.compiled_programs() == {
            "slot_put": 1, "slot_take": 1, "recorder_reset": 1,
            "pool_step": 0, "pool_rollout": 0,
            "pool_step_telemetry": 0, "pool_rollout_telemetry": 0,
            "pool_step_record": 0, "pool_rollout_record": 1}

        # incident bundle: JSON + NPZ post-mortem
        doc = json.load(open(reports[0]["incident"]))
        assert doc["uid"] == "sick" and doc["verdict"]
        assert doc["flagged"]["dead"]
        assert doc["channels"] == list(CHANNELS)
        npz = np.load(os.path.join(str(tmp_path), doc["npz"]))
        assert npz["ring"].shape == (min(WARM + n_anom, DRILL_HCFG.window),
                                     len(CHANNELS))

        # control: same pool, but 'sick' is manually evicted and re-admitted
        # at the checkpoint instead of blowing up — no anomalous steps ever
        b = _sched(impl, datapath, health=DRILL_HCFG)
        for u in users:
            b.admit(u)
        for _ in range(WARM):
            b.pool_step(_own_step_drives(b))
        b.evict("sick")
        b.admit("sick")
        b_outs = [b.pool_step(_own_step_drives(b))["sick"]
                  for _ in range(CONT)]

        for x, y in zip(a_outs, b_outs):
            np.testing.assert_array_equal(_np(x), _np(y))
        _trees_equal(a._take(a.pool, jnp.int32(a.user_slot["sick"])),
                     b._take(b.pool, jnp.int32(b.user_slot["sick"])))


# ---------------------------------------------------------------------------
# 5. quarantine semantics + error paths
# ---------------------------------------------------------------------------

class TestQuarantine:
    def test_quarantine_freezes_slot_bit_exactly(self):
        sched = _sched(health=HealthConfig())
        sched.admit("a")
        sched.admit("b")
        for t in range(3):
            sched.step({u: _clean_drive(u, t) for u in ("a", "b")})
        slot = sched.quarantine("a")
        frozen = jax.tree.map(lambda x: _np(x).copy(),
                              sched._take(sched.pool, jnp.int32(slot)))
        for t in range(3, 6):
            sched.step({u: _clean_drive(u, t) for u in ("a", "b")})
        _trees_equal(frozen, sched._take(sched.pool, jnp.int32(slot)))
        assert sched.quarantined_slots == frozenset({slot})

    def test_error_paths(self, tmp_path):
        sched = _sched(slots=2)
        sched.admit("a")
        sched.admit("b")
        with pytest.raises(KeyError):
            sched.quarantine("ghost")
        with pytest.raises(RuntimeError, match="not quarantined"):
            sched.rollback("a")
        sched.quarantine("a")
        with pytest.raises(RuntimeError, match="quarantined"):
            sched.evict("a")
        with pytest.raises(RuntimeError, match="quarantined"):
            sched.save_pool(str(tmp_path))
        # LRU admission never evicts a quarantined resident
        sched.quarantine("b")
        with pytest.raises(RuntimeError, match="pool is full"):
            sched.admit("c", evict_lru=True)
        # lost slots are drain_failed's business, not quarantine's
        sched2 = _sched(slots=2)
        sched2.admit("a")
        sched2.fail_slots([sched2.user_slot["a"]])
        with pytest.raises(RuntimeError, match="LOST"):
            sched2.quarantine("a")

    def test_remediate_is_noop_on_clean_pool(self):
        sched = _sched(health=HealthConfig())
        sched.admit("a")
        sched.step({"a": _clean_drive("a", 0)}, record=True)
        assert sched.remediate() == []
        # and on a pool that never recorded at all
        assert _sched().remediate() == []

    def test_flagged_sessions_excludes_quarantined_and_lost(self):
        """dead_floor=_OFF turns the dead detector into a 'flag every warm
        active slot' generator: all three users flag, then quarantining /
        losing a slot removes it from the actionable list."""
        cfg = _hcfg(warmup=1, dead_floor=_OFF,
                    hysteresis=(_NEVER, _NEVER, _NEVER, 2))
        sched = _sched(health=cfg)
        for u in ("a", "b", "c"):
            sched.admit(u)
        for t in range(4):
            sched.step({u: _clean_drive(u, t) for u in ("a", "b", "c")},
                       record=True)
        assert sched.flagged_sessions() == ["a", "b", "c"]
        sched.quarantine("b")
        assert sched.flagged_sessions() == ["a", "c"]
        sched.fail_slots([sched.user_slot["c"]], poison=False)
        assert sched.flagged_sessions() == ["a"]


# ---------------------------------------------------------------------------
# 6. LM decode pool parity
# ---------------------------------------------------------------------------

def _model(impl, datapath):
    cfg = factory.build("qwen3-4b", smoke=True).cfg
    cfg = cfg.with_(plastic_adapter=True, adapter_neurons=8,
                    adapter_impl=impl, adapter_quant=(datapath == "int8"))
    model = factory.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params["adapter"]["scale"] = jnp.float32(0.5)
    return model, params


def _prompt(uid, n, vocab):
    rng = np.random.RandomState(sum(ord(c) for c in uid) * 7919 % (2 ** 31))
    return rng.randint(0, vocab, size=n).astype(np.int32)


class TestLMHealth:
    @pytest.mark.parametrize("impl,datapath",
                             [("xla", "float32"), ("pallas-interpret", "int8")])
    def test_quarantine_rollback_bit_identity(self, impl, datapath):
        """Decode-pool drill: recorded steps -> checkpoint -> quarantine
        freezes the stream's whole session row bit-exactly while its
        neighbour keeps decoding -> rollback re-admits the checkpoint and
        the continuation tokens match the manual-control run bitwise."""
        model, params = _model(impl, datapath)
        vocab = model.cfg.vocab
        a = LMScheduler(model, params, slots=3, max_len=32,
                        health=HealthConfig())
        for u in ("keep", "other"):
            a.admit_prompt(u, _prompt(u, 6, vocab))
        for _ in range(3):
            a.step(record=True)
        assert a.health_checkpoint() == 2
        a.quarantine("keep")
        frozen = jax.tree.map(lambda x: _np(x).copy(), a.session_view("keep"))
        for _ in range(2):
            a.step(record=True)    # 'other' decodes on; 'keep' is frozen
        _trees_equal(frozen, a.session_view("keep"))
        report = a.rollback("keep")
        # the 2 frozen decode steps still ticked the host clock: they are
        # the wall-clock steps the session "lost" to the incident
        assert report["uid"] == "keep" and report["steps_lost"] == 2
        a_toks = [a.step(record=True)["keep"] for _ in range(5)]

        b = LMScheduler(model, params, slots=3, max_len=32)
        for u in ("keep", "other"):
            b.admit_prompt(u, _prompt(u, 6, vocab))
        for _ in range(3):
            b.step()
        b.evict("keep")
        b.admit_prompt("keep", _prompt("keep", 6, vocab))   # restore path
        b_toks = [b.step()["keep"] for _ in range(5)]

        assert a_toks == b_toks
        _trees_equal(a.session_view("keep"), b.session_view("keep"))


# ---------------------------------------------------------------------------
# 7. plumbing: HTTP metrics, anomaly presets, FT-runner registry
# ---------------------------------------------------------------------------

class TestServeMetricsHTTP:
    def test_endpoints(self):
        reg = MetricsRegistry()
        reg.counter("pool_admissions_total", "h").inc(3)
        srv = serve_metrics(reg, port=0)
        try:
            port = srv.server_address[1]
            base = f"http://127.0.0.1:{port}"
            with urllib.request.urlopen(f"{base}/metrics") as r:
                assert r.status == 200
                assert r.headers["Content-Type"].startswith("text/plain")
                assert b"pool_admissions_total 3" in r.read()
            with urllib.request.urlopen(f"{base}/metrics.json") as r:
                snap = json.loads(r.read())
            assert snap["pool_admissions_total"]["value"] == 3.0
            with pytest.raises(urllib.error.HTTPError) as e:
                urllib.request.urlopen(f"{base}/bogus")
            assert e.value.code == 404
        finally:
            srv.shutdown()


class TestAnomalyPresets:
    def test_validation(self):
        with pytest.raises(ValueError, match="unknown anomaly"):
            AnomalyPreset("meteor_strike")
        assert ANOMALIES == {"drive_blowout", "dead_input", "stuck_input"}

    def test_deterministic_and_shaped(self):
        drive = np.linspace(-1, 1, 8).astype(np.float32)
        blow = AnomalyPreset("drive_blowout", gain=200.0)
        np.testing.assert_array_equal(inject_anomaly(blow, drive, 3),
                                      drive * np.float32(200.0))
        np.testing.assert_array_equal(
            inject_anomaly(AnomalyPreset("dead_input"), drive, 0),
            np.zeros(8, np.float32))
        stuck = AnomalyPreset("stuck_input")
        np.testing.assert_array_equal(inject_anomaly(stuck, drive, 0),
                                      inject_anomaly(stuck, drive, 17))
        noisy = AnomalyPreset("drive_blowout", gain=1.0, noise_std=0.1)
        a, b = (inject_anomaly(noisy, drive, t) for t in (4, 4))
        np.testing.assert_array_equal(a, b)
        assert np.any(inject_anomaly(noisy, drive, 5) != a)


class TestFTRunnerRegistry:
    def test_counters_reconcile_with_events(self, tmp_path):
        reg = MetricsRegistry()

        def step(state, batch):
            x = state["x"] + batch
            loss = jnp.where(jnp.asarray(int(batch) == 3), jnp.nan, x.sum())
            return {"x": x}, {"loss": loss}

        ckpt = CheckpointManager(str(tmp_path), keep=3)
        runner = FaultTolerantRunner(step, ckpt, save_every=2,
                                     max_rollbacks=3, registry=reg)
        state, hist = runner.run({"x": jnp.zeros(())},
                                 lambda s: jnp.asarray(float(s)), 6)
        snap = reg.snapshot()
        rollback_events = [e for e in runner.events
                           if e["kind"] == "rollback"]
        assert snap["ft_rollbacks_total"]["value"] == len(rollback_events) \
            == runner.rollbacks == 1
        assert snap["ft_step_seconds"]["count"] == len(hist)
        assert snap["ft_stragglers_total"]["value"] == len(
            [e for e in runner.events if e["kind"] == "straggler"])
        # a resume from the checkpoint counts once
        runner2 = FaultTolerantRunner(step, ckpt, registry=reg)
        _, start = runner2.restore_or_init({"x": jnp.zeros(())})
        assert start == 6
        assert reg.snapshot()["ft_resumes_total"]["value"] == 1.0
