"""Closed-loop fleet adaptation harness.

`make_closed_loop` builds ONE jitted program that drives B vectorized env
instances (`VectorEnv`) against B plastic SNN controllers through the
engine's fleet path (``snn.controller_step`` -> ``engine.rollout`` with
``w (B, N, M)``) inside a single `lax.scan` over env steps.  Each control
step's ``cfg.timesteps``-long SNN window is TIME-FUSED: on the Pallas
backends it is one `pallas_call` per control step (the rollout megakernel,
kernels/plasticity/fused), not ``timesteps x num_layers`` launches.
Everything
episode-varying — tasks, actuator masks, dynamics parameters, perturbation
schedules, the plasticity freeze step — is an *operand*, so:

  * perturbation events never recompile (pinned: `ClosedLoop.compile_count`
    stays at 1 across schedule changes);
  * the same program runs float32 and fixed-point (`SNNConfig.quant`), on
    ``impl="xla"``, ``"pallas"`` or ``"pallas-interpret"``;
  * the plasticity-on vs frozen-weights ablation is the SAME program with a
    different ``freeze_at`` scalar: theta is gated to zero from that step
    on (``dw`` is linear in theta, and the quantized stochastic round maps
    an exactly-zero dw to zero grid steps), which freezes the weights
    bit-exactly while the forward dynamics keep running.

The result feeds `repro.scenarios.metrics.adaptation_metrics` (pre/post
perturbation return, time-to-recover) — the paper's robust-adaptation claim
measured at fleet scale.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import snn
from repro.envs.base import Env
from repro.obs import MetricsRegistry, phase
from repro.scenarios import perturb as P
from repro.scenarios.vector_env import VectorEnv, VecEnvState


class RolloutResult(NamedTuple):
    rewards: jax.Array        # (steps, B) per-step env rewards
    actions: jax.Array        # (steps, B, act_dim)
    net: snn.NetworkState     # final fleet controller state
    env_state: VecEnvState    # final vectorized env state


@dataclasses.dataclass
class ClosedLoop:
    """A prepared (jitted-once) closed-loop rollout program.

    Built by `make_closed_loop`; call `run` as many times as needed — every
    call with the same (B, K) shapes reuses the single compiled executable.
    """

    env: Env
    scfg: snn.SNNConfig
    batch: int
    steps: int
    venv: VectorEnv
    _rollout: object  # jitted (net0, vstate0, theta, schedule, freeze, key)
    metrics: MetricsRegistry = dataclasses.field(
        default_factory=MetricsRegistry)

    def compile_count(self) -> int:
        """Executables compiled by the rollout program (recompile gate)."""
        return int(self._rollout._cache_size())

    def metrics_snapshot(self) -> dict:
        """JSON-able rollup of this harness's recorded runs (see `run`
        ``record=True``) plus the live compile count."""
        self.metrics.gauge(
            "closed_loop_compile_count",
            "executables compiled by the rollout program"
        ).set(self.compile_count())
        return self.metrics.snapshot()

    # ---- state builders ----------------------------------------------------

    def init_tasks(self, tasks) -> jax.Array:
        """Resolve a task spec: None -> train task 0; int -> that train
        task; "train"/"eval" -> cycle the task set across slots; or an
        explicit (B, T) / (T,) array."""
        env = self.env
        if tasks is None:
            tasks = 0
        if isinstance(tasks, int):
            return jnp.broadcast_to(env.train_tasks()[tasks],
                                    (self.batch,
                                     env.train_tasks().shape[1]))
        if isinstance(tasks, str):
            pool = env.train_tasks() if tasks == "train" else env.eval_tasks()
            idx = jnp.arange(self.batch) % pool.shape[0]
            return pool[idx]
        tasks = jnp.asarray(tasks, jnp.float32)
        if tasks.ndim == 1:
            tasks = jnp.broadcast_to(tasks[None],
                                     (self.batch, tasks.shape[0]))
        return tasks

    def init_net(self, w0: Optional[Sequence[jax.Array]] = None
                 ) -> snn.NetworkState:
        """Fleet controller state; ``w0`` optionally seeds per-layer weights
        (the weight-trained baseline), broadcast across slots."""
        net = snn.init_state(self.scfg, batch=self.batch, fleet=True)
        if w0 is None:
            return net
        if self.scfg.quant is not None:
            raise ValueError("w0 seeding is a float-mode feature; quantize "
                             "the state via snn.quantize_state instead")
        w = tuple(jnp.broadcast_to(jnp.asarray(wi, self.scfg.dtype),
                                   (self.batch, *jnp.shape(wi)))
                  for wi in w0)
        return dataclasses.replace(net, w=w)

    # ---- execution ---------------------------------------------------------

    def run(self, theta, key: jax.Array, *,
            tasks=None,
            schedule: Optional[P.Schedule] = None,
            freeze_at: Optional[int] = None,
            w0: Optional[Sequence[jax.Array]] = None,
            actuator_mask: Optional[jax.Array] = None,
            record: bool = False) -> RolloutResult:
        """One closed-loop rollout of `steps` env steps for all B slots.

        theta: per-layer rule list, or the flat vector `snn.flatten_theta`
        produces.  ``freeze_at``: env step from which plasticity is gated
        off (None = never; 0 = fully frozen).  ``schedule``: compiled
        perturbations (None = clean episode of the same K=0 program).
        ``record=True`` additionally rolls the run up into ``self.metrics``
        (rollout latency histogram, mean-reward gauge, run counter — the
        `metrics_snapshot` schema); recording blocks on the result, so
        leave it off inside latency-sensitive loops.
        """
        if isinstance(theta, jax.Array) or getattr(theta, "ndim", None) == 1:
            theta = snn.unflatten_theta(self.scfg, theta)
        theta = list(theta)
        k_env, k_loop = jax.random.split(jnp.asarray(key))
        vstate = self.venv.reset(k_env, tasks=self.init_tasks(tasks),
                                 actuator_mask=actuator_mask)
        net = self.init_net(w0)
        if schedule is None:
            schedule = P.empty_schedule(self.env, self.batch)
        freeze = jnp.asarray(self.steps + 1 if freeze_at is None
                             else freeze_at, jnp.int32)
        if not record:
            return self._rollout(net, vstate, theta, schedule, freeze,
                                 k_loop)
        with self.metrics.histogram(
                "closed_loop_rollout_seconds",
                "wall-clock per recorded closed-loop rollout").time(), \
                phase("scenario.rollout"):
            res = self._rollout(net, vstate, theta, schedule, freeze, k_loop)
            res.rewards.block_until_ready()
        self.metrics.counter(
            "closed_loop_rollouts_total", "recorded rollouts").inc()
        self.metrics.gauge(
            "closed_loop_mean_reward",
            "mean per-step reward over slots, last recorded rollout"
        ).set(float(res.rewards.mean()))
        return res


def make_closed_loop(env: Env, scfg: snn.SNNConfig, *, batch: int,
                     steps: int) -> ClosedLoop:
    """Build the jitted closed-loop program for (env, controller, B, T)."""
    venv = VectorEnv(env, batch)

    def rollout(net, vstate, theta, schedule, freeze, key):
        k_obs, k_enc = jax.random.split(key)

        def body(carry, t):
            vs, st = carry
            eff = P.effective_state(schedule, vs, t)
            obs = venv.observe(eff)
            obs = P.transform_obs(schedule, obs, t, k_obs)
            gate = (t < freeze).astype(scfg.dtype)
            th_t = [th * gate for th in theta]
            st, action = snn.controller_step(
                scfg, st, th_t, obs,
                key=jax.random.fold_in(k_enc, t)
                if scfg.encoding == "rate" else None)
            stepped, r = venv.step(eff, action)
            # carry the BASE state forward (perturbations are re-derived
            # from the schedule each step, so they never compound)
            vs = vs._replace(phys=stepped.phys, t=stepped.t)
            return (vs, st), (r, action)

        (vstate, net), (rewards, actions) = jax.lax.scan(
            body, (vstate, net), jnp.arange(steps))
        return RolloutResult(rewards=rewards, actions=actions, net=net,
                             env_state=vstate)

    return ClosedLoop(env=env, scfg=scfg, batch=batch, steps=steps,
                      venv=venv, _rollout=jax.jit(rollout))


def run_closed_loop(env: Env, scfg: snn.SNNConfig, theta, key: jax.Array, *,
                    batch: int, steps: int, **kwargs) -> RolloutResult:
    """One-shot convenience wrapper over `make_closed_loop(...).run(...)`.

    Prefer `make_closed_loop` when running several rollouts of the same
    shape (ablations, schedule sweeps): the program compiles once.
    """
    return make_closed_loop(env, scfg, batch=batch, steps=steps).run(
        theta, key, **kwargs)


# ---- session-health anomaly presets -----------------------------------------
#
# Deterministic host-side input corruptions for exercising the session-health
# detectors (obs.health): each preset maps to the detector that should catch
# it.  These run OUTSIDE the jitted rollout — they corrupt the drive a
# scheduler feeds a session, the way a faulty sensor or client would, so the
# device-side program (and its compile count) is untouched.


@dataclasses.dataclass(frozen=True)
class AnomalyPreset:
    """One injectable input fault.

    kind: "drive_blowout" (drive scaled by `gain` — trips ewma_z / bound),
    "dead_input" (drive zeroed — activity collapses, trips dead), or
    "stuck_input" (drive frozen at a constant pattern — recorded channels
    stop moving, trips stuck).  `noise_std` adds deterministic per-step
    Gaussian noise on top (seeded, so runs are reproducible)."""

    kind: str
    gain: float = 1.0
    noise_std: float = 0.0

    def __post_init__(self):
        if self.kind not in ANOMALIES:
            raise ValueError(f"unknown anomaly kind {self.kind!r}; "
                             f"expected one of {sorted(ANOMALIES)}")


ANOMALIES = frozenset({"drive_blowout", "dead_input", "stuck_input"})


def inject_anomaly(preset: AnomalyPreset, drive, t: int, seed: int = 0):
    """Corrupt one session's drive vector at control step `t` (host-side).

    Returns a numpy float32 array of drive's shape.  Deterministic in
    (preset, drive, t, seed) — the same fault stream replays exactly,
    which the health tests rely on to pin detection latency."""
    import numpy as np

    x = np.asarray(drive, np.float32)
    if preset.kind == "drive_blowout":
        out = x * np.float32(preset.gain)
    elif preset.kind == "dead_input":
        out = np.zeros_like(x)
    elif preset.kind == "stuck_input":
        # frozen pattern: derived from the seed only, NOT from (drive, t),
        # so every step presents the identical stuck value
        out = np.random.RandomState(seed).rand(*x.shape).astype(np.float32)
    else:  # pragma: no cover - __post_init__ rejects unknown kinds
        raise ValueError(preset.kind)
    if preset.noise_std > 0.0 and preset.kind != "stuck_input":
        rng = np.random.RandomState((seed * 1000003 + t) & 0x7FFFFFFF)
        out = out + rng.normal(0.0, preset.noise_std,
                               x.shape).astype(np.float32)
    return out
