from repro.distributed import ft, sharding
from repro.distributed.sharding import (logical_to_physical, named_sharding,
                                        shard_constraint)
from repro.distributed.ft import (FaultTolerantRunner, StragglerMonitor,
                                  elastic_restore)

__all__ = ["ft", "sharding", "logical_to_physical", "named_sharding",
           "shard_constraint", "FaultTolerantRunner", "StragglerMonitor",
           "elastic_restore"]
