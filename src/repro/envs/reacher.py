"""Position-generalization task (Brax `ur5e` stand-in).

A torque-controlled 2-link planar arm reaching toward goal positions sampled
in the workspace annulus.  Train goals: 8 fixed positions; eval: 72 unseen.

Perturbable dynamics params (`PARAM_NAMES`): damping, gain.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.envs.base import Env, EnvState


@dataclasses.dataclass(frozen=True)
class ReacherEnv(Env):
    episode_len: int = 150
    dt: float = 0.05
    obs_dim: int = 11     # sin/cos q(4), dq(2), goal(2), tip-goal(2), 1
    act_dim: int = 2
    link: float = 0.5
    damping: float = 1.0
    gain: float = 2.0

    PARAM_NAMES: tuple = ("damping", "gain")

    def init_phys(self, key: jax.Array) -> jax.Array:
        # phys = [q1, q2, dq1, dq2]
        q0 = 0.1 * jax.random.normal(key, (2,))
        return jnp.concatenate([q0, jnp.zeros(2)])

    def _tip(self, q: jax.Array) -> jax.Array:
        x = self.link * (jnp.cos(q[0]) + jnp.cos(q[0] + q[1]))
        y = self.link * (jnp.sin(q[0]) + jnp.sin(q[0] + q[1]))
        return jnp.array([x, y])

    def dynamics(self, phys: jax.Array, force: jax.Array,
                 params: Optional[jax.Array] = None) -> jax.Array:
        p = self.default_params() if params is None else params
        damping, gain = p[0], p[1]
        q, dq = phys[:2], phys[2:]
        ddq = gain * force - damping * dq
        dq = dq + self.dt * ddq
        q = q + self.dt * dq
        return jnp.concatenate([q, dq])

    def observe(self, state: EnvState) -> jax.Array:
        q, dq = state.phys[:2], state.phys[2:]
        tip = self._tip(q)
        goal = state.task
        return jnp.concatenate([
            jnp.sin(q), jnp.cos(q), dq, goal, goal - tip, jnp.array([1.0])])

    def reward(self, state: EnvState, action: jax.Array,
               new_phys: jax.Array) -> jax.Array:
        tip = self._tip(new_phys[:2])
        dist = jnp.linalg.norm(tip - state.task)
        ctrl = 0.01 * jnp.sum(action ** 2)
        return -dist - ctrl

    def _goals(self, n: int, phase: float) -> jax.Array:
        ang = (jnp.arange(n, dtype=jnp.float32) + phase) * (2 * jnp.pi / n)
        r = 0.7 * self.link * 2 * 0.5 + 0.35  # mid-workspace ring
        return jnp.stack([r * jnp.cos(ang), r * jnp.sin(ang)], axis=1)

    def train_tasks(self) -> jax.Array:
        return self._goals(8, 0.0)

    def eval_tasks(self) -> jax.Array:
        return self._goals(72, 0.5)
