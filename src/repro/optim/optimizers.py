"""AdamW and SGD over pytrees, fp32 master moments, pure JAX.

The optimizer state shards exactly like the parameters (same tree structure,
same per-leaf shapes), so FSDP-style "data"-axis parameter sharding gives
ZeRO-sharded Adam moments for free — the dry-run's memory_analysis covers
params + both moments under the same NamedShardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array          # () int32
    mu: Any                  # first moment (params-shaped, fp32)
    nu: Any                  # second moment (params-shaped, fp32)
    master: Any = None       # optional fp32 master weights (bf16 training)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    """Returns (clipped_tree, pre-clip norm)."""
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), norm


@dataclasses.dataclass(frozen=True)
class adamw:
    """AdamW factory: opt = adamw(lr); state = opt.init(params);
    params, state = opt.update(grads, state, params)."""

    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    master_weights: bool = False   # fp32 master copy (prevents bf16 update
                                   # underflow; shards like the params)
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer HBM — the
                                   # knob that fits grok-1-314b on 256 chips

    def init(self, params) -> OptState:
        mdt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
                  if self.master_weights else None)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=jax.tree.map(zeros, params),
                        master=master)

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: OptState, params):
        if self.grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, self.grad_clip)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mdt = jnp.dtype(self.moment_dtype)
        mu = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32)
                          + (1 - b1) * g.astype(jnp.float32)).astype(mdt),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32)
                          + (1 - b2) * jnp.square(g.astype(jnp.float32))
                          ).astype(mdt),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p32, m, v):
            m, v = m.astype(jnp.float32), v.astype(jnp.float32)
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p32
            return p32 - lr * delta

        src = state.master if self.master_weights else jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
        new_master = jax.tree.map(upd, src, mu, nu)
        new_params = jax.tree.map(lambda p, m32: m32.astype(p.dtype),
                                  params, new_master)
        return new_params, OptState(
            step=step, mu=mu, nu=nu,
            master=new_master if self.master_weights else None)


@dataclasses.dataclass(frozen=True)
class sgd:
    """SGD with optional momentum (stored in OptState.mu; nu unused)."""

    lr: Callable[[jax.Array], jax.Array] | float = 1e-2
    momentum: float = 0.9
    nesterov: bool = False
    grad_clip: Optional[float] = None

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=jax.tree.map(lambda p: jnp.zeros(()), params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: OptState, params):
        if self.grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, self.grad_clip)
        step = state.step + 1
        lr = self._lr(step)
        mu = jax.tree.map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32),
            state.mu, grads)
        if self.nesterov:
            eff = jax.tree.map(
                lambda m, g: self.momentum * m + g.astype(jnp.float32),
                mu, grads)
        else:
            eff = mu
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, eff)
        return new_params, OptState(step=step, mu=mu, nu=state.nu)
