"""Struct-of-arrays vectorized environments.

`VectorEnv` wraps a single-instance `envs.Env` and resets/steps B
independent instances — per-slot PRNG keys, tasks, actuator masks, AND
per-slot dynamics parameters — as one jitted program.  The batch lives in
the leading axis of every `VecEnvState` leaf (struct of arrays, the same
layout the fleet engine uses for its ``(B, N, M)`` weight pool), so a
closed-loop rollout of B envs against B plastic controllers is one
`lax.scan` over fused, fixed-shape programs: occupancy, tasks, masks, and
physics constants are all *data*.

The per-slot ``params`` leaf is what makes mid-episode dynamics shifts
(`repro.scenarios.perturb`) possible with zero recompiles: the wrapped
env's `dynamics` receives its perturbable constants (``Env.PARAM_NAMES``)
as a traced vector instead of reading dataclass fields.

Semantics contract (pinned in tests/test_scenarios.py): a `VectorEnv` with
``B = 1`` produces trajectories bit-identical to stepping the wrapped env
directly.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.envs.base import Env, EnvState


class VecEnvState(NamedTuple):
    """B independent `EnvState`s as a struct of arrays (+ per-slot params)."""

    phys: jax.Array           # (B, phys_dim) float32
    task: jax.Array           # (B, task_dim) float32
    actuator_mask: jax.Array  # (B, act_dim) float32
    t: jax.Array              # (B,) int32
    params: jax.Array         # (B, P) float32 — Env.PARAM_NAMES values

    def slot(self, i: int) -> EnvState:
        """View slot i as a single-env `EnvState` (params not included)."""
        return EnvState(phys=self.phys[i], task=self.task[i],
                        actuator_mask=self.actuator_mask[i], t=self.t[i])


@dataclasses.dataclass(frozen=True)
class VectorEnv:
    """B instances of ``env`` stepped as one program.

    All methods are pure and jit/scan-compatible.  ``tasks`` / ``masks`` /
    ``params`` default to the wrapped env's train task 0 / all-healthy /
    `default_params`, broadcast to every slot.
    """

    env: Env
    batch: int

    # ---- construction ------------------------------------------------------

    def reset(self, key: jax.Array,
              tasks: Optional[jax.Array] = None,
              actuator_mask: Optional[jax.Array] = None,
              params: Optional[jax.Array] = None) -> VecEnvState:
        """Reset all B slots.  ``key`` is split per slot (independent init)."""
        keys = jax.random.split(key, self.batch)
        phys = jax.vmap(self.env.init_phys)(keys).astype(jnp.float32)
        if tasks is None:
            tasks = jnp.broadcast_to(self.env.train_tasks()[0],
                                     (self.batch,
                                      self.env.train_tasks().shape[1]))
        tasks = jnp.asarray(tasks, jnp.float32)
        if tasks.ndim == 1:
            tasks = jnp.broadcast_to(tasks[None], (self.batch, tasks.shape[0]))
        if actuator_mask is None:
            actuator_mask = jnp.ones((self.batch, self.env.act_dim),
                                     jnp.float32)
        actuator_mask = jnp.asarray(actuator_mask, jnp.float32)
        if actuator_mask.ndim == 1:
            # same mask for every slot; without the broadcast a (act_dim,)
            # mask would be vmapped over the batch axis (silently wrong
            # whenever B == act_dim, a shape error otherwise)
            actuator_mask = jnp.broadcast_to(
                actuator_mask[None], (self.batch, self.env.act_dim))
        if params is None:
            params = jnp.broadcast_to(self.env.default_params(),
                                      (self.batch,
                                       len(self.env.PARAM_NAMES)))
        return VecEnvState(
            phys=phys, task=tasks, actuator_mask=actuator_mask,
            t=jnp.zeros((self.batch,), jnp.int32),
            params=jnp.asarray(params, jnp.float32))

    # ---- stepping ----------------------------------------------------------

    def observe(self, state: VecEnvState) -> jax.Array:
        """(B, obs_dim) observations."""
        def one(phys, task, mask, t):
            return self.env.observe(EnvState(phys, task, mask, t))
        return jax.vmap(one)(state.phys, state.task, state.actuator_mask,
                             state.t)

    def step(self, state: VecEnvState, actions: jax.Array
             ) -> tuple[VecEnvState, jax.Array]:
        """Step all B slots with (B, act_dim) actions; returns (state, (B,) r)."""
        def one(phys, task, mask, t, action, params):
            st, r = self.env.step(EnvState(phys, task, mask, t), action,
                                  params=params)
            return st.phys, st.t, r
        phys, t, r = jax.vmap(one)(state.phys, state.task,
                                   state.actuator_mask, state.t, actions,
                                   state.params)
        return state._replace(phys=phys, t=t), r
