"""Procedural MNIST-like digits + Poisson spike encoding (Table II protocol).

Seven-segment style digit rendering on a 28x28 grid with random affine
jitter — classes are visually separable, labels are exact, and everything is
a pure function of (key, label).  Accuracy numbers are NOT comparable to
real-MNIST Table II (97.5%); the online-learning *throughput* methodology
(pipelined forward+plasticity vs sequential) is what the benchmark
reproduces.  See DESIGN.md §8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# seven-segment layout: (x0, y0, x1, y1) in a 0..1 box, per segment
_SEGS = jnp.array([
    [0.2, 0.1, 0.8, 0.1],   # top
    [0.8, 0.1, 0.8, 0.5],   # top-right
    [0.8, 0.5, 0.8, 0.9],   # bottom-right
    [0.2, 0.9, 0.8, 0.9],   # bottom
    [0.2, 0.5, 0.2, 0.9],   # bottom-left
    [0.2, 0.1, 0.2, 0.5],   # top-left
    [0.2, 0.5, 0.8, 0.5],   # middle
])
# digit -> active segments
_DIGIT_SEGS = jnp.array([
    [1, 1, 1, 1, 1, 1, 0],  # 0
    [0, 1, 1, 0, 0, 0, 0],  # 1
    [1, 1, 0, 1, 1, 0, 1],  # 2
    [1, 1, 1, 1, 0, 0, 1],  # 3
    [0, 1, 1, 0, 0, 1, 1],  # 4
    [1, 0, 1, 1, 0, 1, 1],  # 5
    [1, 0, 1, 1, 1, 1, 1],  # 6
    [1, 1, 1, 0, 0, 0, 0],  # 7
    [1, 1, 1, 1, 1, 1, 1],  # 8
    [1, 1, 1, 1, 0, 1, 1],  # 9
], jnp.float32)


def render_digit(key: jax.Array, label: jax.Array, size: int = 28) -> jax.Array:
    """(size, size) float image in [0, 1] for `label` with random jitter."""
    k_shift, k_scale, k_noise = jax.random.split(key, 3)
    shift = jax.random.uniform(k_shift, (2,), minval=-0.08, maxval=0.08)
    scale = jax.random.uniform(k_scale, (), minval=0.85, maxval=1.1)

    ys, xs = jnp.meshgrid(jnp.linspace(0, 1, size), jnp.linspace(0, 1, size),
                          indexing="ij")
    pts = jnp.stack([xs, ys], -1)                       # (size, size, 2)
    segs = (_SEGS.reshape(7, 2, 2) - 0.5) * scale + 0.5 + shift

    def seg_dist(seg):
        a, b = seg[0], seg[1]
        ab = b - a
        tt = jnp.clip(jnp.einsum("ijk,k->ij", pts - a, ab)
                      / jnp.maximum(jnp.dot(ab, ab), 1e-6), 0, 1)
        proj = a + tt[..., None] * ab
        return jnp.linalg.norm(pts - proj, axis=-1)     # (size, size)

    dists = jax.vmap(seg_dist)(segs)                    # (7, size, size)
    strokes = jnp.exp(-(dists / 0.04) ** 2)
    active = _DIGIT_SEGS[label][:, None, None]
    img = jnp.clip((strokes * active).max(0), 0, 1)
    noise = 0.05 * jax.random.uniform(k_noise, (size, size))
    return jnp.clip(img + noise, 0, 1)


def spike_encode(key: jax.Array, img: jax.Array, timesteps: int,
                 max_rate: float = 0.8) -> jax.Array:
    """Poisson-rate spike trains: (timesteps, 784) in {0, 1}."""
    p = (img.reshape(-1) * max_rate)[None, :]
    u = jax.random.uniform(key, (timesteps, p.shape[1]))
    return (u < p).astype(jnp.float32)


def mnist_batch(key: jax.Array, batch: int, size: int = 28):
    """Returns (images (B, size, size), labels (B,) int32)."""
    k_lab, k_img = jax.random.split(key)
    labels = jax.random.randint(k_lab, (batch,), 0, 10)
    keys = jax.random.split(k_img, batch)
    imgs = jax.vmap(render_digit, in_axes=(0, 0, None))(keys, labels, size)
    return imgs, labels
