"""Mamba2 SSD (state-space duality) oracles.

Recurrence per (batch, head) with state matrix ``state in R^{S x P}``::

    da_t    = exp(A * dt_t)                       # scalar decay, A < 0
    state_t = da_t * state_{t-1} + dt_t * B_t (x) x_t      # outer product
    y_t     = C_t @ state_t                                 # (P,)

Two oracles:
  * ssd_scan_ref    — literal lax.scan recurrence (ground truth; also the
                      decode step).
  * ssd_chunked_ref — block-parallel "chunked" formulation (the SSD trick):
    intra-chunk quadratic term + inter-chunk state pass.  This is the XLA
    path the models lower for train/prefill, and the algorithm the Pallas
    kernel implements per-tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, a, b, c, state0=None):
    """x (B,L,H,P), dt (B,L,H), a (H,), b/c (B,L,H,S) -> (y, state_final).

    y (B,L,H,P); state (B,H,S,P).
    """
    bsz, length, h, p = x.shape
    s = b.shape[-1]
    compute = jnp.float32
    x, dt, b, c = (t.astype(compute) for t in (x, dt, b, c))
    a = a.astype(compute)
    if state0 is None:
        state0 = jnp.zeros((bsz, h, s, p), compute)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,S), (B,H,S)
        da = jnp.exp(a[None, :] * dtt)                    # (B,H)
        upd = dtt[..., None, None] * bt[..., :, None] * xt[..., None, :]
        state = da[..., None, None] * state + upd         # (B,H,S,P)
        y = jnp.einsum("bhs,bhsp->bhp", ct, state)
        return state, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          b.transpose(1, 0, 2, 3), c.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state0.astype(compute), xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), state


def ssd_chunked_ref(x, dt, a, bmat, c, state0=None, chunk: int = 64):
    """Chunked SSD — identical result to ssd_scan_ref (up to fp error)."""
    bsz, length, h, p = x.shape
    s = bmat.shape[-1]
    assert length % chunk == 0, (length, chunk)
    n = length // chunk
    compute = jnp.float32
    xc = x.astype(compute).reshape(bsz, n, chunk, h, p)
    dtc = dt.astype(compute).reshape(bsz, n, chunk, h)
    bc = bmat.astype(compute).reshape(bsz, n, chunk, h, s)
    cc = c.astype(compute).reshape(bsz, n, chunk, h, s)
    a = a.astype(compute)
    if state0 is None:
        state0 = jnp.zeros((bsz, h, s, p), compute)

    # cumulative log-decay within each chunk: Lg[b,n,t,h] = A_h * cumsum(dt)
    lg = a[None, None, None, :] * jnp.cumsum(dtc, axis=2)

    # ---- intra-chunk (quadratic within chunk, the "duality" matmul) -------
    # decay(t,s) = exp(Lg_t - Lg_s) for s <= t
    diff = lg[:, :, :, None, :] - lg[:, :, None, :, :]        # (B,n,t,s,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    gate = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bnths,bnzhs->bntzh", cc, bc)             # (B,n,t,z,H)
    y_intra = jnp.einsum("bntzh,bntzh,bnzh,bnzhp->bnthp",
                         cb, gate, dtc, xc)

    # ---- chunk-state contributions ----------------------------------------
    # state_in for chunk i = decayed carry of previous chunks (sequential scan
    # over n chunks — n is small: L/chunk)
    chunk_decay = jnp.exp(lg[:, :, -1, :])                    # (B,n,H)
    # state contribution of chunk i: sum_s exp(Lg_last - Lg_s) dt_s B_s x x_s
    w = jnp.exp(lg[:, :, -1:, :] - lg) * dtc                  # (B,n,t,H)
    state_c = jnp.einsum("bnth,bnths,bnthp->bnhsp", w, bc, xc)

    def carry_fn(state, inp):
        dec, sc = inp                                          # (B,H), (B,H,S,P)
        state_in = state
        state = dec[..., None, None] * state + sc
        return state, state_in

    states_in = jax.lax.scan(
        carry_fn, state0.astype(compute),
        (chunk_decay.transpose(1, 0, 2), state_c.transpose(1, 0, 2, 3, 4)))
    state_f, sins = states_in
    sins = sins.transpose(1, 0, 2, 3, 4)                      # (B,n,H,S,P)

    # inter-chunk output: y_t += exp(Lg_t) * C_t @ state_in
    y_inter = jnp.einsum("bnth,bnths,bnhsp->bnthp",
                         jnp.exp(lg), cc, sins)

    y = (y_intra + y_inter).reshape(bsz, length, h, p)
    return y.astype(x.dtype), state_f
