"""Environment interface: pure reset/step functions over a pytree state.

Two contracts matter to the scenario engine (`repro.scenarios`):

  * **Dtype pinning** — every `EnvState` leaf is explicitly float32 (phys,
    task, actuator_mask) or int32 (t), regardless of the global
    ``jax_enable_x64`` flag.  A state that silently inherits float64 under
    x64 would recompile every downstream jitted program and break the
    bit-parity tests between backends.
  * **Dynamics parameters as data** — each env names its perturbable
    physics constants in ``PARAM_NAMES`` (dataclass float fields such as
    mass/gain/damping) and `dynamics` accepts them as a traced ``(P,)``
    vector.  That is what lets the scenario engine shift dynamics
    mid-episode, per fleet slot, inside one jitted `lax.scan` with zero
    recompiles: a parameter shift is a `jnp.where` on data, never a new
    Python object.  ``dynamics(phys, force)`` without the vector uses the
    static defaults, so single-env code is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class EnvState(NamedTuple):
    phys: jax.Array        # flat physics state vector (float32)
    task: jax.Array        # task parameter (direction / velocity / goal)
    actuator_mask: jax.Array  # (act_dim,) 1 = healthy, 0 = failed
    t: jax.Array           # step counter (int32)


@dataclasses.dataclass(frozen=True)
class Env:
    """Subclasses define obs_dim/act_dim/episode_len and _dynamics."""

    episode_len: int = 200
    dt: float = 0.05

    # --- to override -------------------------------------------------------
    obs_dim: int = 0
    act_dim: int = 0

    # Names of the dataclass fields that are perturbable dynamics
    # parameters, in the order `default_params` packs them.  The scenario
    # engine shifts these per slot / per step as data.
    PARAM_NAMES: tuple = ()

    def init_phys(self, key: jax.Array) -> jax.Array:
        raise NotImplementedError

    def dynamics(self, phys: jax.Array, force: jax.Array,
                 params: Optional[jax.Array] = None) -> jax.Array:
        raise NotImplementedError

    def observe(self, state: EnvState) -> jax.Array:
        raise NotImplementedError

    def reward(self, state: EnvState, action: jax.Array,
               new_phys: jax.Array) -> jax.Array:
        raise NotImplementedError

    def train_tasks(self) -> jax.Array:
        raise NotImplementedError

    def eval_tasks(self) -> jax.Array:
        raise NotImplementedError

    # --- common ------------------------------------------------------------
    def default_params(self) -> jax.Array:
        """The ``PARAM_NAMES`` fields packed as a float32 ``(P,)`` vector."""
        return jnp.asarray([getattr(self, n) for n in self.PARAM_NAMES],
                           jnp.float32).reshape(len(self.PARAM_NAMES))

    def param_index(self, name: str) -> int:
        try:
            return self.PARAM_NAMES.index(name)
        except ValueError:
            raise ValueError(
                f"{type(self).__name__} has no dynamics parameter {name!r}; "
                f"perturbable params are {self.PARAM_NAMES}") from None

    def reset(self, key: jax.Array, task: jax.Array,
              actuator_mask: jax.Array | None = None) -> EnvState:
        if actuator_mask is None:
            actuator_mask = jnp.ones((self.act_dim,), jnp.float32)
        return EnvState(phys=self.init_phys(key).astype(jnp.float32),
                        task=jnp.asarray(task, jnp.float32),
                        actuator_mask=jnp.asarray(actuator_mask, jnp.float32),
                        t=jnp.zeros((), jnp.int32))

    def step(self, state: EnvState, action: jax.Array,
             params: Optional[jax.Array] = None) -> tuple[EnvState, jax.Array]:
        """Returns (new_state, reward).  Actions in [-1, 1].

        ``params`` optionally overrides the static dynamics constants with a
        traced ``(P,)`` vector (see `default_params`); None uses the
        dataclass fields unchanged.
        """
        act = jnp.clip(action, -1.0, 1.0) * state.actuator_mask
        new_phys = self.dynamics(state.phys, act, params)
        new_state = EnvState(phys=new_phys, task=state.task,
                             actuator_mask=state.actuator_mask, t=state.t + 1)
        return new_state, self.reward(state, act, new_phys)
