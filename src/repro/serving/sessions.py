"""SessionStore: per-user plastic state with LRU caching + durable restore.

A *session* is one user's learned synaptic memory — the whole point of
FireFly-P's Phase-2 deployment is that this state is continuously rewritten
on-line, so it can never be recomputed from parameters: it must be OWNED,
evicted, persisted, and restored like any other first-class resource.  The
store is deliberately generic over the state pytree:

  * SNN controllers — an unbatched `engine.NetworkState` (per-layer weights,
    membranes, traces, step counter);
  * the LM fast-weight adapter — the per-stream slice of the decode cache
    (``w_fast``, membranes, traces).

Ownership model (what the FleetScheduler drives):

    checkout(uid) ──> warm-cache hit (exclusive: removed from the cache)
                 ──> durable restore          (bit-identical resumption)
                 ──> factory()                (brand-new user, zero state)
    checkin(uid, state, step)
                 ──> persist FIRST (write-through), then warm-cache (LRU)

`checkin` is write-through: the session is durable the moment it leaves the
fleet, so the LRU warm cache is purely a re-admission fast path and can drop
entries without I/O.  Persistence rides on `checkpoint.manager` unchanged:
each session gets its own directory ``<root>/<uid>/`` with the standard
``step_*/manifest.json`` layout, atomic LATEST pointer, and keep-K gc — a
session checkpoint has exactly the same crash-safety contract as a training
checkpoint, and an evicted user's synapses come back bit-identical on
re-admission (pinned in tests/test_serving.py).  With ``root=None`` the
store archives to host RAM instead (same API, process-lifetime durability)
for tests and ephemeral pools.
"""
from __future__ import annotations

import os
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager, latest_step
from repro.obs import MetricsRegistry


class SessionStore:
    """Durable per-user plastic state behind an LRU warm cache.

    Args:
      root:     directory for durable persistence (one subdirectory per
                user, `checkpoint.manager` layout inside).  ``None``
                archives evicted state in host RAM instead.
      capacity: max sessions held in the warm cache; beyond it the least-
                recently-used entry is dropped (no I/O — `checkin` already
                persisted it).  ``None`` = unbounded cache.
      keep:     checkpoints retained per session (CheckpointManager keep-K).
      registry: `obs.MetricsRegistry` receiving the store's metrics (a
                private registry is created if omitted).  Stable schema:
                counters ``session_store_{warm_hits,restores,creates,
                persists}_total`` and histograms ``session_store_{checkout,
                persist}_seconds`` — `benchmarks/serving_churn.py`
                reconciles these against its own event log.
    """

    def __init__(self, root: Optional[str] = None,
                 capacity: Optional[int] = None, keep: int = 2,
                 registry: Optional[MetricsRegistry] = None):
        self.root = root
        self.capacity = capacity
        self.keep = keep
        self._warm: "OrderedDict[str, Tuple[Any, int]]" = OrderedDict()
        self._archive: Dict[str, Tuple[Any, int]] = {}   # root=None fallback
        self._managers: Dict[str, CheckpointManager] = {}
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._m_warm_hits = self.metrics.counter(
            "session_store_warm_hits_total",
            "checkouts served from the LRU warm cache")
        self._m_restores = self.metrics.counter(
            "session_store_restores_total",
            "checkouts restored from the durable store")
        self._m_creates = self.metrics.counter(
            "session_store_creates_total",
            "checkouts that built a fresh session (factory)")
        self._m_persists = self.metrics.counter(
            "session_store_persists_total", "durable session writes")
        self._m_checkout = self.metrics.histogram(
            "session_store_checkout_seconds", "checkout latency")
        self._m_persist_s = self.metrics.histogram(
            "session_store_persist_seconds", "persist latency")

    # ---- legacy counter views (read-only; the registry is the source) ----

    @property
    def warm_hits(self) -> int:
        """Checkouts served from the warm cache (registry-backed view)."""
        return int(self._m_warm_hits.value)

    @property
    def restores(self) -> int:
        """Checkouts restored from the durable store (registry-backed)."""
        return int(self._m_restores.value)

    @property
    def creates(self) -> int:
        """Checkouts that built a fresh session (registry-backed view)."""
        return int(self._m_creates.value)

    @property
    def persists(self) -> int:
        """Durable session writes (registry-backed view)."""
        return int(self._m_persists.value)

    # ---- ownership -------------------------------------------------------

    def __contains__(self, uid: str) -> bool:
        return uid in self._warm

    @property
    def cached(self) -> list:
        """Warm-cached uids, least-recently-used first."""
        return list(self._warm)

    def known(self, uid: str) -> bool:
        """True if `uid` has any state (warm, archived, or on disk)."""
        if uid in self._warm or uid in self._archive:
            return True
        return (self.root is not None
                and latest_step(os.path.join(self.root, str(uid)))
                is not None)

    def checkout(self, uid: str, factory: Callable[[], Any],
                 template: Any = None) -> Tuple[Any, int]:
        """Return ``(state, step)`` for `uid`; the caller owns it exclusively
        until `checkin`.

        Resolution order: warm cache (entry removed — no stale second copy
        can be handed out while the session lives in a fleet slot) ->
        durable store (restored into the structure of ``factory()``) ->
        ``factory()`` itself (fresh zero state, step 0).

        Every resolved payload is VALIDATED against the ``factory``
        template (pytree structure + per-leaf shape/dtype) before it is
        handed out.  This is what keeps a float32 checkpoint out of an int8
        fleet slot: the scheduler's swap-in scatter casts leaves to the
        pool dtype, so an unvalidated mode mismatch would not crash — it
        would silently destroy the session (a float weight cast to int8
        truncates to garbage).  Migrating a float session into a quantized
        pool is an explicit, sanctioned operation: `snn.quantize_state`.
        The template is ABSTRACT (ShapeDtypeStructs, no device allocation),
        so warm-hit admission stays allocation-free; only a brand-new user
        pays for a concrete ``factory()``.  Callers that already know the
        pool-mode template (a `SessionPool` knows its session pytree
        statically) pass it via ``template`` — otherwise it is derived with
        ``jax.eval_shape(factory)``.  Passing it matters when the factory
        wraps a jitted program (the LM prefill): every `eval_shape` of a
        jitted call adds a trace-cache entry, which would read as a
        "recompile" per admission under the churn benchmarks' pinned-zero
        compile counts.
        """
        with self._m_checkout.time():
            if template is None:
                template = jax.eval_shape(factory)
            if uid in self._warm:
                self._m_warm_hits.inc()
                state, step = self._warm.pop(uid)
                self._validate(uid, state, template)
                return state, step
            if self.root is not None:
                mgr = self._manager(uid)
                if mgr.latest_step() is not None:
                    try:
                        state, step, _ = mgr.restore(template)
                    except (KeyError, ValueError) as e:
                        raise ValueError(
                            f"session {uid!r}: persisted payload does not "
                            f"fit the requested pool mode ({e}); if it is a "
                            "float session being admitted to a quantized "
                            "pool, migrate it explicitly with "
                            "snn.quantize_state"
                        ) from e
                    self._m_restores.inc()
                    self._validate(uid, state, template)
                    return state, int(step)
            elif uid in self._archive:
                self._m_restores.inc()
                state, step = self._archive[uid]
                self._validate(uid, state, template)
                return state, step
            self._m_creates.inc()
            return factory(), 0

    @staticmethod
    def _validate(uid: str, state: Any, template: Any) -> None:
        """Reject payloads whose structure/shape/dtype disagree with the
        pool-mode template (the satellite bugfix: no silent corrupting
        casts on swap-in)."""
        got_def = jax.tree.structure(state)
        want_def = jax.tree.structure(template)
        if got_def != want_def:
            raise ValueError(
                f"session {uid!r}: payload pytree {got_def} does not match "
                f"the requested pool mode {want_def} (use "
                "snn.quantize_state to migrate float sessions into a "
                "quantized pool)")
        for got, want in zip(jax.tree.leaves(state),
                             jax.tree.leaves(template)):
            g_shape, w_shape = tuple(got.shape), tuple(want.shape)
            g_dt = np.dtype(got.dtype)
            w_dt = np.dtype(want.dtype)
            if g_shape != w_shape or g_dt != w_dt:
                raise ValueError(
                    f"session {uid!r}: payload leaf {g_dt.name}{g_shape} "
                    f"does not match the requested pool mode "
                    f"{w_dt.name}{w_shape}; admitting it would silently "
                    "corrupt the session on the swap-in cast (use "
                    "snn.quantize_state to migrate float sessions into a "
                    "quantized pool)")

    def checkin(self, uid: str, state: Any, step: int) -> None:
        """Return a session to the store: persist FIRST, then warm-cache."""
        self.persist(uid, state, step)
        self._warm[uid] = (state, int(step))
        self._warm.move_to_end(uid)
        while self.capacity is not None and len(self._warm) > self.capacity:
            self._warm.popitem(last=False)       # already durable; no I/O

    # ---- durability ------------------------------------------------------

    def persist(self, uid: str, state: Any, step: int) -> None:
        """Durably write one session snapshot."""
        with self._m_persist_s.time():
            self._m_persists.inc()
            if self.root is None:
                # host-RAM archive: snapshot to numpy so later donation of
                # the device buffers cannot corrupt the archived copy
                self._archive[uid] = (
                    jax.tree.map(
                        lambda a: np.asarray(jax.device_get(a)), state),
                    int(step))
                return
            self._manager(uid).save(int(step), state)

    def _manager(self, uid: str) -> CheckpointManager:
        if uid not in self._managers:
            self._managers[uid] = CheckpointManager(
                os.path.join(self.root, str(uid)), keep=self.keep)
        return self._managers[uid]
