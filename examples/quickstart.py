"""Quickstart: the FireFly-P plasticity rule in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Build a plastic SNN controller (zero-initialized weights).
2. Optimize the RULE (not the weights) offline with PEPG on 8 directions.
3. Deploy frozen rule on 72 unseen directions — weights rewrite online.
4. Run the same rule through the fused dual-engine kernel (TPU target,
   validated here in interpret mode).
"""
import jax
import jax.numpy as jnp

from repro import envs
from repro.core import adaptation, snn
from repro.kernels import dual_engine_step

# ---------------------------------------------------------------- phase 1
env = envs.make("direction", episode_len=40)
cfg = adaptation.AdaptationConfig(hidden=16, timesteps=2, pop_pairs=8,
                                  generations=10)
print("Phase 1: optimizing the plasticity rule offline (PEPG)...")
theta, history, scfg = adaptation.optimize_rule(env, cfg)
print(f"  fitness: {float(history[0]):.2f} -> {float(history[-1]):.2f}")

# ---------------------------------------------------------------- phase 2
print("Phase 2: frozen rule, ZERO weights, 72 unseen directions...")
returns = adaptation.evaluate_generalization(env, scfg, theta)
print(f"  mean return on unseen tasks: {float(returns.mean()):.2f}")

# -------------------------------------------------- the hardware kernel
print("Fused dual-engine step (Pallas TPU kernel, interpret mode):")
key = jax.random.PRNGKey(0)
x = (jax.random.uniform(key, (1, 8)) > 0.5).astype(jnp.float32)
w = jnp.zeros((8, 16))
th = 0.05 * jax.random.normal(key, (4, 8, 16))
v = jnp.zeros((1, 16))
tp, tq = jnp.ones((1, 8)), jnp.zeros((1, 16))
spikes, v2, tr2, w2 = dual_engine_step(x, w, th, v, tp, tq,
                                       impl="pallas", interpret=True)
print(f"  spikes={int(spikes.sum())}, |dW|={float(jnp.abs(w2 - w).sum()):.4f}"
      f"  (forward + four-term plasticity in ONE kernel)")
print("done.")
