"""Observability overhead gate: telemetry must be (near-)free.

The fleet telemetry of `repro.obs` is designed as a STATIC trace variant:
``telemetry=False`` programs are byte-identical to the uninstrumented
build, and ``telemetry=True`` adds exactly one extra stable executable per
jitted entry point whose device-side cost is a per-slot reduction fused
into the existing launch.  This benchmark turns both claims into gates:

  1. THROUGHPUT — steady-state `FleetScheduler.pool_step` rate at fleet
     size B, telemetry-off vs telemetry-on (which includes the host-side
     `record_fleet_telemetry` rollup — the real serving cost).  Median of
     ``--repeats`` timing passes.  Full mode (B=256) asserts the overhead
     stays <= ``--max-overhead`` (5%); smoke mode (B=16) records but does
     not assert (tiny-problem timings are launch-overhead noise).

  2. COMPILE DELTA — after warming both variants of both entry points,
     `compiled_programs()` must show EXACTLY one executable per variant:
     telemetry never churns the trace cache per step, and the off-path
     programs are untouched by instrumenting a run.

  3. WATCHDOG-SILENT CHURN — with the recompile watchdog ARMED, a churn
     loop (evict -> re-admit -> step, cycling restore and fresh-create
     admissions) must trigger ZERO violations: the whole observability
     stack — metrics, telemetry variants, store counters — introduces no
     shape or signature drift.  Any violation fails the bench (the CI
     obs-smoke job runs this on xla AND pallas-interpret).

    PYTHONPATH=src python benchmarks/obs_overhead.py [--smoke] [--impl ...]

Writes benchmarks/results/obs_overhead[_smoke].json plus a metrics-
registry snapshot (obs_overhead_metrics[_smoke].json — the artifact the
CI job uploads).
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import jax
import numpy as np

from repro.core import snn
from repro.obs import watchdog as _watchdog

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _make_sched(impl: str, slots: int, admitted: int):
    from repro.serving.scheduler import FleetScheduler

    cfg = snn.SNNConfig(layer_sizes=(32, 64, 8), timesteps=8, plastic=True,
                        encoding="current", impl=impl)
    theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.05)
    sched = FleetScheduler(cfg, theta, slots=slots)
    for i in range(admitted):
        sched.admit(f"user{i}")
    return sched


def _drives(sched):
    rng = np.random.default_rng(1)
    n_in = sched.cfg.layer_sizes[0]
    return {u: rng.standard_normal(n_in).astype(np.float32) * 2.0
            for u in sched.active_users}


def _steps_per_s(sched, drives, telemetry: bool, iters: int,
                 repeats: int) -> float:
    """Median steady-state pool_step (window) rate over `repeats` passes."""
    k = sched.cfg.timesteps
    sched.pool_step(drives, telemetry=telemetry)       # compile + warm
    jax.block_until_ready(sched.fleet.v)
    rates = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            sched.pool_step(drives, telemetry=telemetry)
        # drain the dispatch queue INSIDE the timed region: the off-path
        # never transfers anything to host, so without this it would be
        # timed against work still in flight (the telemetry path syncs
        # every call through the host gauge rollup)
        jax.block_until_ready(sched.fleet.v)
        rates.append(iters * k / (time.perf_counter() - t0))
    return statistics.median(rates)


def bench_overhead(impl: str, slots: int, iters: int, repeats: int) -> dict:
    sched = _make_sched(impl, slots, admitted=slots)
    drives = _drives(sched)
    off = _steps_per_s(sched, drives, False, iters, repeats)
    on = _steps_per_s(sched, drives, True, iters, repeats)
    return {"impl": impl, "batch": slots,
            "steps_per_s_off": off, "steps_per_s_on": on,
            "overhead_frac": 1.0 - on / off,
            "metrics": sched.metrics.snapshot()}


def check_compile_delta(impl: str, slots: int) -> dict:
    """Exactly one stable executable per (entry point x variant)."""
    sched = _make_sched(impl, slots, admitted=max(1, slots // 2))
    drives = _drives(sched)
    base = dict(sched.compiled_programs())
    # warm every stepping entry point, both variants, twice (a second call
    # that retraced would show as count 2)
    for _ in range(2):
        sched.step(drives)
        sched.step(drives, telemetry=True)
        sched.pool_step(drives)
        sched.pool_step(drives, telemetry=True)
    progs = sched.compiled_programs()
    expected = {"pool_step": 1, "pool_rollout": 1,
                "pool_step_telemetry": 1, "pool_rollout_telemetry": 1}
    errors = [f"{name}: {progs.get(name)} executables, expected {want}"
              for name, want in expected.items() if progs.get(name) != want]
    # instrumenting must not have touched the swap programs either
    for name in ("slot_put", "slot_take"):
        if progs[name] != base[name]:
            errors.append(f"{name}: grew {base[name]} -> {progs[name]} "
                          "during stepping")
    return {"impl": impl, "programs": progs, "errors": errors}


def check_watchdog_churn(impl: str, slots: int, cycles: int) -> dict:
    """Churn under an armed watchdog: zero compiles tolerated."""
    watch = _watchdog.install()
    sched = _make_sched(impl, slots, admitted=slots)
    # warmup: every program the churn loop will hit, including the
    # restore-admission path (evict then re-admit) and a fresh create
    drives = _drives(sched)
    sched.pool_step(drives, telemetry=True)
    sched.evict("user0")
    sched.admit("user0")                       # restore path
    sched.evict("user0")
    sched.admit("fresh0")                      # create path (new uid)
    sched.evict("fresh0")
    sched.admit("user0")
    sched.pool_step(_drives(sched), telemetry=True)
    watch.reset()
    with watch.armed():
        for c in range(cycles):
            uid = sched.active_users[c % len(sched.active_users)]
            sched.evict(uid)
            sched.admit(f"fresh{c + 1}" if c % 3 == 2 else uid,
                        evict_lru=True)
            sched.pool_step(_drives(sched), telemetry=True)
    return {"impl": impl, "cycles": cycles,
            "violations": watch.violations,
            "signatures": list(watch.violation_signatures)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="B=16 quick pass for CI (no overhead assertion)")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"])
    ap.add_argument("--batch", type=int, default=None,
                    help="fleet size (default 256 full / 16 smoke)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--churn-cycles", type=int, default=None)
    ap.add_argument("--max-overhead", type=float, default=0.05,
                    help="telemetry-on throughput cost gate (full mode)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    slots = args.batch if args.batch else (16 if args.smoke else 256)
    iters = args.iters if args.iters else (3 if args.smoke else 20)
    cycles = (args.churn_cycles if args.churn_cycles
              else (6 if args.smoke else 24))
    if args.out is None:
        args.out = os.path.join(
            RESULTS,
            "obs_overhead_smoke.json" if args.smoke else "obs_overhead.json")

    failures = []

    overhead = bench_overhead(args.impl, slots, iters, args.repeats)
    print(f"[throughput] B={slots} impl={args.impl}: "
          f"off={overhead['steps_per_s_off']:.1f} steps/s, "
          f"on={overhead['steps_per_s_on']:.1f} steps/s, "
          f"overhead={overhead['overhead_frac'] * 100:+.2f}%")
    if not args.smoke and overhead["overhead_frac"] > args.max_overhead:
        failures.append(
            f"telemetry overhead {overhead['overhead_frac'] * 100:.2f}% "
            f"exceeds the {args.max_overhead * 100:.0f}% gate")

    compile_delta = check_compile_delta(args.impl, slots)
    print(f"[compile] {compile_delta['programs']}")
    failures += compile_delta["errors"]

    churn = check_watchdog_churn(args.impl, min(slots, 8), cycles)
    print(f"[watchdog] {churn['cycles']} churn cycles: "
          f"{churn['violations']} violations")
    if churn["violations"]:
        failures.append(
            f"watchdog fired during churn: {churn['signatures']}")

    out = {"impl": args.impl, "smoke": bool(args.smoke), "batch": slots,
           "iters": iters, "repeats": args.repeats,
           "max_overhead": args.max_overhead,
           "overhead": {k: v for k, v in overhead.items() if k != "metrics"},
           "compile_delta": {"programs": compile_delta["programs"],
                             "errors": compile_delta["errors"]},
           "watchdog_churn": churn,
           "failures": failures}
    os.makedirs(RESULTS, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    # metrics snapshot artifact: obs_overhead_metrics[_smoke].json (the
    # _smoke suffix stays LAST so the run.py drift gate pairs the stems)
    snap_path = os.path.join(
        RESULTS, "obs_overhead_metrics_smoke.json" if args.smoke
        else "obs_overhead_metrics.json")
    with open(snap_path, "w") as f:
        json.dump(overhead["metrics"], f, indent=1, sort_keys=True)
    print(f"wrote {args.out} and {snap_path}; "
          f"{len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
