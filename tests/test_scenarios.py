"""Scenario engine: vectorized envs, perturbation schedules, closed-loop
fleet adaptation (the paper's robust-adaptation claim, asserted)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import envs
from repro import scenarios as S
from repro.core import snn
from repro.scenarios import perturb as P

IMPLS = ("xla", "pallas-interpret")


def _vec_state_from_single(venv, st):
    """A B=1 VecEnvState whose slot 0 is exactly the single EnvState."""
    vst = venv.reset(jax.random.PRNGKey(0), tasks=st.task[None])
    return vst._replace(phys=st.phys[None],
                        actuator_mask=st.actuator_mask[None])


@pytest.mark.parametrize("name", sorted(envs.ENVS))
class TestVectorEnv:
    def test_b1_bitwise_matches_single_env(self, name):
        """VectorEnv[B=1] trajectories are BIT-identical to stepping the
        wrapped env directly (same phys, same rewards)."""
        env = envs.make(name)
        st = env.reset(jax.random.PRNGKey(3), env.train_tasks()[2])
        venv = S.VectorEnv(env, 1)
        vst = _vec_state_from_single(venv, st)
        for t in range(25):
            a = jnp.sin(0.3 * t + jnp.arange(env.act_dim,
                                             dtype=jnp.float32))
            st, r = env.step(st, a)
            vst, vr = venv.step(vst, a[None])
            assert np.array_equal(np.asarray(st.phys),
                                  np.asarray(vst.phys[0])), f"t={t}"
            assert np.array_equal(np.asarray(r), np.asarray(vr[0])), f"t={t}"
        obs = env.observe(st)
        vobs = venv.observe(vst)
        assert np.array_equal(np.asarray(obs), np.asarray(vobs[0]))

    def test_reset_broadcasts_1d_actuator_mask(self, name):
        """A single (act_dim,) mask means 'this mask in EVERY slot' — with
        batch == act_dim it must not be consumed as per-slot scalars."""
        env = envs.make(name)
        venv = S.VectorEnv(env, env.act_dim)   # the dangerous B == A case
        mask = jnp.ones((env.act_dim,)).at[0].set(0.0)
        vst = venv.reset(jax.random.PRNGKey(0), actuator_mask=mask)
        assert vst.actuator_mask.shape == (env.act_dim, env.act_dim)
        assert np.array_equal(np.asarray(vst.actuator_mask),
                              np.broadcast_to(np.asarray(mask),
                                              (env.act_dim, env.act_dim)))

    def test_per_slot_params_are_independent(self, name):
        """Shifting slot 1's dynamics params must not touch slot 0."""
        env = envs.make(name)
        venv = S.VectorEnv(env, 2)
        vst = venv.reset(jax.random.PRNGKey(0),
                         tasks=jnp.broadcast_to(env.train_tasks()[0],
                                                (2, env.train_tasks().shape[1])))
        vst = vst._replace(phys=jnp.broadcast_to(vst.phys[0], vst.phys.shape))
        # additive shift: a uniform multiplier can cancel exactly (e.g.
        # scaling mass, gain, drag, and spring together leaves the
        # stabilizer's dynamics invariant)
        shifted = vst.params.at[1].add(0.5)
        vst = vst._replace(params=shifted)
        a = jnp.ones((2, env.act_dim)) * 0.5
        for _ in range(5):
            vst, _ = venv.step(vst, a)
        assert not np.allclose(np.asarray(vst.phys[0]),
                               np.asarray(vst.phys[1]))
        # slot 0 matches an unshifted single-env rollout bit-for-bit
        st = env.reset(jax.random.PRNGKey(9), env.train_tasks()[0])
        st = st._replace(phys=jax.device_get(venv.reset(
            jax.random.PRNGKey(0)).phys[0]))
        for _ in range(5):
            st, _ = env.step(st, a[0])
        assert np.array_equal(np.asarray(st.phys), np.asarray(vst.phys[0]))


class TestSchedules:
    def test_dropout_kills_k_actuators_per_hit_slot(self):
        env = envs.make("direction")
        sched = P.compile_schedule(
            env, (P.ActuatorDropout(k=3, step=10),), jax.random.PRNGKey(0),
            batch=16)
        mask = np.asarray(sched.act_mask[0])
        assert mask.shape == (16, 8)
        assert (mask.sum(axis=1) == 5).all()       # 3 of 8 dead per slot
        assert set(np.unique(mask)) <= {0.0, 1.0}

    def test_frac_hits_a_strict_subset(self):
        env = envs.make("direction")
        sched = P.compile_schedule(
            env, (P.ActuatorDropout(k=1, step=4, frac=0.5),),
            jax.random.PRNGKey(1), batch=64)
        onset = np.asarray(sched.onset[0])
        hit = onset < P.NEVER
        assert 0 < hit.sum() < 64
        # missed slots never fire: their effective mask stays all-healthy
        venv = S.VectorEnv(env, 64)
        vst = venv.reset(jax.random.PRNGKey(2))
        eff = P.effective_state(sched, vst, jnp.int32(100))
        m = np.asarray(eff.actuator_mask)
        assert (m[~hit] == 1.0).all()
        assert (m[hit].sum(axis=1) == 7).all()

    def test_onset_gates_and_does_not_compound(self):
        """Param shifts apply only after onset and are idempotent over time
        (re-derived from the base state each step, never compounded)."""
        env = envs.make("stabilizer")
        sched = P.compile_schedule(
            env, (P.ParamShift(param="wind", add=2.0, step=7),
                  P.ParamShift(param="gain", scale=0.5, step=9)),
            jax.random.PRNGKey(0), batch=3)
        venv = S.VectorEnv(env, 3)
        vst = venv.reset(jax.random.PRNGKey(0))
        i_wind = env.param_index("wind")
        i_gain = env.param_index("gain")
        before = P.effective_state(sched, vst, jnp.int32(6))
        assert np.allclose(np.asarray(before.params),
                           np.asarray(vst.params))
        mid = P.effective_state(sched, vst, jnp.int32(7))
        assert np.allclose(np.asarray(mid.params[:, i_wind]), 2.0)
        assert np.allclose(np.asarray(mid.params[:, i_gain]), 4.0)
        for t in (9, 50, 200):
            late = P.effective_state(sched, vst, jnp.int32(t))
            assert np.allclose(np.asarray(late.params[:, i_wind]), 2.0)
            assert np.allclose(np.asarray(late.params[:, i_gain]), 2.0)

    def test_goal_switch_last_wins(self):
        env = envs.make("direction")
        t1 = tuple(float(x) for x in env.eval_tasks()[3])
        t2 = tuple(float(x) for x in env.eval_tasks()[40])
        sched = P.compile_schedule(
            env, (P.GoalSwitch(step=5, tasks=t1),
                  P.GoalSwitch(step=10, tasks=t2)),
            jax.random.PRNGKey(0), batch=2)
        venv = S.VectorEnv(env, 2)
        vst = venv.reset(jax.random.PRNGKey(0))
        assert np.allclose(np.asarray(
            P.effective_state(sched, vst, jnp.int32(7)).task[0]), t1)
        assert np.allclose(np.asarray(
            P.effective_state(sched, vst, jnp.int32(12)).task[0]), t2)

    def test_obs_noise_deterministic_and_gated(self):
        env = envs.make("position")
        sched = P.compile_schedule(
            env, (P.SensorNoise(std=0.3, bias=0.1, step=5),),
            jax.random.PRNGKey(0), batch=4)
        obs = jnp.zeros((4, env.obs_dim))
        key = jax.random.PRNGKey(42)
        before = P.transform_obs(sched, obs, jnp.int32(4), key)
        assert np.array_equal(np.asarray(before), np.asarray(obs))
        a1 = P.transform_obs(sched, obs, jnp.int32(6), key)
        a2 = P.transform_obs(sched, obs, jnp.int32(6), key)
        b = P.transform_obs(sched, obs, jnp.int32(7), key)
        assert np.array_equal(np.asarray(a1), np.asarray(a2))
        assert not np.array_equal(np.asarray(a1), np.asarray(b))
        assert float(jnp.abs(a1).max()) > 0


class TestMetrics:
    def test_known_geometry(self):
        r = np.concatenate([np.full(40, -1.0), np.full(30, -3.0),
                            np.full(30, -1.5)])
        m = S.adaptation_metrics(r, onset=40, window=20)
        assert m["pre"] == pytest.approx(-1.0)
        assert m["post"] == pytest.approx(-3.0)
        assert m["drop"] == pytest.approx(2.0)
        assert m["final"] == pytest.approx(-1.5)
        assert m["recovery_frac"] == pytest.approx(0.75)
        assert m["time_to_recover"] > 0

    def test_never_recovers(self):
        r = np.concatenate([np.full(30, -1.0), np.full(70, -3.0)])
        m = S.adaptation_metrics(r, onset=30, window=20)
        assert m["recovery_frac"] == pytest.approx(0.0)
        assert m["time_to_recover"] == -1

    def test_onset_bounds(self):
        with pytest.raises(ValueError):
            S.adaptation_metrics(np.zeros(10), onset=10)


class TestClosedLoop:
    @pytest.mark.parametrize("impl", IMPLS)
    def test_b1_fleet_matches_single_controller_rollout(self, impl):
        """The full closed loop at B=1 reproduces a hand-rolled single-env,
        single-controller rollout (engine fleet path vs unbatched path; the
        env dynamics are bit-identical, the controller paths agree to float
        round-off)."""
        env = envs.make("stabilizer", episode_len=30, spring=2.5)
        scfg = S.controller_config(env, impl=impl)
        theta = S.reference_rule("stabilizer", scfg)
        st = env.reset(jax.random.PRNGKey(3), env.train_tasks()[0])
        net = snn.init_state(scfg)
        rs = []
        for _ in range(30):
            obs = env.observe(st)
            net, a = snn.controller_step(scfg, net, theta, obs)
            st, r = env.step(st, a)
            rs.append(float(r))

        prog = S.make_closed_loop(env, scfg, batch=1, steps=30)
        vst = _vec_state_from_single(
            prog.venv, env.reset(jax.random.PRNGKey(3),
                                 env.train_tasks()[0]))
        res = prog._rollout(prog.init_net(), vst, theta,
                            P.empty_schedule(env, 1), jnp.int32(31),
                            jax.random.PRNGKey(0))
        np.testing.assert_allclose(np.asarray(res.rewards)[:, 0],
                                   np.asarray(rs), rtol=0, atol=1e-4)

    def test_zero_recompiles_across_schedules_and_freeze(self):
        """One compiled program serves: clean episode, two different
        perturbation schedules, and both freeze settings."""
        spec = S.SCENARIOS["stabilizer-wind"]
        env = spec.make_env()
        scfg = S.controller_config(env, impl="xla")
        theta = S.reference_rule(spec.env_name, scfg)
        prog = S.make_closed_loop(env, scfg, batch=4, steps=40)
        key = jax.random.PRNGKey(0)
        s1 = P.compile_schedule(env, spec.perturbations,
                                jax.random.PRNGKey(1), 4)
        # same K as s1: a schedule is pure operand data, so only its VALUES
        # differ — a different K would be a new shape (one extra trace)
        s2 = P.compile_schedule(
            env, (P.ParamShift(param="wind", add=-1.0, step=5),
                  ), jax.random.PRNGKey(2), 4)
        prog.run(theta, key, tasks=spec.tasks, schedule=s1)
        prog.run(theta, key, tasks=spec.tasks, schedule=s2)
        prog.run(theta, key, tasks=spec.tasks, schedule=s2, freeze_at=10)
        prog.run(theta, key, tasks=spec.tasks, schedule=s1, freeze_at=0)
        assert prog.compile_count() == 1

    def test_actions_respect_mask_and_clip(self):
        """Actions recorded by the harness are in [-1, 1]; a dropout
        schedule zeroes the masked actuator's effect (env-side)."""
        spec = S.SCENARIOS["direction-dropout"]
        env = spec.make_env()
        scfg = S.controller_config(env, impl="xla")
        theta = S.reference_rule(spec.env_name, scfg)
        prog = S.make_closed_loop(env, scfg, batch=4, steps=30)
        res = prog.run(theta, jax.random.PRNGKey(0), tasks=spec.tasks)
        a = np.asarray(res.actions)
        assert np.isfinite(a).all()
        assert np.isfinite(np.asarray(res.rewards)).all()
        # controller_step tanh-squashes the readout: recorded actions are
        # already in [-1, 1] before the env's own clip
        assert (np.abs(a) <= 1.0).all()

    def test_quant_closed_loop_bitwise_across_backends(self):
        """The quantized closed loop (integer engine datapath driving float
        env dynamics through the SAME dequantized actions) is bit-identical
        between impl="xla" and impl="pallas-interpret"."""
        spec = S.SCENARIOS["stabilizer-wind"]
        env = spec.make_env()
        out = {}
        for impl in IMPLS:
            scfg = S.controller_config(env, impl=impl, quant=True)
            theta = S.reference_rule(spec.env_name, scfg)
            prog = S.make_closed_loop(env, scfg, batch=4, steps=40)
            sched = P.compile_schedule(env, spec.perturbations,
                                       jax.random.PRNGKey(1), 4)
            out[impl] = prog.run(theta, jax.random.PRNGKey(0),
                                 tasks=spec.tasks, schedule=sched)
        assert np.array_equal(np.asarray(out["xla"].rewards),
                              np.asarray(out["pallas-interpret"].rewards))
        for wa, wb in zip(out["xla"].net.w, out["pallas-interpret"].net.w):
            assert np.array_equal(np.asarray(wa), np.asarray(wb))

    def test_freeze_gate_freezes_weights_bit_exactly(self):
        """freeze_at=0 keeps the (zero-initialized) weights exactly zero in
        both float and quant modes — the frozen ablation is a true no-op on
        the synapses, not a small update."""
        spec = S.SCENARIOS["stabilizer-wind"]
        env = spec.make_env()
        for quant in (False, True):
            scfg = S.controller_config(env, impl="xla", quant=quant)
            theta = S.reference_rule(spec.env_name, scfg)
            prog = S.make_closed_loop(env, scfg, batch=2, steps=20)
            res = prog.run(theta, jax.random.PRNGKey(0), tasks=spec.tasks,
                           freeze_at=0)
            for w in res.net.w:
                assert not np.asarray(w).any(), f"quant={quant}"


class TestRecoveryGate:
    """The acceptance criterion: on the gate scenarios, plasticity-on
    recovers >= half the perturbation-induced return drop while the
    frozen-weights ablation does not — on xla AND pallas-interpret, in
    float32 AND quantized mode, with zero recompiles across perturbation
    events inside the scan."""

    @pytest.mark.parametrize("name", S.GATE_SCENARIOS)
    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("mode", ("float32", "quant"))
    def test_plastic_recovers_frozen_does_not(self, name, impl, mode):
        spec = S.SCENARIOS[name]
        env = spec.make_env()
        scfg = S.controller_config(env, impl=impl, quant=(mode == "quant"))
        theta = S.reference_rule(spec.env_name, scfg)
        prog = S.make_closed_loop(env, scfg, batch=spec.batch,
                                  steps=spec.steps)
        sched = S.compile_schedule(env, spec.perturbations,
                                   jax.random.PRNGKey(123), spec.batch)
        key = jax.random.PRNGKey(7)
        res_p = prog.run(theta, key, tasks=spec.tasks, schedule=sched)
        res_f = prog.run(theta, key, tasks=spec.tasks, schedule=sched,
                         freeze_at=spec.onset)
        mp = S.adaptation_metrics(res_p.rewards, spec.onset, spec.window)
        mf = S.adaptation_metrics(res_f.rewards, spec.onset, spec.window)
        assert mp["drop"] >= 0.02, mp
        assert mp["recovery_frac"] >= 0.5, mp
        assert mf["recovery_frac"] <= 0.25, mf
        assert mp["time_to_recover"] > 0, mp
        # zero recompiles: plastic + frozen + every perturbation event in
        # the scan ran through ONE compiled executable
        assert prog.compile_count() == 1
