"""PlasticEngine parity and stability (the tentpole refactor's contract).

Three guarantees:
  1. `engine.layer_step` under ``impl="pallas-interpret"`` matches
     ``impl="xla"`` within tolerance across shapes (block-multiples and
     not), dtypes (fp32/bf16), plastic on/off, spiking/readout, teach,
     and batched vs unbatched state.
  2. A refactored `snn.controller_step` rollout is BIT-stable vs the
     pre-refactor hand-rolled jnp layer loop under ``impl="xla"``.
  3. A full `controller_step`/`classify_window` rollout agrees between
     backends end-to-end.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, plasticity as P, snn


def _layer(key, b, n, m, dtype, plastic=True):
    ks = jax.random.split(key, 6)
    shp = (lambda *s: s) if b is None else (lambda *s: (b, *s))
    x = (jax.random.uniform(ks[0], shp(n)) > 0.5).astype(dtype)
    state = engine.LayerState(
        w=(0.1 * jax.random.normal(ks[1], (n, m))).astype(dtype),
        v=(0.1 * jax.random.normal(ks[2], shp(m))).astype(dtype),
        trace_pre=jax.random.uniform(ks[3], shp(n)).astype(dtype),
        trace_post=jax.random.uniform(ks[4], shp(m)).astype(dtype),
        theta=(0.01 * jax.random.normal(ks[5], (4, n, m))).astype(dtype)
        if plastic else None)
    return state, x


def _assert_step_parity(state, x, params, teach=None, tol=1e-5):
    ref_s, ref_out = engine.layer_step(state, x, params=params, impl="xla",
                                       teach=teach)
    pal_s, pal_out = engine.layer_step(state, x, params=params,
                                       impl="pallas-interpret", teach=teach)
    pairs = [(ref_out, pal_out, "out"), (ref_s.w, pal_s.w, "w"),
             (ref_s.v, pal_s.v, "v"),
             (ref_s.trace_post, pal_s.trace_post, "trace_post")]
    for r, p, name in pairs:
        assert r.shape == p.shape, name
        np.testing.assert_allclose(
            np.asarray(r, np.float32), np.asarray(p, np.float32),
            rtol=tol, atol=tol, err_msg=name)


class TestLayerStepParity:
    # shapes that are and are not multiples of the 128-wide Pallas block
    @pytest.mark.parametrize("b,n,m", [(1, 8, 8), (4, 32, 48), (2, 100, 130),
                                       (8, 128, 128), (3, 17, 257)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_batched(self, b, n, m, dtype):
        state, x = _layer(jax.random.PRNGKey(b * 997 + n + m), b, n, m, dtype)
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        _assert_step_parity(state, x, engine.EngineParams(), tol=tol)

    @pytest.mark.parametrize("n,m", [(8, 16), (100, 130)])
    def test_unbatched(self, n, m):
        state, x = _layer(jax.random.PRNGKey(n + m), None, n, m, jnp.float32)
        _assert_step_parity(state, x, engine.EngineParams())

    def test_unbatched_equals_batch_of_one(self):
        state, x = _layer(jax.random.PRNGKey(5), None, 24, 40, jnp.float32)
        b1 = jax.tree_util.tree_map(
            lambda a: a[None] if a.ndim < 2 else a, state)
        b1 = dataclasses.replace(b1, w=state.w, theta=state.theta)
        for impl in ("xla", "pallas-interpret"):
            s0, o0 = engine.layer_step(state, x, impl=impl)
            s1, o1 = engine.layer_step(b1, x[None], impl=impl)
            np.testing.assert_allclose(np.asarray(o0), np.asarray(o1[0]),
                                       rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(np.asarray(s0.w), np.asarray(s1.w),
                                       rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("plastic", [True, False])
    def test_plastic_flag(self, plastic):
        state, x = _layer(jax.random.PRNGKey(1), 2, 16, 16, jnp.float32,
                          plastic=plastic)
        params = engine.EngineParams(plastic=plastic)
        _assert_step_parity(state, x, params)
        new_s, _ = engine.layer_step(state, x, params=params,
                                     impl="pallas-interpret")
        if not plastic:
            np.testing.assert_array_equal(np.asarray(new_s.w),
                                          np.asarray(state.w))

    def test_readout_mode(self):
        state, x = _layer(jax.random.PRNGKey(2), 2, 12, 20, jnp.float32)
        params = engine.EngineParams(spiking=False)
        _assert_step_parity(state, x, params)
        # readout emits the membrane potential, not binary spikes
        _, out = engine.layer_step(state, x, params=params, impl="xla")
        assert not np.array_equal(np.unique(np.asarray(out)),
                                  np.asarray([0.0, 1.0]))

    def test_teach_current(self):
        state, x = _layer(jax.random.PRNGKey(3), 2, 10, 30, jnp.float32)
        teach = 2.0 * jax.random.normal(jax.random.PRNGKey(4), (2, 30))
        _assert_step_parity(state, x, engine.EngineParams(), teach=teach)
        # the teaching current must actually change the outcome
        _, out0 = engine.layer_step(state, x, impl="xla")
        _, out1 = engine.layer_step(state, x, impl="xla", teach=teach)
        assert not np.array_equal(np.asarray(out0), np.asarray(out1))

    def test_bad_impl_raises(self):
        state, x = _layer(jax.random.PRNGKey(6), 1, 4, 4, jnp.float32)
        with pytest.raises(ValueError):
            engine.layer_step(state, x, impl="cuda")


# ---------------------------------------------------------------------------
# Bit-stability vs the pre-refactor hand-rolled jnp layer loop.
# ---------------------------------------------------------------------------

def _legacy_timestep(cfg, state, theta, drive, teach=None):
    """The pre-PlasticEngine `snn.timestep` (hand-wired jnp), verbatim."""
    w, v, tr = list(state["w"]), list(state["v"]), list(state["trace"])
    x = drive
    tr[0] = P.update_trace(tr[0], x, cfg.trace_decay)
    out = None
    for i in range(cfg.num_layers):
        current = x @ w[i]
        if teach is not None and i == cfg.num_layers - 1:
            current = current + teach.astype(current.dtype)
        last = i == cfg.num_layers - 1
        if last and not cfg.spiking_readout:
            v[i] = snn.leaky_readout(v[i], current, cfg.lif)
            spikes = jnp.tanh(v[i])
            out = v[i]
        else:
            v[i], spikes = snn.lif_step(v[i], current, cfg.lif)
            out = spikes
        tr[i + 1] = P.update_trace(tr[i + 1], spikes, cfg.trace_decay)
        if cfg.plastic:
            pcfg = cfg.layer_plasticity_cfg(i)
            w[i] = P.apply_plasticity(w[i], theta[i], tr[i], tr[i + 1], pcfg)
        x = spikes
    return {"w": w, "v": v, "trace": tr, "t": state["t"] + 1}, out


def _legacy_controller_step(cfg, state, theta, obs, key=None):
    def body(st, t):
        drive = snn.encode(cfg, obs, key, st["t"])
        st, out = _legacy_timestep(cfg, st, theta, drive)
        return st, out

    state, outs = jax.lax.scan(body, state, jnp.arange(cfg.timesteps))
    action = outs.mean(axis=0)
    if not cfg.spiking_readout:
        action = jnp.tanh(action)
    return state, action


def _as_legacy(state):
    return {"w": list(state.w), "v": list(state.v),
            "trace": list(state.trace), "t": state.t}


class TestRolloutStability:
    @pytest.mark.parametrize("spiking_readout", [False, True])
    @pytest.mark.parametrize("plastic", [True, False])
    def test_controller_step_bit_stable_vs_legacy(self, spiking_readout,
                                                  plastic):
        cfg = snn.SNNConfig(layer_sizes=(6, 16, 4), timesteps=4,
                            plastic=plastic, spiking_readout=spiking_readout,
                            impl="xla")
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.5)
        obs = jnp.linspace(-1.0, 1.0, 6)
        new_state, new_action = snn.controller_step(
            cfg, snn.init_state(cfg), theta, obs)
        old_state, old_action = _legacy_controller_step(
            cfg, _as_legacy(snn.init_state(cfg)), theta, obs)
        np.testing.assert_array_equal(np.asarray(new_action),
                                      np.asarray(old_action))
        for a, b in zip(new_state.w, old_state["w"]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(new_state.trace, old_state["trace"]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_classify_window_teach_bit_stable_vs_legacy(self):
        cfg = snn.SNNConfig(layer_sizes=(10, 12, 3), timesteps=5,
                            spiking_readout=True)
        theta = snn.init_theta(cfg, jax.random.PRNGKey(2), scale=0.5)
        x = jnp.ones((10,))
        teach = 2.0 * jax.nn.one_hot(1, 3)

        def body(st, t):
            st, out = _legacy_timestep(cfg, st, theta,
                                       snn.encode(cfg, x, None, st["t"]),
                                       teach=teach)
            return st, out

        _, outs = jax.lax.scan(body, _as_legacy(snn.init_state(cfg)),
                               jnp.arange(cfg.timesteps))
        _, scores = snn.classify_window(cfg, snn.init_state(cfg), theta, x,
                                        teach=teach)
        np.testing.assert_array_equal(np.asarray(scores),
                                      np.asarray(outs.sum(axis=0)))

    def test_controller_rollout_backend_parity(self):
        """xla vs pallas-interpret agree over a full multi-step rollout."""
        actions, weights = {}, {}
        for impl in ("xla", "pallas-interpret"):
            cfg = snn.SNNConfig(layer_sizes=(6, 16, 4), timesteps=3,
                                impl=impl)
            state = snn.init_state(cfg)
            theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.5)
            acts = []
            for k in range(3):
                obs = jnp.sin(jnp.linspace(0, 2 + k, 6))
                state, a = snn.controller_step(cfg, state, theta, obs)
                acts.append(a)
            actions[impl] = jnp.stack(acts)
            weights[impl] = state.w
        np.testing.assert_allclose(np.asarray(actions["xla"]),
                                   np.asarray(actions["pallas-interpret"]),
                                   rtol=1e-5, atol=1e-5)
        for a, b in zip(weights["xla"], weights["pallas-interpret"]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)


class TestNetworkState:
    def test_pytree_roundtrip(self):
        cfg = snn.SNNConfig(layer_sizes=(5, 7, 2))
        state = snn.init_state(cfg)
        leaves, treedef = jax.tree_util.tree_flatten(state)
        back = jax.tree_util.tree_unflatten(treedef, leaves)
        assert isinstance(back, engine.NetworkState)
        assert back.num_layers == 2
        assert len(back.trace) == 3

    def test_layer_view(self):
        cfg = snn.SNNConfig(layer_sizes=(5, 7, 2))
        state = snn.init_state(cfg)
        layer = state.layer(1)
        assert layer.w.shape == (7, 2)
        assert layer.trace_pre.shape == (7,)
        assert layer.trace_post.shape == (2,)
