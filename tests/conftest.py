# NOTE: no XLA_FLAGS here on purpose — tests and benches run on ONE device;
# only launch/dryrun.py forces 512 placeholder devices (in its own process).
import jax

jax.config.update("jax_enable_x64", False)
