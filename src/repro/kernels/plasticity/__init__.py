from repro.kernels.plasticity.ops import dual_engine_step

__all__ = ["dual_engine_step"]
