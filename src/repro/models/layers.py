"""Common layers + the parameter-plan machinery.

A model is described by a PLAN: a pytree whose leaves are `ParamDesc`
(shape, dtype, init, logical sharding spec).  From one plan we derive:

  * init_from_plan(plan, key)        — real parameters (CPU smoke tests)
  * abstract_from_plan(plan)         — ShapeDtypeStructs (dry-run lowering)
  * shardings_from_plan(plan, mesh)  — NamedShardings (pjit in_shardings)

keeping init / abstract / sharding structurally identical by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd


@dataclasses.dataclass(frozen=True)
class ParamDesc:
    shape: Tuple[int, ...]
    spec: Tuple[Any, ...]              # logical axes, len == ndim
    init: str = "normal"               # normal | zeros | ones | full
                                       # ("full" fills with `scale` — e.g.
                                       # the int8 adapter's default w_scale)
    scale: float = 1.0                 # stddev multiplier (normal)
    fan_in: Optional[int] = None       # normal: std = scale / sqrt(fan_in)
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.spec), (self.shape, self.spec)


def _is_desc(x):
    return isinstance(x, ParamDesc)


def init_from_plan(plan, key: jax.Array):
    leaves, treedef = jax.tree.flatten(plan, is_leaf=_is_desc)
    keys = jax.random.split(key, len(leaves))

    def mk(desc: ParamDesc, k):
        dt = jnp.dtype(desc.dtype)
        if desc.init == "zeros":
            return jnp.zeros(desc.shape, dt)
        if desc.init == "ones":
            return jnp.ones(desc.shape, dt)
        if desc.init == "full":
            return jnp.full(desc.shape, desc.scale, dt)
        fan = desc.fan_in if desc.fan_in else (desc.shape[-2] if len(desc.shape) >= 2 else desc.shape[-1])
        std = desc.scale / (fan ** 0.5)
        return (std * jax.random.normal(k, desc.shape)).astype(dt)

    return jax.tree.unflatten(treedef, [mk(d, k) for d, k in zip(leaves, keys)])


def abstract_from_plan(plan, mesh=None):
    def mk(desc: ParamDesc):
        sh = (shd.named_sharding(mesh, desc.spec, desc.shape)
              if mesh is not None else None)
        return jax.ShapeDtypeStruct(desc.shape, jnp.dtype(desc.dtype), sharding=sh)
    return jax.tree.map(mk, plan, is_leaf=_is_desc)


def shardings_from_plan(plan, mesh):
    return jax.tree.map(
        lambda d: shd.named_sharding(mesh, d.spec, d.shape), plan,
        is_leaf=_is_desc)


def specs_from_plan(plan, mesh):
    return jax.tree.map(
        lambda d: shd.logical_to_physical(mesh, d.spec, d.shape), plan,
        is_leaf=_is_desc)


def param_count(plan) -> int:
    leaves = jax.tree.leaves(plan, is_leaf=_is_desc)
    n = 0
    for d in leaves:
        c = 1
        for s in d.shape:
            c *= s
        n += c
    return n


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def rope(q, k, positions, theta: float):
    """Rotary embeddings.  q/k (..., S, H, D); positions (..., S)."""
    d = q.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]

    def rot(x):
        x1, x2 = x[..., :half], x[..., half:]
        xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
        return jnp.concatenate(
            [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1).astype(x.dtype)

    return rot(q), rot(k)


def cross_entropy(logits, labels, mask=None):
    """Mean token NLL in fp32.  logits (..., V); labels (...) int32.

    Written as reductions over the vocab axis (logsumexp + one-hot
    contraction) rather than a gather, so a model-sharded vocab dim stays
    sharded under SPMD — the picked-logit term lowers to a partial einsum +
    all-reduce instead of an all-gather of the full logit tensor.
    """
    lf = logits.astype(jnp.float32)
    v = lf.shape[-1]
    lmax = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - lmax), axis=-1)) + lmax[..., 0]
    onehot = jax.nn.one_hot(labels, v, dtype=lf.dtype)
    picked = jnp.einsum("...v,...v->...", lf, onehot)
    nll = lse - picked
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)
