"""Velocity-generalization task (Brax `halfcheetah` stand-in).

A 1-D runner driven by 4 actuators coupled through a gait phase oscillator;
drive saturates (tanh) so matching a target velocity needs a *policy*, not a
constant.  Train on 8 target velocities in [0.5, 4.0], evaluate on 72 unseen
velocities over the same range.

Perturbable dynamics params (`PARAM_NAMES`): drag, gain, phase_rate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.envs.base import Env, EnvState


@dataclasses.dataclass(frozen=True)
class VelocityEnv(Env):
    episode_len: int = 150
    dt: float = 0.05
    obs_dim: int = 7      # v, v_target, v_err, sin/cos phase, |v_err|, 1
    act_dim: int = 4
    drag: float = 0.8
    gain: float = 3.0
    phase_rate: float = 4.0

    PARAM_NAMES: tuple = ("drag", "gain", "phase_rate")

    def init_phys(self, key: jax.Array) -> jax.Array:
        # phys = [x, v, phase]
        v0 = 0.05 * jax.random.normal(key, ())
        return jnp.array([0.0, v0, 0.0])

    def dynamics(self, phys: jax.Array, force: jax.Array,
                 params: Optional[jax.Array] = None) -> jax.Array:
        p = self.default_params() if params is None else params
        drag, gain, phase_rate = p[0], p[1], p[2]
        x, v, phase = phys
        # gait coupling: alternating actuators are effective in alternating
        # phase halves (crude stance/swing structure)
        gate = jnp.array([jnp.sin(phase), jnp.cos(phase),
                          -jnp.sin(phase), -jnp.cos(phase)])
        drive = gain * jnp.tanh(jnp.sum(force * jax.nn.relu(gate)))
        v = v + self.dt * (drive - drag * v)
        x = x + self.dt * v
        phase = phase + self.dt * phase_rate
        return jnp.array([x, v, phase])

    def observe(self, state: EnvState) -> jax.Array:
        _, v, phase = state.phys
        vt = state.task[0]
        err = vt - v
        return jnp.array([v, vt, err, jnp.sin(phase), jnp.cos(phase),
                          jnp.abs(err), 1.0])

    def reward(self, state: EnvState, action: jax.Array,
               new_phys: jax.Array) -> jax.Array:
        v = new_phys[1]
        vt = state.task[0]
        ctrl = 0.01 * jnp.sum(action ** 2)
        return -jnp.abs(v - vt) - ctrl

    def train_tasks(self) -> jax.Array:
        return jnp.linspace(0.5, 4.0, 8)[:, None]

    def eval_tasks(self) -> jax.Array:
        lo = jnp.linspace(0.5, 4.0, 8)
        # 72 targets interleaved between / beyond the 8 training velocities
        return (jnp.linspace(0.45, 4.15, 72))[:, None]
