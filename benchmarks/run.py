"""Benchmark entry point: one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick | --smoke]

  adaptation        Fig. 3   plasticity vs weight-trained generalization
  engine_breakdown  Table I  per-engine FLOPs/bytes/roofline latency
  mnist_throughput  Table II pipelined fwd+learn FPS methodology
  latency           8 us     controller end-to-end latency analogue
  fleet_throughput  serving  native batched-weights launch vs vmap recipe
  serving_churn     serving  session churn into a fixed slot pool (pinned
                             zero recompiles + evict/restore bit-equality)
  roofline          Roofline table from the dry-run artifacts (if present)
"""
from __future__ import annotations

import sys
import time


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv or "--smoke" in argv
    t0 = time.time()
    failures = []

    from benchmarks import (adaptation, engine_breakdown, fleet_throughput,
                            latency, mnist_throughput, roofline,
                            serving_churn)

    for name, fn in (
        ("engine_breakdown", lambda: engine_breakdown.main(quick=quick)),
        ("latency", lambda: latency.main(quick=quick)),
        ("mnist_throughput", lambda: mnist_throughput.main(quick=quick)),
        ("adaptation", lambda: adaptation.main(quick=quick)),
        ("fleet_throughput",
         lambda: fleet_throughput.main(
             ["--smoke"] if quick else ["--max-batch", "256"])),
        ("serving_churn",
         lambda: serving_churn.main(
             ["--smoke"] if quick else ["--steps", "100"])),
        ("roofline_single", lambda: roofline.main(["--mesh", "single"])),
        ("roofline_multi", lambda: roofline.main(["--mesh", "multi"])),
    ):
        print(f"\n===== {name} =====")
        try:
            fn()
        except Exception as e:  # keep the harness running; report at end
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))

    print(f"\nbenchmarks done in {time.time() - t0:.0f}s; "
          f"{len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
