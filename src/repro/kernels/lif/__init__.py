from repro.kernels.lif.ops import lif_forward

__all__ = ["lif_forward"]
