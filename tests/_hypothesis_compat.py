"""Offline-friendly hypothesis shim.

Test modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly.  When hypothesis is installed (the `test` extra in
pyproject.toml) the real library is re-exported unchanged; in network-less
environments a small deterministic fallback runs each property test over a
fixed set of examples (strategy bounds + seeded pseudo-random fill), so the
full tier-1 suite collects and runs without the dependency.

The fallback supports exactly the strategy surface this repo uses:
``st.floats(min, max)``, ``st.integers(min, max)``, and
``st.sampled_from(elements)``, positional or keyword ``@given``, stacked
with ``@settings`` and pytest parametrize.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _NUM_EXAMPLES = 10

    class _Strategy:
        def __init__(self, lo, hi, cast):
            self.lo, self.hi, self.cast = lo, hi, cast

        def example(self, rng: random.Random, i: int):
            # corners first, then seeded pseudo-random interior points
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            if i == 2:
                return self.cast((self.lo + self.hi) / 2)
            return self.cast(self.lo + rng.random() * (self.hi - self.lo))

    class _SampledStrategy:
        def __init__(self, elements):
            self.elements = list(elements)

        def example(self, rng: random.Random, i: int):
            if i < len(self.elements):
                return self.elements[i]
            return rng.choice(self.elements)

    class _Strategies:
        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(float(min_value), float(max_value), float)

        @staticmethod
        def integers(min_value, max_value, **_kw):
            return _Strategy(int(min_value), int(max_value),
                             lambda x: int(round(x)))

        @staticmethod
        def sampled_from(elements):
            return _SampledStrategy(elements)

    st = _Strategies()

    def settings(*_args, **_kw):
        """No-op stand-in for hypothesis.settings used as a decorator."""
        def deco(fn):
            return fn
        return deco

    def given(*pos_strategies, **kw_strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            if pos_strategies:
                # hypothesis maps positional strategies onto the trailing
                # parameters of the test function
                names = [p.name for p in params[-len(pos_strategies):]]
                strategies = dict(zip(names, pos_strategies))
            else:
                strategies = dict(kw_strategies)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0xF1EF)
                for i in range(_NUM_EXAMPLES):
                    drawn = {name: s.example(rng, i)
                             for name, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # hide the strategy-supplied params from pytest's fixture
            # resolution, as hypothesis does
            wrapper.__signature__ = sig.replace(
                parameters=[p for p in params if p.name not in strategies])
            return wrapper
        return deco
