"""Control environments + the two-phase learning loop (paper Secs. II-B, IV)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import envs
from repro.core import adaptation, es, snn

ALL_ENVS = sorted(envs.ENVS)


@pytest.mark.parametrize("name", ALL_ENVS)
class TestEnvs:
    def test_reset_step_shapes(self, name):
        env = envs.make(name)
        state = env.reset(jax.random.PRNGKey(0), env.train_tasks()[0])
        obs = env.observe(state)
        assert obs.shape == (env.obs_dim,)
        state, r = env.step(state, jnp.zeros((env.act_dim,)))
        assert jnp.isfinite(r)

    def test_task_protocol_8_train_72_eval(self, name):
        env = envs.make(name)
        assert env.train_tasks().shape[0] == 8
        assert env.eval_tasks().shape[0] == 72

    def test_train_eval_tasks_disjoint(self, name):
        """Eval tasks are UNSEEN: none coincides with a training task."""
        env = envs.make(name)
        train = np.asarray(env.train_tasks())[:, None, :]
        ev = np.asarray(env.eval_tasks())[None, :, :]
        dist = np.abs(train - ev).max(axis=-1)      # (8, 72) pairwise
        assert dist.min() > 1e-3

    def test_actuator_mask_disables(self, name):
        env = envs.make(name)
        mask = jnp.zeros((env.act_dim,))
        state = env.reset(jax.random.PRNGKey(0), env.train_tasks()[0],
                          actuator_mask=mask)
        s1, _ = env.step(state, jnp.ones((env.act_dim,)))
        s2, _ = env.step(state, -jnp.ones((env.act_dim,)))
        np.testing.assert_allclose(np.asarray(s1.phys), np.asarray(s2.phys),
                                   atol=1e-6)

    def test_action_clipping(self, name):
        """Actions saturate at [-1, 1]: wild actions behave like +-1."""
        env = envs.make(name)
        state = env.reset(jax.random.PRNGKey(0), env.train_tasks()[0])
        s_wild, r_wild = env.step(state, 100.0 * jnp.ones((env.act_dim,)))
        s_unit, r_unit = env.step(state, jnp.ones((env.act_dim,)))
        assert np.array_equal(np.asarray(s_wild.phys),
                              np.asarray(s_unit.phys))
        assert np.array_equal(np.asarray(r_wild), np.asarray(r_unit))

    def test_params_vector_matches_static_defaults(self, name):
        """dynamics(phys, force, default_params()) is bit-identical to the
        static dataclass-field path (the scenario engine's contract)."""
        env = envs.make(name)
        state = env.reset(jax.random.PRNGKey(1), env.train_tasks()[1])
        a = 0.3 * jnp.ones((env.act_dim,))
        s1, r1 = env.step(state, a)
        s2, r2 = env.step(state, a, params=env.default_params())
        assert np.array_equal(np.asarray(s1.phys), np.asarray(s2.phys))
        assert np.array_equal(np.asarray(r1), np.asarray(r2))
        assert len(env.PARAM_NAMES) == env.default_params().shape[0]

    @given(seed=st.integers(0, 100))
    @settings(max_examples=5, deadline=None)
    def test_rollout_finite(self, name, seed):
        env = envs.make(name)
        state = env.reset(jax.random.PRNGKey(seed), env.train_tasks()[0])

        def body(s, t):
            a = jnp.sin(t * jnp.ones((env.act_dim,)))
            s, r = env.step(s, a)
            return s, r

        _, rs = jax.lax.scan(body, state, jnp.arange(50))
        assert bool(jnp.isfinite(rs).all())


class TestEnvDtypes:
    @pytest.mark.parametrize("name", ALL_ENVS)
    def test_state_leaf_dtypes_pinned(self, name):
        env = envs.make(name)
        st_ = env.reset(jax.random.PRNGKey(0), env.train_tasks()[0])
        assert st_.phys.dtype == jnp.float32
        assert st_.task.dtype == jnp.float32
        assert st_.actuator_mask.dtype == jnp.float32
        assert st_.t.dtype == jnp.int32
        assert env.default_params().dtype == jnp.float32

    def test_dtypes_pinned_under_x64(self):
        """Regression: `Env.reset`'s default actuator mask (and every other
        EnvState leaf) must stay float32/int32 even with the global x64
        flag on — run in a subprocess so the flag cannot leak into this
        process's other tests."""
        code = textwrap.dedent("""
            import jax
            jax.config.update("jax_enable_x64", True)
            import jax.numpy as jnp
            from repro import envs
            for name, cls in envs.ENVS.items():
                env = cls()
                st = env.reset(jax.random.PRNGKey(0), env.train_tasks()[0])
                assert st.phys.dtype == jnp.float32, (name, st.phys.dtype)
                assert st.task.dtype == jnp.float32, (name, st.task.dtype)
                assert st.actuator_mask.dtype == jnp.float32, (
                    name, st.actuator_mask.dtype)
                assert st.t.dtype == jnp.int32, (name, st.t.dtype)
                assert env.default_params().dtype == jnp.float32, name
                st2, r = env.step(st, jnp.zeros((env.act_dim,), jnp.float32))
                assert st2.t.dtype == jnp.int32, (name, st2.t.dtype)
            print("x64-ok")
        """)
        proc = subprocess.run([sys.executable, "-c", code],
                              capture_output=True, text=True, timeout=300)
        assert proc.returncode == 0, proc.stderr
        assert "x64-ok" in proc.stdout


class TestPEPG:
    def test_optimizes_quadratic(self):
        cfg = es.PEPGConfig(num_params=4, pop_pairs=16, lr_mu=0.3,
                            sigma_init=0.3, rank_shaping=True)
        target = jnp.asarray([1.0, -2.0, 0.5, 3.0])

        def fitness(pop, key):
            return -jnp.sum((pop - target) ** 2, axis=-1)

        state, hist = es.run(cfg, fitness, jax.random.PRNGKey(0), 150)
        assert float(jnp.sum((state.mu - target) ** 2)) < 0.5
        assert float(hist[-1]) > float(hist[0])

    def test_antithetic_layout(self):
        cfg = es.PEPGConfig(num_params=3, pop_pairs=5)
        state = es.init(cfg, jax.random.PRNGKey(0))
        pop, eps = es.ask(cfg, state, jax.random.PRNGKey(1))
        assert pop.shape == (10, 3)
        np.testing.assert_allclose(
            np.asarray(pop[:5] + pop[5:]),
            np.broadcast_to(np.asarray(2 * state.mu[None]), (5, 3)),
            atol=1e-6)

    def test_elitism_tracks_best(self):
        cfg = es.PEPGConfig(num_params=2, pop_pairs=4)
        state = es.init(cfg, jax.random.PRNGKey(0))
        pop, eps = es.ask(cfg, state, jax.random.PRNGKey(1))
        fit = jnp.arange(8.0)
        state = es.tell(cfg, state, eps, fit)
        assert float(state.best_fitness) == 7.0
        np.testing.assert_allclose(np.asarray(state.best_theta),
                                   np.asarray(pop[7]), atol=1e-6)


class TestTwoPhase:
    def test_phase1_improves_fitness(self):
        """A short offline ES run on the direction task must improve mean
        return (the paper's Phase 1, miniaturized)."""
        env = envs.make("direction", episode_len=40)
        cfg = adaptation.AdaptationConfig(hidden=16, timesteps=2,
                                          pop_pairs=8, generations=8)
        theta, hist, scfg = adaptation.optimize_rule(env, cfg)
        # 8 generations is tiny; the mean fitness is noisy generation-to-
        # generation, so assert the search FOUND better rules than it
        # started with rather than that the last generation is the best.
        assert float(max(hist)) > float(hist[0])

    def test_phase2_zero_shot_generalization(self):
        """The learned rule (not weights) transfers to unseen tasks with
        weights starting from zero."""
        env = envs.make("direction", episode_len=40)
        cfg = adaptation.AdaptationConfig(hidden=16, timesteps=2,
                                          pop_pairs=8, generations=8)
        theta, _, scfg = adaptation.optimize_rule(env, cfg)
        rets = adaptation.evaluate_generalization(env, scfg, theta)
        assert rets.shape == (72,)
        assert bool(jnp.isfinite(rets).all())

    def test_actuator_failure_mask_applies(self):
        env = envs.make("direction", episode_len=30)
        cfg = adaptation.AdaptationConfig(hidden=8, timesteps=2)
        scfg = adaptation.make_snn_config(env, cfg)
        theta = snn.flatten_theta(snn.init_theta(scfg, jax.random.PRNGKey(0)))
        mask = jnp.ones((env.act_dim,)).at[0].set(0.0)
        r = adaptation.episode_return(env, scfg, theta,
                                      env.train_tasks()[0],
                                      jax.random.PRNGKey(1),
                                      actuator_mask=mask, mask_after=10)
        assert jnp.isfinite(r)
