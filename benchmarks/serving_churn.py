"""Session-serving churn: continuous batching into a fixed-shape slot pool.

Poisson arrivals and geometric departures drive admit/evict churn against a
`FleetScheduler` pool while every occupant learns online (fleet-mode fused
dual-engine steps).  Sweeps slot count x churn rate and reports, per cell:

  * pool steps/s and controller-steps/s (steps/s x mean occupancy),
  * admission latency, p50/mean ms — the full user-visible cost of
    `admit(evict_lru=True)`: SessionStore checkout (disk restore or
    zero-init) + the jitted slot scatter, PLUS, whenever the pool is full,
    evicting the displaced session (gather + write-through persist),
  * recompiles after warm-up — PINNED AT ZERO: the pool tensor shape is
    fixed, slot indices are traced, and occupancy is a runtime `active`
    mask, so churn never retraces anything (asserted, not just reported),
  * evict -> persist -> re-admit bit-equality through the DISK store, with
    the re-admitted session landing in a different slot (asserted),
  * idle-slot freeze: a vacated slot's weights are bit-unchanged after N
    further pool steps (asserted — this is the `active`-mask contract that
    makes fixed-shape batching semantically correct).

    PYTHONPATH=src python benchmarks/serving_churn.py [--smoke] [--impl ...]

Writes benchmarks/results/serving_churn.json (or _smoke.json under --smoke
so CI never clobbers the checked-in full-sweep artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

from repro.core import snn
from repro.serving import FleetScheduler, SessionStore

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def drive_for(uid: str, t: int, n: int) -> np.ndarray:
    phase = (hash(uid) % 97) / 97.0
    return np.sin(0.3 * t + phase + np.arange(n)).astype(np.float32)


def churn_cell(cfg, theta, slots: int, arrival: float, depart: float,
               steps: int, root: str, seed: int = 0) -> dict:
    """One sweep cell: run `steps` pool steps under Poisson churn."""
    rng = np.random.default_rng(seed)
    # warm-cache capacity deliberately SMALLER than the recycled-uid pool:
    # re-admissions overflow the LRU cache and exercise the disk-restore
    # path, so admit_ms genuinely includes restore I/O (disk_restores > 0
    # in the checked-in results, not just warm hits)
    store = SessionStore(root=root, capacity=max(1, slots // 2))
    sched = FleetScheduler(cfg, theta, slots=slots, store=store)
    n_in = cfg.layer_sizes[0]

    # Warm-up: touch every jitted program once (pool step with and without
    # occupancy churn) so the measured phase sees only cached executables.
    sched.admit("warm")
    sched.step({"warm": drive_for("warm", 0, n_in)})
    sched.evict("warm")
    sched.admit("warm")
    sched.step({"warm": drive_for("warm", 1, n_in)})
    sched.evict("warm")
    warm_compiles = sched.compile_count()

    user_pool = [f"u{i:03d}" for i in range(4 * slots)]  # ids recycle ->
    next_uid = 0                                         # disk restores
    admit_lat = []
    n_admits = 2                         # the benchmark's OWN event log
    occupancy = 0                        # (warm-up did 2 admits/2 evicts)
    t0 = time.perf_counter()
    for t in range(steps):
        for _ in range(int(rng.poisson(arrival))):
            uid = user_pool[next_uid % len(user_pool)]
            next_uid += 1
            if uid in sched.user_slot:
                continue
            ta = time.perf_counter()
            sched.admit(uid, evict_lru=True)
            admit_lat.append(time.perf_counter() - ta)
            n_admits += 1
        for uid in list(sched.active_users):
            if rng.random() < depart:
                sched.evict(uid)
        sched.step({u: drive_for(u, t, n_in) for u in sched.active_users})
        occupancy += len(sched.user_slot)
    wall = time.perf_counter() - t0

    recompiles = sched.compile_count() - warm_compiles
    assert recompiles == 0, (
        f"churn caused {recompiles} recompiles — the fixed-shape contract "
        "is broken")

    # ---- idle-slot freeze proof ------------------------------------------
    victim = sched.active_users[0] if sched.active_users else None
    if victim is not None:
        sched.evict(victim)
    vacant = sched.slot_user.index(None)
    frozen_before = [np.asarray(w[vacant]).copy() for w in sched.fleet.w]
    for t in range(10):
        sched.step({u: drive_for(u, 1000 + t, n_in)
                    for u in sched.active_users})
    idle_frozen = all(
        (np.asarray(w[vacant]) == b).all()
        for w, b in zip(sched.fleet.w, frozen_before))
    assert idle_frozen, "idle slot drifted — active mask is not a no-op"

    # ---- metrics reconciliation ------------------------------------------
    # The store's obs counters must agree with the benchmark's own event
    # log: every admission is exactly one checkout (warm hit | disk
    # restore | fresh create), every eviction exactly one durable persist.
    snap = store.metrics.snapshot()

    def ctr(name):
        return int(snap[name]["value"])

    checkouts = (ctr("session_store_warm_hits_total")
                 + ctr("session_store_restores_total")
                 + ctr("session_store_creates_total"))
    assert checkouts == n_admits, (
        f"store checkouts {checkouts} != admissions {n_admits} — the obs "
        "counters drifted from the event log")
    assert ctr("session_store_persists_total") == sched.evictions, (
        f"store persists {ctr('session_store_persists_total')} != "
        f"evictions {sched.evictions}")
    pool_snap = sched.metrics.snapshot()
    assert int(pool_snap["pool_admissions_total"]["value"]) == n_admits
    assert int(pool_snap["pool_evictions_total"]["value"]) == sched.evictions

    lat_ms = sorted(x * 1e3 for x in admit_lat) or [0.0]
    return {
        "slots": slots, "arrival_rate": arrival, "depart_rate": depart,
        "steps": steps,
        "steps_per_s": steps / wall,
        "controller_steps_per_s": occupancy / wall,
        "mean_occupancy": occupancy / steps,
        "admissions": len(admit_lat), "evictions": sched.evictions,
        "disk_restores": store.restores,
        "admit_ms_p50": lat_ms[len(lat_ms) // 2],
        "admit_ms_mean": float(np.mean(lat_ms)),
        "compiled_programs": warm_compiles,
        "recompiles_after_warmup": recompiles,
        "idle_slot_frozen": bool(idle_frozen),
        "warm_hits": ctr("session_store_warm_hits_total"),
        "store_creates": ctr("session_store_creates_total"),
        "store_persists": ctr("session_store_persists_total"),
        "metrics_reconciled": True,
    }


def evict_restore_bit_equality(cfg, theta, root: str) -> bool:
    """Probe trajectory: interrupted (evict -> DISK persist -> re-admit into
    a DIFFERENT slot) vs uninterrupted; must match bit for bit."""
    n_in = cfg.layer_sizes[0]

    def trajectory(interrupt: bool, sub: str):
        store = SessionStore(root=os.path.join(root, sub))
        sched = FleetScheduler(cfg, theta, slots=2, store=store)
        sched.admit("probe")                    # slot 0
        outs = []
        for t in range(16):
            if interrupt and t == 6:
                sched.evict("probe")            # persisted to disk
                store._warm.clear()             # force the DISK restore path
                sched.admit("rival")            # rival takes slot 0
                sched.step({"rival": drive_for("rival", 0, n_in)})
                assert sched.admit("probe") == 1  # resumes in the OTHER slot
            outs.append(np.asarray(sched.step(
                {u: drive_for(u, t, n_in) for u in sched.active_users}
            )["probe"]))
        return np.stack(outs)

    a = trajectory(False, "uninterrupted")
    b = trajectory(True, "interrupted")
    return bool((a == b).all())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single tiny cell for CI (seconds)")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"])
    ap.add_argument("--steps", type=int, default=None,
                    help="pool steps per sweep cell (default 200; smoke 25)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        # a non-default --steps run gets its own file so CI/quick sweeps
        # never clobber the checked-in 200-step artifact (same convention
        # as fleet_throughput's _capped results)
        capped = args.steps is not None and args.steps != 200
        name = ("serving_churn_smoke.json" if args.smoke else
                "serving_churn_capped.json" if capped else
                "serving_churn.json")
        args.out = os.path.join(RESULTS, name)

    cfg = snn.SNNConfig(layer_sizes=(16, 128, 8), timesteps=2,
                        impl=args.impl)
    theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
    steps = args.steps or (25 if args.smoke else 200)
    cells = ([(4, 0.3, 0.08)] if args.smoke else
             [(s, a, d) for s in (4, 16, 64)
              for a, d in ((0.1, 0.02), (0.5, 0.08), (2.0, 0.25))])

    sweep = []
    print("slots,arrival,depart,steps_per_s,ctrl_steps_per_s,admit_ms_p50,"
          "recompiles")
    with tempfile.TemporaryDirectory() as root:
        for slots, arrival, depart in cells:
            row = churn_cell(cfg, theta, slots, arrival, depart, steps,
                             os.path.join(root, f"s{slots}a{arrival}"))
            sweep.append(row)
            print(f"{slots},{arrival},{depart},{row['steps_per_s']:.1f},"
                  f"{row['controller_steps_per_s']:.1f},"
                  f"{row['admit_ms_p50']:.2f},"
                  f"{row['recompiles_after_warmup']}")
        bit_equal = evict_restore_bit_equality(cfg, theta, root)
    assert bit_equal, "evict -> restore trajectory diverged!"
    print(f"evict_restore_bit_identical={bit_equal}")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"impl": args.impl, "layer_sizes": list(cfg.layer_sizes),
                   "steps_per_cell": steps, "smoke": bool(args.smoke),
                   "evict_restore_bit_identical": bit_equal,
                   "sweep": sweep}, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
