"""Fleet mode (per-request batched weights) — the many-user serving contract.

Pins four guarantees plus this PR's satellite bugfix guards:

  1. `engine.layer_step` with ``w (B, N, M)`` is BIT-equal to per-sample
     ``vmap(layer_step)`` on the xla oracle — fleet mode is exactly B
     independent plastic layers, fused into one launch.
  2. xla vs pallas-interpret parity for the fleet kernel across shapes,
     dtypes, teach/readout/plastic modes, AND postsynaptic widths that are
     not a multiple of block_m (tile-padding edge), for both the fleet and
     the shared-weight kernels.
  3. The `core/snn` fleet API (``init_state(batch=..., fleet=True)``) steps
     B controllers as one NetworkState and matches B vmapped controllers.
  4. `models/plastic.decode_step` (the LM adapter) matches the historical
     vmap recipe bit-for-bit on the oracle and keeps streams independent.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adaptation, engine, snn
from repro import envs


def _fleet_layer(key, b, n, m, dtype=jnp.float32, plastic=True):
    ks = jax.random.split(key, 6)
    x = (jax.random.uniform(ks[0], (b, n)) > 0.5).astype(dtype)
    state = engine.LayerState(
        w=(0.1 * jax.random.normal(ks[1], (b, n, m))).astype(dtype),
        v=(0.1 * jax.random.normal(ks[2], (b, m))).astype(dtype),
        trace_pre=jax.random.uniform(ks[3], (b, n)).astype(dtype),
        trace_post=jax.random.uniform(ks[4], (b, m)).astype(dtype),
        theta=(0.01 * jax.random.normal(ks[5], (4, n, m))).astype(dtype)
        if plastic else None)
    return state, x


def _vmap_reference(state, x, params, impl="xla", teach=None):
    """The historical per-request recipe: vmap over the unbatched step."""
    return jax.vmap(
        lambda l, xx, th: engine.layer_step(
            l, xx, params=params, impl=impl, teach=th),
        in_axes=(engine.LayerState(w=0, v=0, trace_pre=0, trace_post=0,
                                   theta=None), 0,
                 None if teach is None else 0))(state, x, teach)


class TestFleetBitEquivalence:
    """Fleet xla == vmap(layer_step) xla, bit for bit."""

    @pytest.mark.parametrize("b,n,m", [(1, 8, 8), (4, 10, 30), (3, 17, 257),
                                       (8, 128, 128)])
    def test_matches_vmap(self, b, n, m):
        state, x = _fleet_layer(jax.random.PRNGKey(b + n + m), b, n, m)
        params = engine.EngineParams()
        fleet_s, fleet_out = engine.layer_step(state, x, params=params,
                                               impl="xla")
        ref_s, ref_out = _vmap_reference(state, x, params)
        np.testing.assert_array_equal(np.asarray(fleet_out),
                                      np.asarray(ref_out))
        for name, a, rb in (("w", fleet_s.w, ref_s.w),
                            ("v", fleet_s.v, ref_s.v),
                            ("trace_post", fleet_s.trace_post,
                             ref_s.trace_post)):
            assert a.shape == rb.shape, name
            np.testing.assert_array_equal(np.asarray(a), np.asarray(rb),
                                          err_msg=name)

    @pytest.mark.parametrize("spiking", [True, False])
    def test_matches_vmap_teach_and_readout(self, spiking):
        b, n, m = 3, 12, 20
        state, x = _fleet_layer(jax.random.PRNGKey(7), b, n, m)
        teach = 2.0 * jax.random.normal(jax.random.PRNGKey(8), (b, m))
        params = engine.EngineParams(spiking=spiking)
        fleet_s, fleet_out = engine.layer_step(state, x, params=params,
                                               impl="xla", teach=teach)
        ref_s, ref_out = _vmap_reference(state, x, params, teach=teach)
        np.testing.assert_array_equal(np.asarray(fleet_out),
                                      np.asarray(ref_out))
        np.testing.assert_array_equal(np.asarray(fleet_s.w),
                                      np.asarray(ref_s.w))

    # M == B is the dangerous case: a wrongly-vmapped (M,) teach would be
    # consumed silently along the stream axis instead of broadcasting.
    @pytest.mark.parametrize("b,m", [(3, 20), (4, 4)])
    def test_unbatched_teach_broadcasts_to_every_stream(self, b, m):
        state, x = _fleet_layer(jax.random.PRNGKey(b * 31 + m), b, 10, m)
        teach1 = 2.0 * jax.random.normal(jax.random.PRNGKey(9), (m,))
        teach_b = jnp.broadcast_to(teach1, (b, m))
        for impl in ("xla", "pallas-interpret"):
            s1, o1 = engine.layer_step(state, x, impl=impl, teach=teach1)
            s2, o2 = engine.layer_step(state, x, impl=impl, teach=teach_b)
            np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
            np.testing.assert_array_equal(np.asarray(s1.w), np.asarray(s2.w))

    def test_streams_are_independent(self):
        """Zeroing one stream's input must not touch other streams' weights."""
        state, x = _fleet_layer(jax.random.PRNGKey(3), 4, 16, 16)
        s_all, _ = engine.layer_step(state, x, impl="xla")
        x0 = x.at[0].set(0.0)
        s_zero, _ = engine.layer_step(state, x0, impl="xla")
        np.testing.assert_array_equal(np.asarray(s_all.w[1:]),
                                      np.asarray(s_zero.w[1:]))

    def test_shape_mismatch_raises(self):
        state, x = _fleet_layer(jax.random.PRNGKey(4), 4, 8, 8)
        with pytest.raises(ValueError):
            engine.layer_step(state, x[:2], impl="xla")
        with pytest.raises(ValueError):
            engine.layer_step(state, x[0], impl="xla")


class TestFleetBackendParity:
    """pallas-interpret fleet kernel vs the xla fleet oracle."""

    def _assert_parity(self, state, x, params, teach=None, tol=1e-5):
        ref_s, ref_out = engine.layer_step(state, x, params=params,
                                           impl="xla", teach=teach)
        pal_s, pal_out = engine.layer_step(state, x, params=params,
                                           impl="pallas-interpret",
                                           teach=teach)
        for name, r, p in (("out", ref_out, pal_out), ("w", ref_s.w, pal_s.w),
                           ("v", ref_s.v, pal_s.v),
                           ("trace_post", ref_s.trace_post,
                            pal_s.trace_post)):
            assert r.shape == p.shape, name
            np.testing.assert_allclose(
                np.asarray(r, np.float32), np.asarray(p, np.float32),
                rtol=tol, atol=tol, err_msg=name)

    @pytest.mark.parametrize("b,n,m", [(1, 8, 8), (4, 32, 48), (2, 100, 130),
                                       (8, 128, 128), (3, 17, 257)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, b, n, m, dtype):
        state, x = _fleet_layer(jax.random.PRNGKey(b * 131 + n + m), b, n, m,
                                dtype)
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        self._assert_parity(state, x, engine.EngineParams(), tol=tol)

    # the tile-padding edge: m deliberately NOT a multiple of block_m
    @pytest.mark.parametrize("m,block_m", [(48, 32), (130, 128), (40, 16),
                                           (257, 64)])
    def test_padded_postsynaptic_tiles(self, m, block_m):
        state, x = _fleet_layer(jax.random.PRNGKey(m + block_m), 3, 24, m)
        self._assert_parity(state, x, engine.EngineParams(block_m=block_m))

    @pytest.mark.parametrize("m,block_m", [(48, 32), (40, 16), (257, 64)])
    def test_padded_tiles_shared_weights(self, m, block_m):
        """Same edge for the SHARED-weight kernel (batch-averaged dw)."""
        b, n = 3, 24
        ks = jax.random.split(jax.random.PRNGKey(m * 7 + block_m), 6)
        state = engine.LayerState(
            w=0.1 * jax.random.normal(ks[1], (n, m)),
            v=0.1 * jax.random.normal(ks[2], (b, m)),
            trace_pre=jax.random.uniform(ks[3], (b, n)),
            trace_post=jax.random.uniform(ks[4], (b, m)),
            theta=0.01 * jax.random.normal(ks[5], (4, n, m)))
        x = (jax.random.uniform(ks[0], (b, n)) > 0.5).astype(jnp.float32)
        params = engine.EngineParams(block_m=block_m)
        ref_s, ref_out = engine.layer_step(state, x, params=params,
                                           impl="xla")
        pal_s, pal_out = engine.layer_step(state, x, params=params,
                                           impl="pallas-interpret")
        np.testing.assert_allclose(np.asarray(ref_out), np.asarray(pal_out),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ref_s.w), np.asarray(pal_s.w),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("spiking", [True, False])
    def test_teach_and_readout(self, spiking):
        state, x = _fleet_layer(jax.random.PRNGKey(11), 2, 10, 30)
        teach = 2.0 * jax.random.normal(jax.random.PRNGKey(12), (2, 30))
        self._assert_parity(state, x, engine.EngineParams(spiking=spiking),
                            teach=teach)

    def test_plastic_off_passes_weights_through(self):
        state, x = _fleet_layer(jax.random.PRNGKey(13), 3, 16, 16,
                                plastic=False)
        params = engine.EngineParams(plastic=False)
        self._assert_parity(state, x, params)
        new_s, _ = engine.layer_step(state, x, params=params,
                                     impl="pallas-interpret")
        np.testing.assert_array_equal(np.asarray(new_s.w),
                                      np.asarray(state.w))


class TestFleetSNN:
    """init_state(batch, fleet=True): B controllers as one NetworkState."""

    def _cfg(self, impl="xla"):
        return snn.SNNConfig(layer_sizes=(6, 16, 4), timesteps=3, impl=impl)

    def test_init_shapes(self):
        cfg = self._cfg()
        state = snn.init_state(cfg, batch=5, fleet=True)
        assert state.w[0].shape == (5, 6, 16)
        assert state.w[1].shape == (5, 16, 4)
        assert state.v[0].shape == (5, 16)
        assert state.trace[0].shape == (5, 6)

    def test_fleet_requires_batch(self):
        with pytest.raises(ValueError):
            snn.init_state(self._cfg(), fleet=True)

    def test_fleet_controller_matches_vmap(self):
        """One fleet controller_step == B vmapped per-sample steps (xla)."""
        cfg = self._cfg()
        b = 4
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.5)
        obs = jnp.sin(jnp.arange(b * 6, dtype=jnp.float32)).reshape(b, 6)
        fleet_state = snn.init_state(cfg, batch=b, fleet=True)
        f_state, f_act = snn.controller_step(cfg, fleet_state, theta, obs)

        per_axes = engine.NetworkState(w=0, v=0, trace=0, t=None)
        v_state, v_act = jax.vmap(
            lambda st, o: snn.controller_step(cfg, st, theta, o),
            in_axes=(per_axes, 0))(fleet_state, obs)
        np.testing.assert_array_equal(np.asarray(f_act), np.asarray(v_act))
        for a, rb in zip(f_state.w, v_state.w):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(rb))

    def test_fleet_backend_parity_rollout(self):
        """Fleet rollouts agree between xla and pallas-interpret."""
        results = {}
        for impl in ("xla", "pallas-interpret"):
            cfg = self._cfg(impl)
            theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.5)
            state = snn.init_state(cfg, batch=3, fleet=True)
            obs = jnp.linspace(-1, 1, 18).reshape(3, 6)
            for _ in range(2):
                state, act = snn.controller_step(cfg, state, theta, obs)
            results[impl] = (act, state.w)
        np.testing.assert_allclose(np.asarray(results["xla"][0]),
                                   np.asarray(results["pallas-interpret"][0]),
                                   rtol=1e-5, atol=1e-5)
        for a, rb in zip(results["xla"][1], results["pallas-interpret"][1]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(rb),
                                       rtol=1e-5, atol=1e-5)


class TestPlasticAdapterFleet:
    """models/plastic.decode_step rides the fleet path, not vmap."""

    def _setup(self, b=3, n=8, d=12):
        from repro.configs import get_smoke
        cfg = get_smoke("qwen3-4b").with_(plastic_adapter=True,
                                          adapter_neurons=n)
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        params = {
            "p_in": jax.random.normal(ks[0], (cfg.d_model, n)) * 0.5,
            "p_out": jax.random.normal(ks[1], (n, cfg.d_model)) * 0.5,
            "theta": jax.random.normal(ks[2], (4, n, n)) * 0.3,
            "scale": jnp.asarray(0.5, jnp.float32),
        }
        state = {
            "w_fast": jnp.zeros((b, n, n)), "v1": jnp.zeros((b, n)),
            "v2": jnp.zeros((b, n)), "tr1": jnp.zeros((b, n)),
            "tr2": jnp.zeros((b, n)), "t": jnp.zeros((b,), jnp.int32),
        }
        h = jax.random.normal(ks[3], (b, 1, cfg.d_model))
        return cfg, params, state, h

    def test_matches_legacy_vmap_recipe(self):
        from repro.core.plasticity import update_trace
        from repro.core.snn import lif_step
        from repro.models import plastic

        cfg, params, state, h = self._setup()
        h_new, s_new = plastic.decode_step(params, state, h, cfg)

        # the pre-fleet implementation, verbatim
        drive = jnp.einsum("bd,dn->bn", h[:, 0].astype(jnp.float32),
                           params["p_in"].astype(jnp.float32))
        v1, s1 = lif_step(state["v1"], drive, plastic.LIF)
        tr1 = update_trace(state["tr1"], s1, 0.8)
        ep = engine.EngineParams(trace_decay=0.8, w_clip=4.0)
        layer = engine.LayerState(
            w=state["w_fast"], v=state["v2"], trace_pre=tr1,
            trace_post=state["tr2"],
            theta=params["theta"].astype(jnp.float32))
        layer, s2 = jax.vmap(
            lambda l, x: engine.layer_step(l, x, params=ep, impl="xla"),
            in_axes=(engine.LayerState(w=0, v=0, trace_pre=0, trace_post=0,
                                       theta=None), 0))(layer, s1)
        out = jnp.einsum("bn,nd->bd", s2, params["p_out"].astype(jnp.float32))
        h_ref = h + (params["scale"] * out[:, None, :]).astype(h.dtype)

        np.testing.assert_array_equal(np.asarray(h_new), np.asarray(h_ref))
        np.testing.assert_array_equal(np.asarray(s_new["w_fast"]),
                                      np.asarray(layer.w))

    def test_streams_adapt_independently(self):
        from repro.models import plastic

        cfg, params, state, h = self._setup()
        h0 = h.at[0].set(0.0)
        _, s_a = plastic.decode_step(params, state, h, cfg)
        _, s_b = plastic.decode_step(params, state, h0, cfg)
        # stream 0 differs, the other streams' fast weights are untouched
        np.testing.assert_array_equal(np.asarray(s_a["w_fast"][1:]),
                                      np.asarray(s_b["w_fast"][1:]))


class TestRateEncodingKeyGuard:
    """encoding="rate" without a PRNG key must fail loudly at entry."""

    def _cfg(self):
        return snn.SNNConfig(layer_sizes=(6, 8, 4), timesteps=2,
                             encoding="rate")

    def test_controller_step_raises_without_key(self):
        cfg = self._cfg()
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="PRNG key"):
            snn.controller_step(cfg, snn.init_state(cfg), theta,
                                jnp.ones((6,)))

    def test_classify_window_raises_without_key(self):
        cfg = dataclasses.replace(self._cfg(), spiking_readout=True)
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="PRNG key"):
            snn.classify_window(cfg, snn.init_state(cfg), theta,
                                jnp.ones((6,)))

    def test_encode_raises_without_key(self):
        with pytest.raises(ValueError, match="PRNG key"):
            snn.encode(self._cfg(), jnp.ones((6,)), None, jnp.zeros((), jnp.int32))

    def test_rate_encoding_with_key_works(self):
        cfg = self._cfg()
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.5)
        state, action = snn.controller_step(
            cfg, snn.init_state(cfg), theta, 0.5 * jnp.ones((6,)),
            key=jax.random.PRNGKey(1))
        assert action.shape == (4,)
        assert bool(jnp.isfinite(action).all())

    def test_rate_encoding_is_stochastic_across_timesteps(self):
        cfg = self._cfg()
        obs = 0.5 * jnp.ones((6,))
        key = jax.random.PRNGKey(0)
        d0 = snn.encode(cfg, obs, key, jnp.asarray(0))
        d1 = snn.encode(cfg, obs, key, jnp.asarray(1))
        assert not np.array_equal(np.asarray(d0), np.asarray(d1))
        assert set(np.unique(np.asarray(d0))) <= {0.0, 1.0}


class TestFitnessPRNG:
    """ES candidates see independent episode randomness unless crn=True."""

    def _setup(self):
        env = envs.make("direction", episode_len=10)
        cfg = adaptation.AdaptationConfig(hidden=8, timesteps=2)
        scfg = adaptation.make_snn_config(env, cfg)
        theta = snn.flatten_theta(
            snn.init_theta(scfg, jax.random.PRNGKey(0), scale=0.1))
        pop = jnp.stack([theta, theta])        # two IDENTICAL candidates
        return env, scfg, pop

    def test_identical_candidates_get_independent_noise(self):
        env, scfg, pop = self._setup()
        fitness = adaptation.make_fitness_fn(env, scfg, env.train_tasks()[:2])
        rets = fitness(pop, jax.random.PRNGKey(7))
        assert float(rets[0]) != float(rets[1])

    def test_crn_couples_the_population(self):
        env, scfg, pop = self._setup()
        fitness = adaptation.make_fitness_fn(env, scfg, env.train_tasks()[:2],
                                             crn=True)
        rets = fitness(pop, jax.random.PRNGKey(7))
        assert float(rets[0]) == float(rets[1])
