"""Paper Table I analogue: per-engine resource/latency breakdown.

The FPGA report counts LUTs/REGs/BRAM/DSP per engine; the TPU-native
equivalent is FLOPs / HBM bytes / roofline-latency per engine stage of the
fused dual-engine step, derived from the kernel's actual shapes at the
paper's controller scale (L1: obs->128, L2: 128->act) and at MNIST scale
(784-1024-10).

Also measures CPU wall time of the PRODUCT layer step —
`core.engine.layer_step`, the same entry point `snn.timestep` and serving
run — under the "xla" backend (and "pallas-interpret" with --interpret),
and — the paper's architectural claim — FUSED dual-engine vs SEQUENTIAL
forward-then-plasticity HBM traffic.
"""
from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import engine
from repro.launch.mesh import HW

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def stage_model(b: int, n: int, m: int, plastic: bool = True) -> dict:
    """Analytic FLOPs/bytes for one fused dual-engine invocation."""
    d = 2  # bf16 storage on TPU (paper: fp16)
    fwd_flops = 2 * b * n * m                 # psum matmul
    lif_flops = 4 * b * m                     # V update + compare + select
    trace_flops = 2 * b * m
    plast_flops = (2 * b * n * m             # Hebbian outer product (MXU)
                   + 4 * n * m               # four-term combine
                   + 2 * n * m)              # w += clip
    fwd_bytes = d * (b * n + n * m + 3 * b * m)
    plast_bytes = d * (4 * n * m + n * m + b * n + b * m)  # theta+w+traces
    seq_bytes = fwd_bytes + plast_bytes + d * n * m  # re-fetch w if unfused
    fused_bytes = fwd_bytes + d * 4 * n * m          # w/traces already resident
    out = {
        "forward": {"flops": fwd_flops + lif_flops + trace_flops,
                    "bytes": fwd_bytes},
        "plasticity": {"flops": plast_flops if plastic else 0,
                       "bytes": plast_bytes if plastic else 0},
        "fused_bytes": fused_bytes,
        "sequential_bytes": seq_bytes,
    }
    for stage in ("forward", "plasticity"):
        s = out[stage]
        s["compute_us"] = s["flops"] / HW["peak_flops_bf16"] * 1e6
        s["memory_us"] = s["bytes"] / HW["hbm_bw"] * 1e6
        s["roofline_us"] = max(s["compute_us"], s["memory_us"])
    return out


def measure_wall(b, n, m, iters=5, impls=("xla",)) -> dict:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    x = (jax.random.uniform(ks[0], (b, n)) > 0.5).astype(jnp.float32)
    layer = engine.LayerState(
        w=0.1 * jax.random.normal(ks[1], (n, m)),
        v=jnp.zeros((b, m)),
        trace_pre=jax.random.uniform(ks[4], (b, n)),
        trace_post=jax.random.uniform(ks[5], (b, m)),
        theta=0.01 * jax.random.normal(ks[2], (4, n, m)))
    step = jax.jit(functools.partial(engine.layer_step,
                                     params=engine.EngineParams()),
                   static_argnames=("impl",))

    res = {}
    for impl in impls:
        out = step(layer, x, impl=impl)                # warm up / compile
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = step(layer, x, impl=impl)
            jax.block_until_ready(out)
        res[f"{impl}_us"] = (time.perf_counter() - t0) / iters * 1e6
    return res


def measure_launch_overhead(iters: int = 20) -> dict:
    """Per-launch floor: a NO-OP `pallas_call` vs the real step kernel.

    The no-op kernel copies one (8, 128) tile — everything it costs is
    launch/dispatch overhead, not compute.  Its share of the control-scale
    dual-engine step is the fraction a per-step schedule burns on launches
    alone, and exactly what the time-fused rollout (`engine.rollout`, one
    launch per K * num_layers steps) amortizes away.
    """
    def _noop(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    x = jnp.zeros((8, 128), jnp.float32)
    fn = jax.jit(pl.pallas_call(
        _noop, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True))
    out = fn(x)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(x)
        jax.block_until_ready(out)
    noop_us = (time.perf_counter() - t0) / iters * 1e6
    wall = measure_wall(1, 8, 128, iters=iters,
                        impls=("pallas-interpret",))
    step_us = wall["pallas-interpret_us"]
    return {"impl": "pallas-interpret",
            "noop_pallas_call_us": noop_us,
            "step_kernel_us": step_us,
            "launch_overhead_fraction": min(1.0, noop_us / step_us)}


def measure_fused_k_sweep(ks=(1, 2, 4, 8), b: int = 16, n: int = 64,
                          m: int = 64, iters: int = 3,
                          impl: str = "pallas-interpret") -> dict:
    """Fused-vs-per-step window timing: K steps per launch vs K launches.

    Both sides run the SAME fleet workload (B per-stream weight sets, one
    plastic layer) jitted; the per-step side issues one `layer_step`
    pallas_call per timestep, the fused side one `engine.rollout` launch
    for the whole window.  Reported per-TIMESTEP so rows are comparable
    across K.
    """
    key = jax.random.PRNGKey(0)
    ks_r = jax.random.split(key, 5)
    x = (jax.random.uniform(ks_r[0], (b, n)) > 0.5).astype(jnp.float32)
    layer = engine.LayerState(
        w=jnp.zeros((b, n, m), jnp.float32),
        v=0.1 * jax.random.normal(ks_r[1], (b, m)),
        trace_pre=jax.random.uniform(ks_r[2], (b, n)),
        trace_post=jax.random.uniform(ks_r[3], (b, m)),
        theta=0.05 * jax.random.normal(ks_r[4], (4, n, m)))
    params = engine.EngineParams(block_m=m)
    net = engine.NetworkState(
        w=(layer.w,), v=(layer.v,),
        trace=(layer.trace_pre, layer.trace_post),
        t=jnp.zeros((), jnp.int32))
    theta = [layer.theta]

    def time_fn(fn, *args):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters * 1e6

    rows = []
    for k in ks:
        def per_step(l, xx):
            for _ in range(k):
                l, _o = engine.layer_step(l, xx, params=params, impl=impl)
            return l
        drives = jnp.broadcast_to(x[None], (k, b, n)).astype(jnp.float32)
        step_us = time_fn(jax.jit(per_step), layer, x)
        fused_us = time_fn(
            jax.jit(functools.partial(engine.rollout, params=[params],
                                      impl=impl)),
            net, theta, drives)
        rows.append({"k": k,
                     "per_step_us_per_step": step_us / k,
                     "fused_us_per_step": fused_us / k,
                     "fused_speedup": step_us / fused_us})
    return {"impl": impl, "batch": b, "n": n, "m": m, "sweep": rows}


def main(quick: bool = False, interpret: bool = False):
    os.makedirs(RESULTS, exist_ok=True)
    # paper scales: control (8-128-8 @ batch 1), MNIST (784-1024-10)
    layers = {
        "control_L1": (1, 8, 128), "control_L2": (1, 128, 8),
        "mnist_L1": (1, 784, 1024), "mnist_L2": (1, 1024, 10),
    }
    impls = ("xla", "pallas-interpret") if interpret else ("xla",)
    rows = {}
    print("layer,engine,flops,bytes,roofline_us,cpu_xla_us")
    for name, (b, n, m) in layers.items():
        sm = stage_model(b, n, m)
        wall = measure_wall(b, n, m, iters=2 if quick else 5, impls=impls)
        rows[name] = {"model": sm, "wall": wall}
        for eng in ("forward", "plasticity"):
            s = sm[eng]
            print(f"{name},{eng},{s['flops']},{s['bytes']},"
                  f"{s['roofline_us']:.3f},{wall['xla_us']:.1f}")
        fused_save = 1 - sm["fused_bytes"] / sm["sequential_bytes"]
        rows[name]["fusion_traffic_saving"] = fused_save
        print(f"{name},fusion_saving,,,{100*fused_save:.1f}%,")
    # end-to-end latency analogue of the paper's 8 us (two layers, roofline)
    total_us = sum(
        max(rows[f"control_L{i}"]["model"][e]["roofline_us"]
            for e in ("forward", "plasticity")) for i in (1, 2))
    rows["control_e2e_roofline_us"] = total_us
    print(f"control_e2e,roofline_total,,,{total_us:.3f},  (paper FPGA: 8 us)")
    # per-launch overhead floor (the cost the fused rollout amortizes)
    lo = measure_launch_overhead(iters=5 if quick else 20)
    rows["launch_overhead"] = lo
    print(f"launch_overhead,noop_vs_step,,,"
          f"{lo['noop_pallas_call_us']:.1f}us/"
          f"{lo['step_kernel_us']:.1f}us,"
          f"{100 * lo['launch_overhead_fraction']:.0f}%")
    # fused-vs-per-step window: K timesteps per launch vs K launches
    sweep = measure_fused_k_sweep(ks=(1, 4) if quick else (1, 2, 4, 8),
                                  iters=2 if quick else 3)
    rows["fused_k_sweep"] = sweep
    for r in sweep["sweep"]:
        print(f"fused_k_sweep,k={r['k']},,,"
              f"{r['per_step_us_per_step']:.0f}us->"
              f"{r['fused_us_per_step']:.0f}us,"
              f"{r['fused_speedup']:.2f}x")
    with open(os.path.join(RESULTS, "engine_breakdown.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv, interpret="--interpret" in sys.argv)
