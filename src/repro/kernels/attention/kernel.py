"""Flash attention Pallas TPU kernel (online softmax, KV-blocked, GQA).

Grid: (B*H, n_q_blocks, n_kv_blocks), kv innermost.  Running max / sum /
output accumulator live in VMEM scratch and persist across the kv walk —
the classic memory-roofline fix: O(S^2) score matrix never materializes in
HBM, each q/k/v tile is DMA'd once.

GQA is resolved in the BlockSpec index maps: query head bh -> kv head
(bh // group), so no jnp.repeat of K/V ever happens (saving HBM bytes —
exactly the wide-fetch-once philosophy of the paper's Plasticity Engine,
applied to attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale, causal, n_kv, block_q, block_kv, kv_len, q_offset):
    i = pl.program_id(1)   # q block
    j = pl.program_id(2)   # kv block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions of this tile's queries/keys
    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0) + q_offset
    k_pos = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)

    # skip fully-masked causal blocks (they are still visited by the grid;
    # on TPU pl.when compiles to a cheap predicated region)
    relevant = True
    if causal:
        relevant = (j * block_kv) <= (i * block_q + block_q - 1 + q_offset)

    @pl.when(relevant if causal else j >= 0)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = k_pos < kv_len
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]                     # (bq, 1)
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)

        v = v_ref[0].astype(jnp.float32)          # (bkv, d)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == n_kv - 1)
    def _epilogue():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)           # fully-masked rows -> 0 out
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           scale: float | None = None,
                           kv_len: int | None = None,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = False):
    """q (B,Sq,H,D), k/v (B,Skv,HKV,D) -> (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    scale = scale if scale is not None else d ** -0.5
    kv_len = skv if kv_len is None else kv_len
    q_offset = skv - sq  # causal: queries are the last sq kv positions

    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    q_pad, kv_pad = (-sq) % bq, (-skv) % bkv
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    sq_p, skv_p = sq + q_pad, skv + kv_pad

    # flatten heads; GQA resolved in index maps
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq_p, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv_p, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv_p, d)

    n_q, n_kv = sq_p // bq, skv_p // bkv
    grid = (b * h, n_q, n_kv)

    def kv_index(bh, i, j):
        return ((bh // h) * hkv + (bh % h) // group, j, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, n_kv=n_kv,
        block_q=bq, block_kv=bkv, kv_len=kv_len, q_offset=q_offset)

    of = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bkv, d), kv_index),
            pl.BlockSpec((1, bkv, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max
            pltpu.VMEM((bq, 128), jnp.float32),   # running denom
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = of.reshape(b, h, sq_p, d).transpose(0, 2, 1, 3)
    return out[:, :sq]
