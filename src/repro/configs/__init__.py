"""Assigned-architecture registry: ``--arch <id>`` resolution.

Each module exports CONFIG (the exact published dims) and SMOKE (a reduced
same-family config for CPU tests).  `get_config(arch)` / `get_smoke(arch)`
are the public API; `ARCHS` lists every selectable id (10 assigned LM archs
+ the paper's own SNN controller).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen2-72b", "internlm2-20b", "qwen3-4b", "qwen1.5-32b",
    "zamba2-7b", "deepseek-moe-16b", "grok-1-314b",
    "musicgen-medium", "pixtral-12b", "mamba2-1.3b",
    "firefly-snn",
]

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def _load(arch: str):
    if arch not in _MOD:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MOD[arch]}")


def get_config(arch: str):
    return _load(arch).CONFIG


def get_smoke(arch: str):
    return _load(arch).SMOKE
