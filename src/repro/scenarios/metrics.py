"""Adaptation metrics: what "robust adaptive control" means, measured.

All metrics reduce a per-step reward array (``(T,)`` or ``(T, B)``, fleet
axis averaged) around a perturbation onset:

  * ``pre``      — mean reward rate over the window before the onset (the
                   adapted, healthy behaviour).
  * ``post``     — mean over the window right after the onset (the damage).
  * ``final``    — mean over the last window of the episode (where the
                   controller ends up).
  * ``drop``     — ``pre - post``: the perturbation-induced return drop.
  * ``recovery_frac`` — ``(final - post) / drop``: the fraction of the drop
                   won back by the end.  1 = full recovery, 0 = none; the
                   paper's claim is that plasticity recovers while frozen
                   weights do not.
  * ``time_to_recover`` — env steps after onset until the trailing
                   window-mean first re-crosses ``pre - (1 - target) *
                   drop`` (default target 0.5, i.e. half the drop won
                   back); -1 if it never does.
"""
from __future__ import annotations

import numpy as np


def adaptation_metrics(rewards, onset: int, window: int = 20,
                       target: float = 0.5) -> dict:
    """Pre/post/final reward rates + recovery around a perturbation onset.

    `rewards` may be jax or numpy, ``(T,)`` or ``(T, B)`` (B averaged).
    ``onset`` is the nominal perturbation step; ``window`` the averaging
    span (clipped to what the episode affords).
    """
    r = np.asarray(rewards, np.float64)
    if r.ndim == 2:
        r = r.mean(axis=1)
    t_total = r.shape[0]
    if not 0 < onset < t_total:
        raise ValueError(f"onset {onset} outside episode of {t_total} steps")
    w = max(1, min(window, onset, t_total - onset))
    pre = float(r[onset - w:onset].mean())
    post = float(r[onset:onset + w].mean())
    final = float(r[t_total - w:].mean())
    drop = pre - post
    recovery = (final - post) / drop if abs(drop) > 1e-9 else float("nan")

    # trailing window-mean after onset; first crossing of the recovery bar
    bar = pre - (1.0 - target) * drop
    ttr = -1
    if drop > 1e-9:
        csum = np.concatenate([[0.0], np.cumsum(r)])
        # a full window must clear the bar (a single lucky step must not)
        for t in range(onset + w, t_total + 1):
            if (csum[t] - csum[t - w]) / w >= bar:
                ttr = t - onset
                break
    return {"pre": pre, "post": post, "final": final, "drop": drop,
            "recovery_frac": float(recovery), "time_to_recover": ttr,
            "window": w, "onset": onset}


def ablation_summary(plastic: dict, frozen: dict) -> dict:
    """Side-by-side of a plasticity-on run and its frozen-weights ablation
    (same seed, same schedule): the paper's core claim is
    ``plastic.recovery_frac`` high while ``frozen.recovery_frac`` is not."""
    return {
        "plastic": plastic, "frozen": frozen,
        "recovery_gap": plastic["recovery_frac"] - frozen["recovery_frac"],
    }
