"""firefly-snn — the paper's OWN model (Sec. IV-A).

Three-layer fully-connected plastic SNN controller: 128 hidden neurons for
continuous control, 1024 for the MNIST task (784-1024-10, Table II).
These are `SNNConfig`s (core/snn.py), not ModelConfigs — the controller is
the FPGA-resident network the FireFly-P accelerator runs."""
from repro.core.snn import SNNConfig

# continuous control (obs/act dims are env-dependent; 8-dim default task)
CONFIG = SNNConfig(
    layer_sizes=(8, 128, 8), timesteps=4, trace_decay=0.8, plastic=True)

# MNIST online-learning variant (Table II: 784-1024-10)
MNIST = SNNConfig(
    layer_sizes=(784, 1024, 10), timesteps=8, trace_decay=0.8,
    spiking_readout=True, plastic=True)

SMOKE = SNNConfig(
    layer_sizes=(8, 32, 4), timesteps=2, trace_decay=0.8, plastic=True)
