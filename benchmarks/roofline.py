"""§Roofline reader: turns the dry-run JSONs into the per-cell roofline
table (three terms, dominant bottleneck, MODEL_FLOPS ratio, one-line fix).

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single|multi]

Reads benchmarks/results/dryrun/<mesh>/*.json (written by
repro.launch.dryrun) and writes benchmarks/results/roofline_<mesh>.json +
a markdown table to stdout (EXPERIMENTS.md §Roofline is generated from
this).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")

FIX_HINTS = {
    ("compute",): "increase arithmetic intensity (larger per-chip batch) or "
                  "accept — compute-bound IS the roofline target",
    ("memory",): "fuse elementwise chains, keep bf16 end-to-end, shard the "
                 "dominant resident tensor over more axes",
    ("collective",): "activation sharding sp (RS+AG halves AR), bf16 "
                     "collectives, fewer microbatches, overlap via async "
                     "collectives",
}


def load(mesh: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "dryrun", mesh,
                                              "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(mesh: str = "single"):
    rows = load(mesh)
    out = []
    for r in rows:
        if r.get("skipped"):
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "skipped": r["skipped"]})
            continue
        if "error" in r:
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "error": r["error"]})
            continue
        t = r["roofline"]
        hlo_global_flops = r["hlo"]["flops_per_device"] * r["chips"]
        ratio = r["model_flops"] / hlo_global_flops if hlo_global_flops else 0
        arch = r["arch"] + ("+plastic" if r.get("plastic") else "")
        out.append({
            "arch": arch, "shape": r["shape"], "kind": r["kind"],
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "dominant": t["dominant"],
            "model_flops_ratio": ratio,
            "roofline_frac": (t["compute_s"] / t["step_s_sum"]
                              if t["step_s_sum"] else 0.0),
            "hbm_frac": r["memory"].get("hbm_frac", 0.0),
            "fix": FIX_HINTS[(t["dominant"],)],
        })
    return out


def markdown(rows) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| 6ND/HLO | roofline-frac |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped (quadratic-attn) | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['model_flops_ratio']:.2f} | "
            f"{r['roofline_frac']:.2f} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args(argv)
    rows = table(args.mesh)
    print(markdown(rows))
    with open(os.path.join(RESULTS, f"roofline_{args.mesh}.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
