"""Optimizers, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim import (adamw, clip_by_global_norm, compress_int8,
                         decompress_int8, ef_compress_update, global_norm,
                         init_ef_state, linear_warmup, sgd, warmup_cosine)


class TestAdamW:
    def test_converges_on_quadratic(self):
        opt = adamw(lr=0.1, weight_decay=0.0)
        params = {"x": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        loss = lambda p: jnp.sum(p["x"] ** 2)
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params)
        assert float(loss(params)) < 1e-3

    def test_master_weights_beat_bf16_underflow(self):
        """Tiny updates vanish in bf16 without a master copy."""
        params = {"x": jnp.ones((4,), jnp.bfloat16)}
        g = {"x": jnp.full((4,), 1e-4, jnp.float32)}
        for master in (False, True):
            opt = adamw(lr=1e-4, weight_decay=0.0, master_weights=master)
            state = opt.init(params)
            p = params
            for _ in range(50):
                p, state = opt.update(g, state, p)
            moved = float(jnp.abs(p["x"].astype(jnp.float32) - 1.0).max())
            if master:
                assert float(
                    jnp.abs(state.master["x"] - 1.0).max()) > 1e-4
            # bf16 storage may or may not move; master path must track
        assert state.master is not None

    def test_bf16_moments(self):
        opt = adamw(lr=0.1, moment_dtype="bfloat16")
        params = {"x": jnp.asarray([1.0])}
        state = opt.init(params)
        assert state.mu["x"].dtype == jnp.bfloat16
        g = {"x": jnp.asarray([0.5])}
        _, state = opt.update(g, state, params)
        assert state.nu["x"].dtype == jnp.bfloat16

    def test_sgd_momentum(self):
        opt = sgd(lr=0.05, momentum=0.9)
        params = jnp.asarray([4.0])
        state = opt.init(params)
        for _ in range(200):
            g = 2 * params
            params, state = opt.update(g, state, params)
        assert abs(float(params[0])) < 5e-2


class TestClip:
    def test_clip_rescales(self):
        tree = {"a": jnp.asarray([3.0, 4.0])}       # norm 5
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert abs(float(norm) - 5.0) < 1e-5
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5

    def test_noop_below_threshold(self):
        tree = {"a": jnp.asarray([0.3])}
        clipped, _ = clip_by_global_norm(tree, 1.0)
        np.testing.assert_allclose(np.asarray(clipped["a"]), [0.3], rtol=1e-6)


class TestSchedules:
    def test_warmup_cosine_shape(self):
        fn = warmup_cosine(1.0, 10, 100, final_frac=0.1)
        assert float(fn(jnp.asarray(0))) < 0.2
        assert abs(float(fn(jnp.asarray(10))) - 1.0) < 0.1
        assert float(fn(jnp.asarray(100))) <= 0.11

    def test_linear_warmup_monotone(self):
        fn = linear_warmup(1.0, 5)
        vals = [float(fn(jnp.asarray(i))) for i in range(8)]
        assert vals == sorted(vals)
        assert vals[-1] == 1.0


class TestCompression:
    @given(st.integers(0, 2**32 - 1), st.floats(0.01, 100.0))
    @settings(max_examples=20, deadline=None)
    def test_int8_roundtrip_error_bounded(self, seed, scale):
        g = scale * jax.random.normal(jax.random.PRNGKey(seed), (256,))
        q, s = compress_int8(g)
        assert q.dtype == jnp.int8
        err = jnp.abs(decompress_int8(q, s) - g).max()
        assert float(err) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_converges(self):
        """With EF, the *accumulated* compressed stream tracks the true
        gradient sum (the residual stays bounded)."""
        key = jax.random.PRNGKey(0)
        g_true = jax.random.normal(key, (64,)) * 0.01
        ef = jnp.zeros((64,))
        acc = jnp.zeros((64,))
        for i in range(50):
            q, s, ef = ef_compress_update(g_true, ef)
            acc = acc + decompress_int8(q, s)
        total_err = jnp.abs(acc - 50 * g_true).max()
        # without EF the bias would grow linearly; with EF it stays ~1 quantum
        assert float(total_err) <= float(jnp.abs(g_true).max()) * 5

    def test_init_ef_state_shapes(self):
        grads = {"w": jnp.ones((3, 3), jnp.bfloat16)}
        ef = init_ef_state(grads)
        assert ef["w"].shape == (3, 3) and ef["w"].dtype == jnp.float32


class TestCompressionContract:
    """compress/decompress_int8 are now load-bearing for session persistence
    (the fixed-point engine's float -> int8 migration rides the fixed-scale
    path), so the edge behavior is pinned explicitly."""

    @given(st.integers(0, 2**32 - 1), st.sampled_from([2.0**-6, 2.0**-5,
                                                       2.0**-4, 0.01]))
    @settings(max_examples=20, deadline=None)
    def test_fixed_scale_roundtrip_bounded(self, seed, scale):
        """With a FIXED scale, error <= scale/2 for in-range values and
        saturates (clips) beyond +-127*scale."""
        g = jax.random.normal(jax.random.PRNGKey(seed), (256,))
        q, s = compress_int8(g, scale=scale)
        assert q.dtype == jnp.int8 and float(s) == float(np.float32(scale))
        x = decompress_int8(q, s)
        in_range = np.abs(np.asarray(g)) <= 127.0 * scale
        err = np.abs(np.asarray(x) - np.asarray(g))
        assert err[in_range].max(initial=0.0) <= scale * 0.5 + 1e-6
        assert np.abs(np.asarray(q)).max() <= 127

    def test_zero_input(self):
        q, s = compress_int8(jnp.zeros((16,)))
        assert (np.asarray(q) == 0).all() and float(s) > 0
        np.testing.assert_array_equal(np.asarray(decompress_int8(q, s)),
                                      np.zeros(16, np.float32))

    @given(st.floats(1e-6, 1e6))
    @settings(max_examples=20, deadline=None)
    def test_constant_input_maps_to_full_scale(self, c):
        """A constant tensor lands on +-127 exactly (amax defines the grid),
        so the round trip is exact up to f32 arithmetic."""
        q, s = compress_int8(jnp.full((8,), c))
        assert (np.asarray(q) == 127).all()
        np.testing.assert_allclose(np.asarray(decompress_int8(q, s)),
                                   np.full(8, c, np.float32), rtol=1e-6)

    def test_denormal_input_is_finite_not_nan(self):
        """Sub-1e-12 magnitudes hit the scale floor: quantize to zero
        rather than dividing by ~0 and producing inf/nan."""
        tiny = jnp.full((8,), 1e-40)
        q, s = compress_int8(tiny)
        assert np.isfinite(float(s)) and float(s) > 0
        assert (np.asarray(q) == 0).all()
        assert np.isfinite(np.asarray(decompress_int8(q, s))).all()

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16,
                                       jnp.float16])
    def test_dtype_stability(self, dtype):
        """Any float input -> int8 payload + f32 scale + f32 decompress."""
        g = jnp.linspace(-2, 2, 32).astype(dtype)
        q, s = compress_int8(g)
        assert q.dtype == jnp.int8
        assert s.dtype == jnp.float32
        assert decompress_int8(q, s).dtype == jnp.float32

    def test_fixed_scale_grid_is_data_independent(self):
        """Same scale in -> same grid out regardless of data (the property
        session persistence relies on: the representation never drifts as
        weights learn)."""
        s1 = compress_int8(jnp.asarray([0.5]), scale=1 / 32)[1]
        s2 = compress_int8(jnp.asarray([123.0]), scale=1 / 32)[1]
        assert float(s1) == float(s2) == 1 / 32
