"""Model configuration for the assigned architecture fleet.

One `ModelConfig` describes any of the ten assigned LM-family archs:
dense GQA transformers, fine-grained MoE, Mamba2 SSM, Zamba2-style hybrid,
plus stub-frontend audio/VLM backbones.  The config fully determines the
parameter plan, the forward pass, and the sharding layout.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN width
    n_shared: int = 0             # always-on shared experts (deepseek-moe)
    first_dense: int = 0          # leading dense layers
    first_dense_ff: int = 0       # their FFN width
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 128              # S — state dimension per head
    head_dim: int = 64            # P — channels per head
    expand: int = 2               # d_inner = expand * d_model
    n_groups: int = 1             # B/C projection groups
    conv_width: int = 4           # short causal conv
    chunk: int = 256              # SSD chunk length
    attn_every: int = 0           # hybrid: shared attn block every N blocks

    def n_heads(self, d_model: int) -> int:
        return (self.expand * d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    layout: str = "dense"         # dense | moe | ssm | hybrid
    input_mode: str = "tokens"    # tokens | embeddings (stub frontend)
    sub_quadratic: bool = False   # eligible for long_500k
    # plastic adapter (the paper's technique as an LM serving feature)
    plastic_adapter: bool = False
    adapter_neurons: int = 512
    adapter_impl: str = "xla"     # PlasticEngine backend for the adapter
                                  # ("xla" | "pallas" | "pallas-interpret")
    adapter_quant: bool = False   # fixed-point adapter pool: int8 W_fast
                                  # with per-slot scales, int32 membranes/
                                  # traces (EngineParams.quant datapath)
    # int8 KV cache (beyond-paper: halves decode cache reads — the memory
    # roofline term of every decode cell; per-(position, kv-head) scales)
    kv_quant: bool = False
    # numerics
    dtype: str = "bfloat16"       # activations/params storage
    remat: bool = True
    # residual-stream activation sharding between blocks:
    #   "dp" — batch over data only (baseline)
    #   "sp" — batch over data + sequence over model (Megatron-SP analogue;
    #          required for the biggest train cells to fit 16 GiB/chip)
    act_shard: str = "dp"

    @property
    def act_spec(self):
        return (("data", "model", None) if self.act_shard == "sp"
                else ("data", None, None))

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM fleet (one set shared by all ten archs).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k only for sub-quadratic archs (see DESIGN.md
    §Arch-applicability for the layout x shape/adapter composition table)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention arch — 524k dense-"
                       "attention KV decode is the quadratic regime the "
                       "shape spec excludes")
    return True, ""
