"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072; 8 experts top-2.  [hf:xai-org/grok-1]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    layout="moe",
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=32768, n_shared=0,
                  capacity_factor=1.25),
)

SMOKE = ModelConfig(
    name="grok-1-314b-smoke",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=1,
    d_ff=192, vocab=512,
    layout="moe", remat=False,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=192, n_shared=0,
                  capacity_factor=1.25),
)
