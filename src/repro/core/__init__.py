"""FireFly-P core: four-term plasticity rule, LIF SNN, PEPG, two-phase learning."""
from repro.core import adaptation, es, plasticity, snn

__all__ = ["adaptation", "es", "plasticity", "snn"]
