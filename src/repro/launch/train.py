"""End-to-end training driver (runnable on CPU for smoke scale; the same
code path the dry-run lowers for the production meshes).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 50 --ckpt /tmp/run1

Wires together: config registry -> model init (sharded) -> deterministic
token pipeline -> AdamW train step (jit, donated) -> FaultTolerantRunner
(checkpoint/restart, NaN rollback, straggler log).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.data import TokenPipelineConfig, batch_at_step
from repro.distributed import FaultTolerantRunner, sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import train_setup
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.optim import adamw, warmup_cosine


def build(arch: str, smoke: bool, global_batch: int, seq_len: int,
          lr: float, total_steps: int, data_par: int = 1, model_par: int = 1):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    setup = train_setup(arch) if not smoke else {}
    if "act_shard" in setup:
        cfg = cfg.with_(act_shard=setup["act_shard"])
    mesh = make_local_mesh(data_par, model_par)
    opt = adamw(lr=warmup_cosine(lr, max(total_steps // 20, 1), total_steps),
                moment_dtype=setup.get("moment_dtype", "float32"))
    step_fn = make_train_step(
        cfg, opt, microbatches=setup.get("microbatches", 1),
        accum_dtype=setup.get("accum_dtype", "float32"),
        remat_policy="nothing" if cfg.remat else "none")
    return cfg, mesh, opt, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, mesh, opt, step_fn = build(
        args.arch, args.smoke, args.global_batch, args.seq_len, args.lr,
        args.steps, args.data_par, args.model_par)
    print(f"arch={cfg.name} params={T.n_params(cfg)/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    pipe = TokenPipelineConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                               global_batch=args.global_batch, seed=args.seed)

    with shd.use_mesh(mesh), mesh:
        params = T.init(cfg, jax.random.PRNGKey(args.seed))
        opt_state = opt.init(params)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        def wrapped(state, batch):
            p, o = state["params"], state["opt"]
            if cfg.input_mode == "embeddings":
                # stub frontend: embed tokens through a fixed projection
                emb = jax.nn.one_hot(batch["inputs"] % cfg.d_model,
                                     cfg.d_model, dtype=cfg.adtype)
                batch = {"inputs": emb, "labels": batch["labels"]}
            p, o, metrics = jit_step(p, o, batch)
            return {"params": p, "opt": o}, metrics

        ckpt = CheckpointManager(args.ckpt, keep=3)
        runner = FaultTolerantRunner(wrapped, ckpt,
                                     save_every=args.save_every)
        state = {"params": params, "opt": opt_state}
        state, start = runner.restore_or_init(state)

        t0 = time.time()
        state, history = runner.run(
            state, lambda s: batch_at_step(pipe, s), args.steps,
            start_step=start, log_every=args.log_every)
        dt = time.time() - t0

    losses = [h["loss"] for h in history]
    print(json.dumps({
        "arch": cfg.name, "steps": len(history),
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "wall_s": dt,
        "rollbacks": runner.rollbacks,
        "stragglers": runner.monitor.flagged,
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
