"""qwen1.5-32b [dense] — 64L d_model=5120 40H (MHA: kv=40) d_ff=27392
vocab=152064; QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]

40 heads do not divide the 16-way model axis: per-head activation shardings
fall back to replicated (sharding.py drops non-dividing axes) while the
flattened h*hd projections stay sharded — a deliberate baseline for the
roofline table (see EXPERIMENTS.md §Perf for the head-padding fix)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=27392, vocab=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
    layout="dense",
)

SMOKE = ModelConfig(
    name="qwen1.5-32b-smoke",
    n_layers=2, d_model=120, n_heads=5, n_kv_heads=5,   # odd head count, as in full
    d_ff=256, vocab=512,
    qkv_bias=True, rope_theta=1_000_000.0,
    layout="dense", remat=False,
)
