"""Session-health gate: the flight recorder must see real faults and only
real faults, for (near-)free.

Four gates over `repro.obs.health` + `repro.obs.recorder` as threaded
through the schedulers' ``record=`` trace variants:

  1. DETECTION — one row per detector (the "detector" coverage dimension):
     an injected input fault (`scenarios.inject_anomaly` presets, host-side
     corruption of one session's drive) must flag THAT session on the
     matching detector within a fixed step budget.  ewma_z and bound run
     the full FleetScheduler record path against a drive blowout (bound
     with a corridor calibrated between the clean and anomalous channel
     levels, z disabled — same streams, different detector); dead runs the
     dead_input preset; stuck feeds a frozen synthetic channel stream
     through `health_update` directly (a stuck datapath means telemetry
     stops moving, which a healthy pool — by design — never reproduces).

  2. FALSE POSITIVES — clean churn (admit/evict/step cycles) on a recorded
     FleetScheduler AND a recorded LM adapter pool with the DEFAULT
     HealthConfig must flag nothing.  Any flag fails the bench: the
     default corridor is tuned to the serving benchmarks' clean traffic.

  3. OVERHEAD — steady-state `pool_step` rate, record-off vs record-on
     (ring write + detectors fused into the same launch, no host sync).
     Full mode (B=256) asserts <= ``--max-overhead`` (5%); smoke (B=16)
     records without asserting (tiny-problem timings are launch noise).

  4. COMPILE DELTA — after warming record-on and record-off paths,
     `compiled_programs()` shows exactly one executable per record variant
     and untouched off-path programs.

    PYTHONPATH=src python benchmarks/obs_health.py [--smoke] [--impl ...]

Writes benchmarks/results/obs_health[_smoke].json (the CI obs-smoke
artifact, uploaded for xla and pallas-interpret).
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import snn
from repro.obs.health import (CHANNELS, DETECTORS, HealthConfig, HealthState,
                              health_update, init_health)
from repro.scenarios import AnomalyPreset, inject_anomaly

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# generous-but-finite stand-in for "this detector is off"
_OFF = 1e9


def _make_sched(impl: str, slots: int, admitted: int, health=None):
    from repro.serving.scheduler import FleetScheduler

    cfg = snn.SNNConfig(layer_sizes=(32, 64, 8), timesteps=8, plastic=True,
                        encoding="current", impl=impl)
    theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.05)
    sched = FleetScheduler(cfg, theta, slots=slots, health=health)
    for i in range(admitted):
        sched.admit(f"user{i}")
    return sched


def _drives(sched, scale: float = 2.0):
    rng = np.random.default_rng(1)
    n_in = sched.cfg.layer_sizes[0]
    return {u: rng.standard_normal(n_in).astype(np.float32) * scale
            for u in sched.active_users}


# ---- 1. detection ----------------------------------------------------------


def _make_detect_sched(impl: str, health):
    """Small, lightly-driven fleet for the fault-injection scenarios: the
    B=256-scale pool above runs saturated (clean drives already pin
    spike/saturation rates), which hides input faults; detection wants a
    controller whose channels still respond to its input."""
    from repro.serving.scheduler import FleetScheduler

    cfg = snn.SNNConfig(layer_sizes=(8, 12, 4), timesteps=3, plastic=True,
                        encoding="current", impl=impl)
    theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.05)
    sched = FleetScheduler(cfg, theta, slots=4, health=health)
    for i in range(3):
        sched.admit(f"user{i}")
    return sched


def _run_fault(impl: str, health: HealthConfig, preset, target: str,
               warm_steps: int, budget: int):
    """Warm a recorded pool on clean drives, then corrupt `target`'s drive
    with `preset` until it flags (or the budget runs out).  Returns
    (steps_to_flag or None, flagged-detector names, other flagged uids,
    the scheduler) — the scheduler so callers can read the ring."""
    sched = _make_detect_sched(impl, health)
    clean = _drives(sched, scale=0.5)
    for _ in range(warm_steps):
        sched.pool_step(clean, record=True)
    assert not sched.flagged_sessions(), (
        f"flagged during clean warmup: {sched.flagged_sessions()}")
    steps_to_flag = None
    for t in range(budget):
        drives = dict(clean)
        drives[target] = inject_anomaly(preset, clean[target], t)
        sched.pool_step(drives, record=True)
        if target in sched.flagged_sessions():
            steps_to_flag = t + 1
            break
    slot = sched.user_slot[target]
    flags = np.asarray(jax.device_get(sched._rec.health.flagged))
    hit = [DETECTORS[d] for d in np.nonzero(flags[slot])[0]]
    others = [u for u in sched.flagged_sessions() if u != target]
    return steps_to_flag, hit, others, sched


def _ring_channel_max(sched, uid: str, ch: str) -> float:
    ring = np.asarray(jax.device_get(sched._rec.ring))
    return float(ring[sched.user_slot[uid], :, CHANNELS.index(ch)].max())


def check_detection(impl: str) -> dict:
    """One detection row per detector; every row must detect."""
    rows = []
    blowout = AnomalyPreset("drive_blowout", gain=200.0)

    # ewma_z: blowout vs the session's own baseline (absolute corridor
    # off).  The blowout's z-signature is a single recorded window — the
    # weights hit their new equilibrium within one step and the WINSORIZED
    # baseline then absorbs the level shift (by design: the FP gate below
    # pins that recurring clean bursts never latch) — so this row runs the
    # z detector as the fast tripwire it is, hysteresis 1: one window at
    # z > 6 against the session's own baseline, with the clean-warmup
    # assert proving the same config stays silent on healthy streams.
    # The fault's SUSTAINED signature is wnorm_drift, the bound row below.
    zcfg = HealthConfig(warmup=8, hysteresis=(1, 2, 1000, 1000),
                        bounds=((0.0, _OFF),) * len(CHANNELS))
    n, hit, others, sched = _run_fault(impl, zcfg, blowout, "user1",
                                       warm_steps=12, budget=12)
    rows.append({"detector": "ewma_z", "injected": "drive_blowout",
                 "steps_to_flag": n, "flagged": hit, "others": others,
                 "detected": n is not None and "ewma_z" in hit})

    # bound: SAME fault streams, z disabled, corridor calibrated between
    # the clean and anomalous weight-norm-drift levels the ewma_z run
    # recorded (drift is the SUSTAINED post-blowout signal: the weights
    # jump to a new equilibrium and stay there, so the corridor breach
    # holds for the full hysteresis streak)
    clean_hi = max(_ring_channel_max(sched, u, "wnorm_drift")
                   for u in ("user0", "user2"))
    anom_hi = _ring_channel_max(sched, "user1", "wnorm_drift")
    assert anom_hi > clean_hi + 0.1, (
        f"blowout did not separate wnorm drift: clean={clean_hi} "
        f"anomalous={anom_hi}")
    corridor = (clean_hi + anom_hi) / 2.0
    bcfg = HealthConfig(warmup=8, z_threshold=_OFF,
                        hysteresis=(2, 2, 1000, 1000),
                        bounds=((0.0, _OFF),) * 3 + ((0.0, corridor),))
    n, hit, others, _ = _run_fault(impl, bcfg, blowout, "user1",
                                   warm_steps=12, budget=12)
    rows.append({"detector": "bound", "injected": "drive_blowout",
                 "corridor_hi": corridor, "steps_to_flag": n,
                 "flagged": hit, "others": others,
                 "detected": n is not None and "bound" in hit})

    # dead: zeroed drive -> spike collapse (stuck hysteresis parked so the
    # equally-frozen channels attribute to the right detector)
    dcfg = HealthConfig(warmup=8, hysteresis=(1000, 1000, 1000, 3),
                        bounds=((0.0, _OFF),) * len(CHANNELS))
    n, hit, others, _ = _run_fault(impl, dcfg,
                                   AnomalyPreset("dead_input"), "user1",
                                   warm_steps=12, budget=12)
    rows.append({"detector": "dead", "injected": "dead_input",
                 "steps_to_flag": n, "flagged": hit, "others": others,
                 "detected": n is not None and "dead" in hit})

    # stuck: a frozen telemetry stream straight through the detector math —
    # the channel vector stops moving while staying non-zero and in-corridor
    scfg = HealthConfig(warmup=4, hysteresis=(1000, 1000, 3, 1000))
    h = init_health(scfg, 2)
    rng = np.random.default_rng(7)
    active = jnp.ones((2,), jnp.float32)
    frozen = jnp.asarray([[0.4, 0.02, 0.1, 0.5]] * 2, jnp.float32)
    steps_to_flag = None
    for t in range(12):
        x = (frozen if t >= 6 else
             jnp.asarray(rng.uniform(0.05, 0.6, (2, len(CHANNELS))),
                         jnp.float32))
        h, verdict = health_update(scfg, h, x, active)
        if steps_to_flag is None and bool(np.asarray(verdict).any()):
            steps_to_flag = t - 6 + 1
    hit = [DETECTORS[d]
           for d in np.nonzero(np.asarray(h.flagged)[0])[0]]
    rows.append({"detector": "stuck", "injected": "frozen_channels",
                 "steps_to_flag": steps_to_flag, "flagged": hit,
                 "others": [],
                 "detected": steps_to_flag is not None and "stuck" in hit})

    errors = [f"{r['detector']}: not detected ({r})"
              for r in rows if not r["detected"]]
    return {"impl": impl, "rows": rows, "errors": errors}


# ---- 2. false positives ----------------------------------------------------


def check_false_positives(impl: str, cycles: int) -> dict:
    """Clean churn with the DEFAULT HealthConfig must flag nothing —
    fleet pool and LM adapter pool both."""
    sched = _make_sched(impl, slots=8, admitted=8, health=HealthConfig())
    fleet_flags = []
    for c in range(cycles):
        sched.pool_step(_drives(sched), record=True)
        if c % 3 == 2:
            uid = sched.active_users[c % len(sched.active_users)]
            sched.evict(uid)
            sched.admit(uid)
        fleet_flags += sched.flagged_sessions()

    from repro.configs import get_smoke
    from repro.models import factory
    from repro.serving.lm import LMScheduler

    cfg = get_smoke("qwen3-4b").with_(plastic_adapter=True,
                                      adapter_neurons=8, adapter_impl=impl)
    model = factory.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lm = LMScheduler(model, params, slots=4, max_len=32,
                     health=HealthConfig())
    for i in range(4):
        lm.admit_prompt(f"lmuser{i}", jnp.arange(6, dtype=jnp.int32) + 1)
    lm_flags = []
    for _ in range(cycles):
        lm.step(record=True)
        lm_flags += lm.flagged_sessions()
    errors = []
    if fleet_flags:
        errors.append(f"fleet clean churn flagged {sorted(set(fleet_flags))}")
    if lm_flags:
        errors.append(f"lm clean decode flagged {sorted(set(lm_flags))}")
    return {"impl": impl, "cycles": cycles,
            "fleet_false_positives": sorted(set(fleet_flags)),
            "lm_false_positives": sorted(set(lm_flags)), "errors": errors}


# ---- 3. overhead -----------------------------------------------------------


def bench_overhead(impl: str, slots: int, iters: int, repeats: int) -> dict:
    """Recorder cost as ALTERNATING per-call latency, min-based.

    Two methodology rules, both load-bearing:

    * ALTERNATE the record-off / record-on calls rather than timing one
      block after the other.  Host-side throughput decays measurably over
      a process's lifetime (allocator growth, cache pressure — a 20-30%
      drop within a single run is normal here), so sequential blocks
      charge the drift between the blocks to whichever variant ran
      second.  Interleaving samples both variants under identical drift.
    * Compare the MINIMUM per-call latency (per-call block_until_ready).
      The min isolates the deterministic dispatch+device cost of each
      program from scheduling noise riding on top — the standard latency
      trick; the medians are reported alongside for context.
    """
    sched = _make_sched(impl, slots, admitted=slots, health=HealthConfig())
    drives = _drives(sched)
    k = sched.cfg.timesteps
    for record in (False, True):                       # compile + warm
        sched.pool_step(drives, record=record)
    jax.block_until_ready(sched.fleet.v)
    lat = {False: [], True: []}
    for _ in range(iters * repeats):
        for record in (False, True):
            t0 = time.perf_counter()
            sched.pool_step(drives, record=record)
            jax.block_until_ready(sched.fleet.v)
            lat[record].append(time.perf_counter() - t0)
    off, on = min(lat[False]), min(lat[True])
    return {"impl": impl, "batch": slots,
            "calls_per_variant": iters * repeats,
            "percall_ms_off": off * 1e3, "percall_ms_on": on * 1e3,
            "percall_ms_off_median": statistics.median(lat[False]) * 1e3,
            "percall_ms_on_median": statistics.median(lat[True]) * 1e3,
            "steps_per_s_off": k / off, "steps_per_s_on": k / on,
            "overhead_frac": on / off - 1.0}


# ---- 4. compile delta ------------------------------------------------------


def check_compile_delta(impl: str, slots: int) -> dict:
    """Exactly one stable executable per record variant, off-path frozen."""
    sched = _make_sched(impl, slots, admitted=max(1, slots // 2),
                        health=HealthConfig())
    drives = _drives(sched)
    base = dict(sched.compiled_programs())
    for _ in range(2):
        sched.step(drives)
        sched.step(drives, record=True)
        sched.pool_step(drives)
        sched.pool_step(drives, record=True)
    progs = sched.compiled_programs()
    expected = {"pool_step": 1, "pool_rollout": 1,
                "pool_step_record": 1, "pool_rollout_record": 1}
    errors = [f"{name}: {progs.get(name)} executables, expected {want}"
              for name, want in expected.items() if progs.get(name) != want]
    for name in ("slot_put", "slot_take"):
        if progs[name] != base[name]:
            errors.append(f"{name}: grew {base[name]} -> {progs[name]} "
                          "during recorded stepping")
    return {"impl": impl, "programs": progs, "errors": errors}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="B=16 quick pass for CI (no overhead assertion)")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"])
    ap.add_argument("--batch", type=int, default=None,
                    help="fleet size for the overhead gate "
                         "(default 256 full / 16 smoke)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--churn-cycles", type=int, default=None)
    ap.add_argument("--max-overhead", type=float, default=0.05,
                    help="record-on throughput cost gate (full mode)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    slots = args.batch if args.batch else (16 if args.smoke else 256)
    iters = args.iters if args.iters else (3 if args.smoke else 20)
    cycles = (args.churn_cycles if args.churn_cycles
              else (8 if args.smoke else 24))
    if args.out is None:
        args.out = os.path.join(
            RESULTS,
            "obs_health_smoke.json" if args.smoke else "obs_health.json")

    failures = []

    # The overhead measurement runs FIRST, on the pristine process: the
    # detection / false-positive checks behind it churn dozens of pools
    # through the allocator, and that fragmentation skews the absolute
    # per-call latencies (the record-on program's extra buffers are the
    # more sensitive of the two — sequencing it after the churn charged
    # it several extra percent that a fresh process never shows).
    overhead = bench_overhead(args.impl, slots, iters, args.repeats)
    print(f"[overhead] B={slots} impl={args.impl}: "
          f"off={overhead['percall_ms_off']:.2f} ms/call "
          f"({overhead['steps_per_s_off']:.1f} steps/s), "
          f"on={overhead['percall_ms_on']:.2f} ms/call, "
          f"overhead={overhead['overhead_frac'] * 100:+.2f}%")
    if not args.smoke and overhead["overhead_frac"] > args.max_overhead:
        failures.append(
            f"recorder overhead {overhead['overhead_frac'] * 100:.2f}% "
            f"exceeds the {args.max_overhead * 100:.0f}% gate")

    detection = check_detection(args.impl)
    for r in detection["rows"]:
        print(f"[detect] {r['detector']:7s} <- {r['injected']:16s} "
              f"steps_to_flag={r['steps_to_flag']} flagged={r['flagged']}")
    failures += detection["errors"]

    fp = check_false_positives(args.impl, cycles)
    print(f"[clean] {fp['cycles']} churn cycles: "
          f"fleet FP={fp['fleet_false_positives']} "
          f"lm FP={fp['lm_false_positives']}")
    failures += fp["errors"]

    compile_delta = check_compile_delta(args.impl, min(slots, 16))
    print(f"[compile] {compile_delta['programs']}")
    failures += compile_delta["errors"]

    out = {"impl": args.impl, "smoke": bool(args.smoke), "batch": slots,
           "iters": iters, "repeats": args.repeats,
           "max_overhead": args.max_overhead,
           "detection": detection["rows"],
           "false_positives": {k: v for k, v in fp.items() if k != "errors"},
           "overhead": overhead,
           "compile_delta": {"programs": compile_delta["programs"],
                             "errors": compile_delta["errors"]},
           "failures": failures}
    os.makedirs(RESULTS, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}; {len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
