"""Payload-arm task: a 2-DOF gravity-loaded arm with a variable tip payload.

A torque-controlled 2-link planar arm (like `ReacherEnv`) but with in-plane
gravity and a payload mass attached at the tip.  The payload adds both
inertia and a configuration-dependent gravity torque, so a payload change
mid-episode is a *persistent* disturbance: a frozen controller sags to a
steady-state error while a plastic controller can keep integrating the
error away — the paper's robust-adaptation claim in its cleanest mechanical
form (pick-and-place with an unknown load).

Task protocol mirrors the other envs: 8 training goals on a mid-workspace
ring, 72 unseen eval goals.

Perturbable dynamics params (`PARAM_NAMES`): payload, gain, damping.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.envs.base import Env, EnvState


@dataclasses.dataclass(frozen=True)
class ArmEnv(Env):
    episode_len: int = 150
    dt: float = 0.05
    obs_dim: int = 11     # sin/cos q(4), dq(2), goal(2), goal-tip(2), 1
    act_dim: int = 2
    link: float = 0.5
    damping: float = 1.2
    gain: float = 3.0
    payload: float = 0.0  # tip mass (adds inertia + gravity torque)
    gravity: float = 2.0  # in-plane gravity (toy scale), pulls along -y

    PARAM_NAMES: tuple = ("payload", "gain", "damping")

    def init_phys(self, key: jax.Array) -> jax.Array:
        # phys = [q1, q2, dq1, dq2]; start mid-workspace, elbow down
        q0 = jnp.array([0.4, -0.8]) + 0.1 * jax.random.normal(key, (2,))
        return jnp.concatenate([q0, jnp.zeros(2)])

    def _tip(self, q: jax.Array) -> jax.Array:
        x = self.link * (jnp.cos(q[0]) + jnp.cos(q[0] + q[1]))
        y = self.link * (jnp.sin(q[0]) + jnp.sin(q[0] + q[1]))
        return jnp.array([x, y])

    def dynamics(self, phys: jax.Array, force: jax.Array,
                 params: Optional[jax.Array] = None) -> jax.Array:
        p = self.default_params() if params is None else params
        payload, gain, damping = p[0], p[1], p[2]
        q, dq = phys[:2], phys[2:]
        # gravity torque of the tip payload about each joint (moment arm =
        # horizontal distance from the joint to the tip)
        r1 = self.link * (jnp.cos(q[0]) + jnp.cos(q[0] + q[1]))
        r2 = self.link * jnp.cos(q[0] + q[1])
        tau_g = -self.gravity * payload * jnp.stack([r1, r2])
        inertia = 1.0 + payload
        ddq = (gain * force + tau_g - damping * dq) / inertia
        dq = dq + self.dt * ddq
        q = q + self.dt * dq
        return jnp.concatenate([q, dq])

    def observe(self, state: EnvState) -> jax.Array:
        q, dq = state.phys[:2], state.phys[2:]
        tip = self._tip(q)
        goal = state.task
        return jnp.concatenate([
            jnp.sin(q), jnp.cos(q), dq, goal, goal - tip, jnp.array([1.0])])

    def reward(self, state: EnvState, action: jax.Array,
               new_phys: jax.Array) -> jax.Array:
        tip = self._tip(new_phys[:2])
        dist = jnp.linalg.norm(tip - state.task)
        ctrl = 0.01 * jnp.sum(action ** 2)
        return -dist - ctrl

    def _goals(self, n: int, phase: float) -> jax.Array:
        # frontal arc (+-60 deg): the fixed error->torque wiring of a
        # linear controller is only sign-consistent in the front workspace
        ang = (jnp.arange(n, dtype=jnp.float32) + phase) * (
            (2 * jnp.pi / 3) / n) - jnp.pi / 3
        r = 1.4 * self.link
        return jnp.stack([r * jnp.cos(ang), r * jnp.sin(ang)], axis=1)

    def train_tasks(self) -> jax.Array:
        return self._goals(8, 0.0)

    def eval_tasks(self) -> jax.Array:
        return self._goals(72, 0.5)
