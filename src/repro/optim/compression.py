"""Error-feedback int8 gradient compression (distributed-optimization trick).

For collective-bound meshes the gradient all-reduce can run over int8 with a
per-leaf fp32 scale (4x byte reduction on the wire) at no convergence cost
when the quantization error is fed back into the next step (Seide et al.'14;
1-bit Adam lineage).  Usage inside a shard_map'd step:

    ef = init_ef_state(grads)                # once
    q, scale = compress_int8(grad + ef)      # per leaf
    q_sum = lax.psum(q.astype(int32), axis)  # wire bytes: 1/4 of fp32
    g_hat = decompress_int8(q_sum, scale_avg)
    ef    = (grad + ef) - local_dequant      # residual carried forward

`ef_compress_update` packages the per-leaf round trip; the all-reduce itself
stays in the caller so the same code serves psum (shard_map) and jit-visible
collectives (sharding constraints).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array, scale: jax.Array | float | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization.  Returns (q int8, scale f32).

    ``scale=None`` (the gradient-compression default) derives the scale from
    the tensor's absolute max.  Passing a FIXED ``scale`` quantizes onto a
    known grid instead — that is the fixed-point-engine path
    (`snn.quantize_state` migrating a float session onto the int8 weight
    grid ``2**-w_frac_bits``), where the grid must not depend on the data so
    the representation stays stable as weights learn.
    """
    xf = x.astype(jnp.float32)
    if scale is None:
        amax = jnp.max(jnp.abs(xf))
        scale = jnp.maximum(amax, 1e-12) / 127.0
    else:
        scale = jnp.asarray(scale, jnp.float32)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_ef_state(grads):
    """Zeroed error-feedback residuals, grads-shaped (fp32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_compress_update(grad: jax.Array, ef: jax.Array):
    """One leaf's compress step with error feedback.

    Returns (q int8, scale, new_ef).  The caller all-reduces q (int32 psum)
    and averages scale, then `decompress_int8(q_sum / n, scale_mean)`.
    """
    target = grad.astype(jnp.float32) + ef
    q, scale = compress_int8(target)
    new_ef = target - decompress_int8(q, scale)
    return q, scale, new_ef
