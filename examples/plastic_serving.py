"""FireFly-P inside an LM serving stack: per-request plastic fast-weights.

    PYTHONPATH=src python examples/plastic_serving.py

Each decode stream carries its own fast-weight matrix W_fast (zero-init)
that the four-term rule rewrites every generated token — the paper's
Phase-2 online adaptation as a serving feature.  The adapter's synaptic
layer is ONE fleet-mode `core.engine.layer_step` over all streams (the
fused dual-engine program with per-request weights; `ModelConfig.
adapter_impl` selects the backend).  This example
serves two archs (dense + SSM) with and without the adapter and reports
the decode overhead and the fast-weight drift per stream.
"""
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.distributed import sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_decode_step, make_prefill
from repro.models import transformer as T


def serve(arch: str, plastic: bool, gen: int = 12, batch: int = 2):
    cfg = get_smoke(arch)
    if plastic:
        cfg = cfg.with_(plastic_adapter=True, adapter_neurons=32)
    mesh = make_local_mesh()
    with shd.use_mesh(mesh), mesh:
        params = T.init(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (batch, 12),
                                  0, cfg.vocab)
        inputs = (jnp.take(params["embed"], toks, axis=0)
                  if cfg.input_mode == "embeddings" else toks)
        prefill = jax.jit(make_prefill(cfg, 12 + gen))
        decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
        logits, cache = prefill(params, inputs)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        lat = []
        for i in range(gen):
            t0 = time.perf_counter()
            logits, cache = decode(params, cache, tok[:, None])
            logits.block_until_ready()
            lat.append(time.perf_counter() - t0)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = {"arch": cfg.name, "plastic": plastic,
               "decode_ms_p50": sorted(lat)[len(lat) // 2] * 1e3}
        if plastic:
            wf = cache["adapter"]["w_fast"]
            out["fast_weight_drift_per_stream"] = [
                float(jnp.abs(wf[b]).mean()) for b in range(batch)]
        return out


def main():
    rows = []
    for arch in ("qwen3-4b", "mamba2-1.3b"):
        for plastic in (False, True):
            rows.append(serve(arch, plastic))
            print(json.dumps(rows[-1]))
    base = rows[0]["decode_ms_p50"]
    plas = rows[1]["decode_ms_p50"]
    print(f"\nadapter decode overhead ({rows[1]['arch']}): "
          f"{(plas / base - 1) * 100:.1f}% "
          f"(one extra (B,N,N) rule application per token)")


if __name__ == "__main__":
    main()
