"""Composable decoder-only LM covering the assigned architecture fleet.

A model is a sequence of SEGMENTS, each a stack of identical blocks scanned
with `lax.scan` (stacked params => small HLO, fast compile, layer-count-
independent program size):

  dense     — GQA attention + SwiGLU MLP           (qwen*, internlm2, musicgen,
                                                    pixtral backbones)
  dense_ff  — dense with an override FFN width      (deepseek-moe layer 0)
  moe       — GQA attention + routed-expert FFN     (deepseek-moe, grok-1)
  ssm       — Mamba2 SSD block                      (mamba2)
  zsuper    — one SHARED transformer block + (attn_every-1) Mamba2 blocks
              (zamba2; the shared block's params live once at top level)

Entry points:
  plan / init / abstract          — parameter plan machinery
  forward                         — full-sequence logits (train/prefill)
  loss_fn                         — next-token cross entropy
  init_cache / prefill / decode_step — serving path with KV/SSM caches
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_constraint
from repro.models import attention, moe as moe_mod, plastic, ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (ParamDesc, abstract_from_plan,
                                 cross_entropy, init_from_plan, param_count,
                                 rms_norm, shardings_from_plan,
                                 specs_from_plan, swiglu)

# ---------------------------------------------------------------------------
# Segments
# ---------------------------------------------------------------------------


def segments(cfg: ModelConfig) -> list[tuple[str, int]]:
    if cfg.layout == "dense":
        return [("dense", cfg.n_layers)]
    if cfg.layout == "moe":
        fd = cfg.moe.first_dense
        segs = []
        if fd:
            segs.append(("dense_ff", fd))
        segs.append(("moe", cfg.n_layers - fd))
        return segs
    if cfg.layout == "ssm":
        return [("ssm", cfg.n_layers)]
    if cfg.layout == "hybrid":
        per = cfg.ssm.attn_every
        n_super = cfg.n_layers // per
        rem = cfg.n_layers - n_super * per
        segs: list[tuple[str, int]] = [("zsuper", n_super)]
        if rem:
            segs.append(("ssm", rem))
        return segs
    raise ValueError(cfg.layout)


def _stack_plan(p, n: int):
    """Prepend a stacking dim to every ParamDesc in a plan."""
    return jax.tree.map(
        lambda d: ParamDesc((n, *d.shape), (None, *d.spec), d.init, d.scale,
                            d.fan_in, d.dtype),
        p, is_leaf=lambda x: isinstance(x, ParamDesc))


def _mlp_plan(cfg: ModelConfig, d_ff: int, stack: int = 0) -> dict:
    d = cfg.d_model

    def desc(shape, spec, **kw):
        if stack:
            shape, spec = (stack, *shape), (None, *spec)
        return ParamDesc(shape, spec, dtype=cfg.dtype, **kw)

    return {
        "norm": desc((d,), (None,), init="ones"),
        "w_gate": desc((d, d_ff), ("data", "model"), fan_in=d),
        "w_up": desc((d, d_ff), ("data", "model"), fan_in=d),
        "w_down": desc((d_ff, d), ("model", "data"), fan_in=d_ff),
    }


def _segment_plan(cfg: ModelConfig, kind: str, count: int):
    if kind == "dense":
        return {"attn": attention.plan(cfg, stack=count),
                "mlp": _mlp_plan(cfg, cfg.d_ff, stack=count)}
    if kind == "dense_ff":
        return {"attn": attention.plan(cfg, stack=count),
                "mlp": _mlp_plan(cfg, cfg.moe.first_dense_ff, stack=count)}
    if kind == "moe":
        return {"attn": attention.plan(cfg, stack=count),
                "moe": moe_mod.plan(cfg, stack=count)}
    if kind == "ssm":
        return ssm_mod.plan(cfg, stack=count)
    if kind == "zsuper":
        inner = cfg.ssm.attn_every - 1
        return {"ssm": _stack_plan(ssm_mod.plan(cfg, stack=inner), count)}
    raise ValueError(kind)


def plan(cfg: ModelConfig, fsdp: bool = True) -> dict:
    d, v = cfg.d_model, cfg.vocab
    p: dict[str, Any] = {
        "embed": ParamDesc((v, d), ("model", "data"), scale=1.0, fan_in=d,
                           dtype=cfg.dtype),
        "segments": [_segment_plan(cfg, k, n) for k, n in segments(cfg)],
        "final_norm": ParamDesc((d,), (None,), init="ones", dtype=cfg.dtype),
    }
    if cfg.layout == "hybrid":
        p["shared_attn"] = attention.plan(cfg)
        p["shared_mlp"] = _mlp_plan(cfg, cfg.d_ff)
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamDesc((d, v), ("data", "model"), fan_in=d,
                                 dtype=cfg.dtype)
    if cfg.plastic_adapter:
        p["adapter"] = plastic.plan(cfg)
    if not fsdp:
        p = jax.tree.map(
            lambda pd: ParamDesc(
                pd.shape,
                tuple(None if s == "data" else s for s in pd.spec),
                pd.init, pd.scale, pd.fan_in, pd.dtype),
            p, is_leaf=lambda x: isinstance(x, ParamDesc))
    return p


def init(cfg: ModelConfig, key: jax.Array, fsdp: bool = True):
    return init_from_plan(plan(cfg, fsdp), key)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _mlp_apply(p, x, cfg: ModelConfig):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    return x + shard_constraint(
        swiglu(h, p["w_gate"], p["w_up"], p["w_down"]), cfg.act_spec)


def _block_fn(cfg: ModelConfig, kind: str, *, collect_cache: bool,
              attn_impl: str, ssd_impl: str, shared=None):
    """Returns body(x, p) -> (x, cache_leaf) for one block of `kind`."""

    def dense(x, p):
        x, (k, v) = attention.apply(p["attn"], x, cfg, impl=attn_impl)
        x = _mlp_apply(p["mlp"], x, cfg)
        return x, ((k, v) if collect_cache else None)

    def moe_block(x, p):
        x, (k, v) = attention.apply(p["attn"], x, cfg, impl=attn_impl)
        x = moe_mod.apply(p["moe"], x, cfg)
        return x, ((k, v) if collect_cache else None)

    def ssm_block(x, p):
        x, state, conv = ssm_mod.apply(p, x, cfg, impl=ssd_impl)
        return x, ((state, conv) if collect_cache else None)

    def zsuper(x, p):
        x, (k, v) = attention.apply(shared[0], x, cfg, impl=attn_impl)
        x = _mlp_apply(shared[1], x, cfg)

        def inner(h, pl):
            h, state, conv = ssm_mod.apply(pl, h, cfg, impl=ssd_impl)
            return h, ((state, conv) if collect_cache else None)

        x, inner_cache = jax.lax.scan(inner, x, p["ssm"])
        return x, (((k, v), inner_cache) if collect_cache else None)

    return {"dense": dense, "dense_ff": dense, "moe": moe_block,
            "ssm": ssm_block, "zsuper": zsuper}[kind]


_REMAT_POLICIES = {
    "none": None,   # no remat
    "nothing": "nothing_saveable",
    "dots": "dots_with_no_batch_dims_saveable",
}


def _maybe_remat(fn, cfg: ModelConfig, remat_policy: str):
    if not cfg.remat or remat_policy == "none":
        return fn
    pol = getattr(jax.checkpoint_policies, _REMAT_POLICIES[remat_policy])
    return jax.checkpoint(fn, policy=pol)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(params, inputs, cfg: ModelConfig, *, collect_cache: bool = False,
            attn_impl: str = "xla_flash", ssd_impl: str = "xla",
            remat_policy: str = "nothing", head: bool = True):
    """inputs: tokens (B,S) int32 or embeddings (B,S,D) per cfg.input_mode.

    Returns (logits (B,S,V), per-segment caches or Nones); with head=False
    the first element is the final hidden state (B,S,D) instead (prefill
    uses this to avoid materializing all-position logits).
    """
    if cfg.input_mode == "embeddings" and inputs.ndim == 3:
        h = inputs.astype(cfg.adtype)
    else:
        h = jnp.take(params["embed"], inputs, axis=0)
    h = shard_constraint(h, cfg.act_spec)

    shared = ((params["shared_attn"], params["shared_mlp"])
              if cfg.layout == "hybrid" else None)
    caches = []
    for seg_idx, (kind, count) in enumerate(segments(cfg)):
        blk = _block_fn(cfg, kind, collect_cache=collect_cache,
                        attn_impl=attn_impl, ssd_impl=ssd_impl, shared=shared)

        def body(x, p, _blk=blk):
            return _blk(x, p)

        body = _maybe_remat(body, cfg, remat_policy)
        h, cache = jax.lax.scan(body, h, params["segments"][seg_idx])
        caches.append(cache)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if not head:
        return h, caches
    head_w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", h, head_w)
    return shard_constraint(logits, ("data", None, "model")), caches


def loss_fn(params, batch, cfg: ModelConfig, **fw):
    """batch: {"inputs": tokens|embeddings, "labels": (B,S) int32 (-1 = pad)}."""
    logits, _ = forward(params, batch["inputs"], cfg, **fw)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    return cross_entropy(logits, jnp.maximum(labels, 0), mask)


# ---------------------------------------------------------------------------
# Serving: cache plan, prefill, decode
# ---------------------------------------------------------------------------


def cache_plan(cfg: ModelConfig, batch: int, max_len: int,
               per_slot_index: bool = False) -> dict:
    """Descriptor pytree for the decode cache (shardable, eval_shape-able).

    ``per_slot_index=True`` gives the cache a ``(batch,)`` sequence index —
    one length per stream — instead of the scalar lockstep index: the
    continuous-batching pool layout, where streams admitted at different
    times decode at different positions (`serving.lm.LMScheduler`).
    """
    import dataclasses as _dc
    seq_shard = batch == 1  # long-context: shard sequence, not batch
    segs = segments(cfg)

    def attn_cache(count):
        kv = attention.plan_kv_cache(cfg, batch, max_len, count, seq_shard)
        if not cfg.kv_quant:
            return {"k": kv, "v": kv}
        kv8 = _dc.replace(kv, dtype="int8")
        sc = attention.plan_kv_scale(cfg, batch, max_len, count)
        return {"k": kv8, "v": kv8, "k_scale": sc, "v_scale": sc}

    seg_caches: list[Any] = []
    for kind, count in segs:
        if kind in ("dense", "dense_ff", "moe"):
            seg_caches.append(attn_cache(count))
        elif kind == "ssm":
            seg_caches.append(ssm_mod.plan_cache(cfg, batch, count))
        elif kind == "zsuper":
            inner = cfg.ssm.attn_every - 1
            c = attn_cache(count)
            c["ssm"] = _stack_plan(ssm_mod.plan_cache(cfg, batch, inner),
                                   count)
            seg_caches.append(c)
    idx_shape, idx_spec = (((batch,), ("data",)) if per_slot_index
                           else ((), ()))
    out = {"segments": seg_caches,
           "index": ParamDesc(idx_shape, idx_spec, init="zeros",
                              dtype="int32")}
    if cfg.plastic_adapter:
        out["adapter"] = plastic.plan_cache(cfg, batch)
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               per_slot_index: bool = False):
    return init_from_plan(cache_plan(cfg, batch, max_len, per_slot_index),
                          jax.random.PRNGKey(0))


def prefill(params, inputs, cfg: ModelConfig, max_len: int, *,
            attn_impl: str = "xla_flash", ssd_impl: str = "xla"):
    """Run the prompt through the model, building the decode cache.

    Returns (last-position logits (B,V), cache).
    """
    bsz = inputs.shape[0]
    s = inputs.shape[1]
    hidden, caches = forward(params, inputs, cfg, collect_cache=True,
                             attn_impl=attn_impl, ssd_impl=ssd_impl,
                             remat_policy="none", head=False)
    head_w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bd,dv->bv", hidden[:, -1], head_w)
    logits = shard_constraint(logits, ("data", "model"))
    segs = segments(cfg)

    def pack_kv(k, v):
        out = {}
        if cfg.kv_quant:
            kq, ks = attention.quantize_kv(k)
            vq, vs = attention.quantize_kv(v)
            out = {"k": _embed_kv(kq, bsz, max_len, cfg),
                   "v": _embed_kv(vq, bsz, max_len, cfg),
                   "k_scale": _embed_kv(ks, bsz, max_len, cfg),
                   "v_scale": _embed_kv(vs, bsz, max_len, cfg)}
        else:
            out = {"k": _embed_kv(k, bsz, max_len, cfg),
                   "v": _embed_kv(v, bsz, max_len, cfg)}
        return out

    seg_caches = []
    for (kind, count), c in zip(segs, caches):
        if kind in ("dense", "dense_ff", "moe"):
            k, v = c
            seg_caches.append(pack_kv(k, v))
        elif kind == "ssm":
            state, conv = c
            seg_caches.append({"ssm": state, "conv": conv})
        else:  # zsuper
            (k, v), (state, conv) = c
            sc = pack_kv(k, v)
            sc["ssm"] = {"ssm": state, "conv": conv}
            seg_caches.append(sc)
    cache = {"segments": seg_caches, "index": jnp.asarray(s, jnp.int32)}
    if cfg.plastic_adapter:
        cache["adapter"] = init_from_plan(plastic.plan_cache(cfg, bsz),
                                          jax.random.PRNGKey(0))
    return logits, cache


def _embed_kv(k, bsz, max_len, cfg):
    """Place prefilled (L,B,S,...) into a (L,B,max_len,...) buffer."""
    if k.shape[2] == max_len:
        return k
    buf = jnp.zeros((*k.shape[:2], max_len, *k.shape[3:]), k.dtype)
    return jax.lax.dynamic_update_slice(buf, k, (0,) * k.ndim)


def _decode_backbone(params, cache, tokens, cfg: ModelConfig, active=None):
    """Embed + all segments for ONE new token per stream.  tokens (B,1).

    Returns (h (B,1,D) pre-final-norm, new segment caches, new index).
    ``active (B,)`` is the pool's vacant-slot mask, enforcing the TRUE
    no-op contract on every piece of per-stream state: KV/scale cache rows
    are write-gated (`attention._write_at`), SSM/conv states are
    select-gated, per-slot sequence indices freeze, and MoE dispatch
    sentinels vacant slots' garbage tokens out of expert capacity (the one
    cross-row interaction in the decode path).  A vacant slot's entire
    session row is bit-identical after any number of pool steps.  Vacant
    rows' hidden-state COMPUTE is garbage, but nothing persistent reads it
    (the adapter and pending-token updates are gated downstream).
    """
    index = cache["index"]
    token_mask = (None if active is None
                  else jnp.broadcast_to(active.astype(bool)[:, None],
                                        tokens.shape))

    def gate_rows(new, old):
        # freeze vacant streams' state rows (leading axis = stream)
        if active is None:
            return new
        m = active.astype(bool).reshape((-1,) + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)
    h = jnp.take(params["embed"], tokens, axis=0)       # (B,1,D)
    h = shard_constraint(h, ("data", None, None))

    new_segs = []
    for seg_idx, (kind, count) in enumerate(segments(cfg)):
        seg_p = params["segments"][seg_idx]
        c = cache["segments"][seg_idx]
        if kind in ("dense", "dense_ff", "moe"):
            def body(x, xs, _kind=kind):
                if cfg.kv_quant:
                    p, k_l, v_l, sk_l, sv_l = xs
                    x, kn, vn, skn, svn = attention.decode_step(
                        p["attn"], x, k_l, v_l, index, cfg,
                        scale_k=sk_l, scale_v=sv_l, active=active)
                else:
                    p, k_l, v_l = xs
                    x, kn, vn = attention.decode_step(p["attn"], x, k_l, v_l,
                                                      index, cfg,
                                                      active=active)
                    skn = svn = None
                if _kind == "moe":
                    x = moe_mod.apply(p["moe"], x, cfg,
                                      token_mask=token_mask)
                else:
                    x = _mlp_apply(p["mlp"], x, cfg)
                if cfg.kv_quant:
                    return x, (kn, vn, skn, svn)
                return x, (kn, vn)

            if cfg.kv_quant:
                h, (ks, vs, sks, svs) = jax.lax.scan(
                    body, h, (seg_p, c["k"], c["v"],
                              c["k_scale"], c["v_scale"]))
                new_segs.append({"k": ks, "v": vs,
                                 "k_scale": sks, "v_scale": svs})
            else:
                h, (ks, vs) = jax.lax.scan(body, h, (seg_p, c["k"], c["v"]))
                new_segs.append({"k": ks, "v": vs})
        elif kind == "ssm":
            def body(x, xs):
                p, st, cv = xs
                x, st_n, cv_n = ssm_mod.decode_step(p, x, st, cv, cfg)
                return x, (gate_rows(st_n, st), gate_rows(cv_n, cv))

            h, (sts, cvs) = jax.lax.scan(body, h, (seg_p, c["ssm"], c["conv"]))
            new_segs.append({"ssm": sts, "conv": cvs})
        else:  # zsuper
            shared_p = (params["shared_attn"], params["shared_mlp"])

            def super_body(x, xs):
                if cfg.kv_quant:
                    p, k_l, v_l, sk_l, sv_l, st_l = xs
                    x, kn, vn, skn, svn = attention.decode_step(
                        shared_p[0], x, k_l, v_l, index, cfg,
                        scale_k=sk_l, scale_v=sv_l, active=active)
                else:
                    p, k_l, v_l, st_l = xs
                    x, kn, vn = attention.decode_step(shared_p[0], x, k_l,
                                                      v_l, index, cfg,
                                                      active=active)
                    skn = svn = None
                x = _mlp_apply(shared_p[1], x, cfg)

                def inner(xx, ys):
                    pl, st, cv = ys
                    xx, st_n, cv_n = ssm_mod.decode_step(pl, xx, st, cv, cfg)
                    return xx, (gate_rows(st_n, st), gate_rows(cv_n, cv))

                x, (sts, cvs) = jax.lax.scan(
                    inner, x, (p["ssm"], st_l["ssm"], st_l["conv"]))
                if cfg.kv_quant:
                    return x, (kn, vn, skn, svn, sts, cvs)
                return x, (kn, vn, sts, cvs)

            if cfg.kv_quant:
                h, (ks, vs, sks, svs, sts, cvs) = jax.lax.scan(
                    super_body, h,
                    (seg_p, c["k"], c["v"], c["k_scale"], c["v_scale"],
                     c["ssm"]))
                new_segs.append({"k": ks, "v": vs, "k_scale": sks,
                                 "v_scale": svs,
                                 "ssm": {"ssm": sts, "conv": cvs}})
            else:
                h, (ks, vs, sts, cvs) = jax.lax.scan(
                    super_body, h, (seg_p, c["k"], c["v"], c["ssm"]))
                new_segs.append({"k": ks, "v": vs,
                                 "ssm": {"ssm": sts, "conv": cvs}})

    if index.ndim == 0:
        new_index = index + 1
    else:  # per-slot: vacant slots' sequence positions stay frozen
        new_index = index + (active.astype(jnp.int32) if active is not None
                             else 1)
    return h, new_segs, new_index


def _head(params, h, cfg: ModelConfig):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    return shard_constraint(logits, ("data", None, "model"))


def decode_step(params, cache, tokens, cfg: ModelConfig, active=None):
    """One decode step.  tokens (B,1) int32.

    Returns (logits (B,V), new_cache).  cache["index"] is the number of
    tokens already resident (scalar for lockstep decode, per-slot ``(B,)``
    under the continuous-batching pool); the new token is written at that
    position.  ``active (B,)`` marks resident streams — vacant slots are
    no-ops: adapter state bit-frozen, per-slot index frozen, no expert
    capacity consumed, logits garbage nothing reads.
    """
    h, new_segs, new_index = _decode_backbone(params, cache, tokens, cfg,
                                              active=active)
    new_cache = {"segments": new_segs, "index": new_index}
    if cfg.plastic_adapter:
        h, new_cache["adapter"] = plastic.decode_step(
            params["adapter"], cache["adapter"], h, cfg, active=active)
    logits = _head(params, h, cfg)[:, 0]
    return shard_constraint(logits, ("data", "model")), new_cache


def decode_rollout(params, cache, tokens, cfg: ModelConfig, active=None):
    """K known tokens per stream in one jitted program.  tokens (B,K) int32.

    Teacher-forced multi-token decode — chunked prompt tails, speculative
    draft verification, the scheduler's windowed `decode_window`: the
    backbone advances token-by-token inside a `lax.scan` (each token's
    attention must see the one before it), but the plastic adapter's K
    update steps run as ONE time-fused `engine.rollout` launch via
    `plastic.decode_rollout` instead of K per-token `layer_step` launches.
    This is sound because the adapter sits AFTER all segments: it touches
    only the final hidden state (hence the logits), never the KV/SSM
    caches, so the backbone scan can run to completion first and hand the
    adapter the whole (B, K, D) window.  Bit-identical to K `decode_step`
    calls on the same tokens (pinned in tests/test_serving_lm.py).

    Returns (logits (B,K,V), new_cache).  Works for every layout, with or
    without the adapter.
    """
    tk = jnp.swapaxes(tokens, 0, 1)[:, :, None]          # (K,B,1)

    def body(carry, tok):
        segs, index = carry
        h, segs, index = _decode_backbone(
            params, {"segments": segs, "index": index}, tok, cfg,
            active=active)
        return (segs, index), h[:, 0]

    (new_segs, new_index), hs = jax.lax.scan(
        body, (cache["segments"], cache["index"]), tk)
    h = jnp.swapaxes(hs, 0, 1)                           # (B,K,D)
    new_cache = {"segments": new_segs, "index": new_index}
    if cfg.plastic_adapter:
        h, new_cache["adapter"] = plastic.decode_rollout(
            params["adapter"], cache["adapter"], h, cfg, active=active)
    return _head(params, h, cfg), new_cache


# ---------------------------------------------------------------------------
# Introspection
# ---------------------------------------------------------------------------


def n_params(cfg: ModelConfig) -> int:
    return param_count(plan(cfg))


def abstract(cfg: ModelConfig, mesh=None, fsdp: bool = True):
    return abstract_from_plan(plan(cfg, fsdp), mesh)


def shardings(cfg: ModelConfig, mesh, fsdp: bool = True):
    return shardings_from_plan(plan(cfg, fsdp), mesh)


def pspecs(cfg: ModelConfig, mesh, fsdp: bool = True):
    return specs_from_plan(plan(cfg, fsdp), mesh)
