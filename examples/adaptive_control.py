"""End-to-end driver: adaptive control with simulated leg failure.

    PYTHONPATH=src python examples/adaptive_control.py [--full]

Reproduces the paper's central scenario: a controller whose synapses are
continuously rewritten by the learned rule RECOVERS from a mid-episode
actuator failure, while a weight-trained controller cannot adapt.

Pipeline: Phase-1 PEPG rule search on the direction task (8 headings) ->
Phase-2 deployment on unseen headings -> actuator-failure stress test.
Every rollout layer step runs through the PlasticEngine (`--impl` picks the
backend: "xla" CPU oracle, "pallas" TPU, "pallas-interpret" validation).
"""
import argparse
import json

import jax
import jax.numpy as jnp

from repro import envs
from repro.core import adaptation


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale run (slower)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"],
                    help="PlasticEngine backend for every rollout")
    args = ap.parse_args()

    gens = 60 if args.full else 12
    hidden = 128 if args.full else 24
    ep_len = 150 if args.full else 50

    env = envs.make("direction", episode_len=ep_len)
    cfg = adaptation.AdaptationConfig(hidden=hidden, timesteps=2,
                                      pop_pairs=16, generations=gens,
                                      seed=args.seed, impl=args.impl)

    results = {}
    for label, plastic in (("fireflyp", True), ("weight-trained", False)):
        print(f"== {label}: Phase 1 ({gens} generations) ==")
        params, hist, scfg = adaptation.optimize_rule(env, cfg,
                                                      plastic=plastic)
        print(f"  train fitness {float(hist[0]):.2f} -> {float(hist[-1]):.2f}")

        healthy = adaptation.evaluate_generalization(env, scfg, params,
                                                     seed=args.seed + 1)
        # leg failure: thruster 0 dies 1/3 into the episode
        mask = jnp.ones((env.act_dim,)).at[0].set(0.0)
        damaged = adaptation.evaluate_generalization(
            env, scfg, params, seed=args.seed + 1,
            actuator_mask=mask, mask_after=ep_len // 3)
        retention = float(damaged.mean()) / max(float(healthy.mean()), 1e-9)
        results[label] = {
            "train_first": float(hist[0]), "train_last": float(hist[-1]),
            "unseen72_mean": float(healthy.mean()),
            "unseen72_damaged_mean": float(damaged.mean()),
            "damage_retention": retention,
        }
        print(f"  unseen-72 mean return: {float(healthy.mean()):.2f}  "
              f"with leg failure: {float(damaged.mean()):.2f}")

    print(json.dumps(results, indent=1))
    print("\nThe plastic controller's weights are rewritten online by the "
          "rule, so it re-balances the remaining 7 thrusters after the "
          "failure; the weight-trained policy is frozen.")


if __name__ == "__main__":
    main()
