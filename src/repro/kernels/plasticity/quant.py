"""Fixed-point (FPGA-faithful) arithmetic for the dual-engine step.

FireFly-P's 8 us / 0.713 W / ~10K-LUT result rests entirely on fixed-point
arithmetic: multiplier-free tau_m = 2 (a shift), hard-reset LIF, power-of-two
trace decays, and integer weight updates.  This module is the single source
of truth for that datapath on JAX: the quantized oracle
(`ref.dual_engine_step_q`) and the quantized Pallas kernels
(`kernel.dual_engine_step_q_pallas`) both call the helpers below, so the
elementwise math literally cannot diverge between backends — and every
reduction in the quantized path is an INTEGER reduction (exact, order
independent), which is what makes the whole path bit-deterministic across
``impl="xla"`` and ``impl="pallas-interpret"`` (pinned in tests/test_quant.py).

Representation (see also the scheme writeup in `ops.py`):

  * weights    — int8 ``w_q`` with a per-tile fp32 scale ``s`` (one scale per
                 (N, M) weight matrix; the fleet pool carries one per slot):
                 ``w = w_q * s``.  The default scale is the power of two
                 ``2**-w_frac_bits`` so the int8 grid spans the clip range
                 and dequant is a shift on hardware.
  * membrane & traces — int32 fixed point with ``frac_bits`` fractional
                 bits: ``value = q * 2**-frac_bits``.
  * events     — same fixed point: a spike is ``one = 2**frac_bits``; the
                 readout event is the SATURATING-LINEAR activation
                 ``clip(v, -one, one)`` (the piecewise-linear tanh an FPGA
                 ships instead of the transcendental).
  * dw         — computed elementwise in f32 from exact integer trace
                 reductions, then converted to INTEGER grid steps with a
                 deterministic stochastic round (counter-hash PRNG below);
                 ``w_q`` advances by whole int8 steps.

Determinism contract: everything after the integer reductions is elementwise
(IEEE-reproducible), and the stochastic round draws its uniform from
`uniform_hash(seed, index)` — a pure function of the SESSION step counter
and the weight's flat (N*M) index, never of the fleet slot, the neighbours,
or wall-clock.  That is exactly what makes evict -> persist -> re-admit of a
quantized session bit-identical to an uninterrupted run.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.plasticity import ALPHA, BETA, GAMMA, DELTA


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static fixed-point parameters (hashable; threaded as a jit-static).

    ``frac_bits``   — fractional bits of the int32 membrane/trace format.
    ``w_frac_bits`` — weight grid: default scale is ``2**-w_frac_bits``
                      (1/32 -> int8 range +-127/32 ~= +-3.97, pairing with
                      the paper's w_clip = 4).
    ``trace_shift`` — power-of-two trace decay ``1 - 2**-trace_shift``
                      (shift-and-subtract on hardware; 2 -> 0.75).
    ``tau_shift``   — membrane time constant ``tau_m = 2**tau_shift``
                      (1 -> the paper's multiplier-free tau_m = 2).
    ``stoch_round`` — deterministic stochastic rounding of dw to grid steps
                      (False = round-half-even).
    """

    frac_bits: int = 8
    w_frac_bits: int = 5
    trace_shift: int = 2
    tau_shift: int = 1
    stoch_round: bool = True

    def __post_init__(self):
        for name in ("frac_bits", "w_frac_bits", "trace_shift", "tau_shift"):
            v = getattr(self, name)
            if not (isinstance(v, int) and 0 <= v <= 24):
                raise ValueError(f"{name} must be an int in [0, 24], got {v!r}")

    @property
    def one(self) -> int:
        """Fixed-point 1.0 of the membrane/trace format."""
        return 1 << self.frac_bits

    @property
    def w_scale(self) -> float:
        """Default (power-of-two) weight scale."""
        return 2.0 ** -self.w_frac_bits

    @property
    def decay(self) -> float:
        """Effective trace decay ``1 - 2**-trace_shift``."""
        return 1.0 - 2.0 ** -self.trace_shift

    @property
    def tau_m(self) -> float:
        return float(1 << self.tau_shift)


# ---- fixed-point conversion (network boundary) -----------------------------

def to_fixed(x, qc: QuantConfig):
    """float -> int32 fixed point (round-half-even, the hardware quantizer)."""
    return jnp.round(x.astype(jnp.float32) * float(qc.one)).astype(jnp.int32)


def from_fixed(q, qc: QuantConfig):
    """int32 fixed point -> float32 (exact for |q| < 2**24)."""
    return q.astype(jnp.float32) * jnp.float32(2.0 ** -qc.frac_bits)


# ---- integer datapath (shared verbatim by oracle AND Pallas kernels) -------

def neuron_update_q(v_fx, i_fx, qc: QuantConfig, v_th: float, v_reset: float,
                    spiking: bool):
    """Integer LIF / readout update.  Returns ``(event_fx, v_out_fx)``.

    ``v += (I - v) >> tau_shift`` is the paper's multiplier-free leaky
    integration (arithmetic shift = floor division, same as the RTL).
    Spiking: hard reset, event = fixed-point 1.0.  Readout: the event is
    ``clip(v, -1, 1)`` — the saturating-linear stand-in for tanh.
    """
    one = qc.one
    vth_fx = jnp.int32(int(round(v_th * one)))
    vres_fx = jnp.int32(int(round(v_reset * one)))
    v_new = v_fx + jnp.right_shift(i_fx - v_fx, qc.tau_shift)
    if spiking:
        sp = v_new >= vth_fx
        event = jnp.where(sp, jnp.int32(one), jnp.int32(0))
        v_out = jnp.where(sp, vres_fx, v_new)
    else:
        event = jnp.clip(v_new, -one, one)
        v_out = v_new
    return event, v_out


def trace_update_q(tp_fx, event_fx, qc: QuantConfig):
    """Integer trace decay + accumulate: ``tp - (tp >> k) + event``."""
    return tp_fx - jnp.right_shift(tp_fx, qc.trace_shift) + event_fx


def current_fx(acc_i32, scale, qc: QuantConfig):
    """Integer psum accumulator -> membrane fixed point.

    ``acc = x_fx @ w_q`` carries units ``2**-frac_bits * scale``; one
    elementwise multiply by the (per-tile) scale converts to membrane units.
    (With the default power-of-two scale this is a shift on hardware.)
    """
    del qc  # units cancel: acc * 2^-F * s * 2^F = acc * s
    return jnp.round(acc_i32.astype(jnp.float32) * scale).astype(jnp.int32)


def dw_from_int_reductions(hebb_i32, pre_sum_i32, post_sum_i32, theta,
                           batch: int, qc: QuantConfig):
    """Four-term dw (f32) from EXACT integer trace reductions.

    ``hebb_i32 = trace_pre_fx^T @ trace_post_fx`` and the pre/post sums are
    int32 (order-independent => bit-identical between the oracle's einsum
    and the kernel's per-tile dot); everything below is elementwise.

    A leading stream axis broadcasts: ``hebb (S, N, M)`` with sums
    ``(S, N)`` / ``(S, M)`` yields a per-stream ``(S, N, M)`` dw — the
    layout the fused rollout kernel uses for a block of fleet streams
    (elementwise identical to S separate unbatched calls).
    """
    inv1 = jnp.float32(1.0 / (qc.one * batch))
    inv2 = jnp.float32(1.0 / (qc.one * qc.one * batch))
    hebb = hebb_i32.astype(jnp.float32) * inv2
    pre_m = pre_sum_i32.astype(jnp.float32) * inv1
    post_m = post_sum_i32.astype(jnp.float32) * inv1
    th = theta.astype(jnp.float32)
    return (th[ALPHA] * hebb + th[BETA] * pre_m[..., :, None]
            + th[GAMMA] * post_m[..., None, :] + th[DELTA])


# ---- deterministic stochastic rounding -------------------------------------

def uniform_hash(seed, idx):
    """Counter-based uniform in [0, 1): avalanche hash of (seed, index).

    Pure elementwise uint32 arithmetic (wrapping mul/xor/shift) — identical
    on every backend, no PRNG state, no key threading.  ``seed`` is the
    session's step counter (scalar int32); ``idx`` the weight's flat index
    within its own (N, M) matrix, NEVER including the fleet slot.
    """
    h = idx.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
    h = h ^ ((jnp.asarray(seed, jnp.int32).astype(jnp.uint32)
              + jnp.uint32(0x7F4A7C15)) * jnp.uint32(0x85EBCA6B))
    h = h ^ (h >> jnp.uint32(15))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0x27D4EB2F)
    h = h ^ (h >> jnp.uint32(16))
    return (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0 ** -24)


def round_steps(steps_f32, seed, idx, qc: QuantConfig):
    """dw in units of the weight grid -> integer int8 steps.

    Stochastic: round up with probability = fractional part, drawn from
    `uniform_hash` — unbiased in expectation, so sub-grid updates still
    accumulate, yet fully deterministic given (seed, index).
    """
    if not qc.stoch_round:
        return jnp.round(steps_f32).astype(jnp.int32)
    fl = jnp.floor(steps_f32)
    frac = steps_f32 - fl
    return (fl + (frac > uniform_hash(seed, idx))).astype(jnp.int32)


def qclip(w_clip: float, scale):
    """Largest admissible |w_q|: ``min(floor(w_clip / scale), 127)``."""
    return jnp.minimum(jnp.floor(jnp.float32(w_clip) / scale),
                       jnp.float32(127.0)).astype(jnp.int32)


def fold_seed(seed, layer: int):
    """Per-layer seed: wrap-multiply fold so layers draw distinct uniforms."""
    return jnp.asarray(seed, jnp.int32) * jnp.int32(1000003) + jnp.int32(layer)
