from repro.kernels.plasticity.ops import dual_engine_step
from repro.kernels.plasticity.quant import QuantConfig

__all__ = ["dual_engine_step", "QuantConfig"]
