"""Time-fused rollout megakernel: fused-vs-per-step K-sweep, both datapaths.

Benchmarks `engine.rollout` (kernels/plasticity/fused: K timesteps x all
layers in ONE `pallas_call`, state VMEM-resident across the window) against
the per-step schedule (one `layer_step` launch per timestep) on the same
fleet workload, for the float32 AND the int8/int32 fixed-point datapaths.

Each cell also asserts the fusion contract before timing it: the fused
window must be BITWISE equal to the scanned xla oracle on the fixed-point
datapath (integer reductions — loop structure cannot move a bit), and
float-exact to 1e-6 on float32 (at 64-wide layers XLA contracts the dw
FMA chain differently in the two programs, the same ULP-level freedom the
per-step float kernels have always had; `tests/test_fused.py` pins float
BITWISE at controller scale).  A row only exists if its parity gate held;
``bitwise_vs_oracle`` records the measured bit-equality per cell.

    PYTHONPATH=src python benchmarks/rollout_fused.py [--smoke] [--impl ...]

Writes benchmarks/results/rollout_fused.json:
    {"impl": ..., "batch": B, "n": N, "m": M, "block_b": ...,
     "datapaths": ["float32", "int8"], "sweep": [
        {"k": K, "datapath": ..., "per_step_us_per_step": ...,
         "fused_us_per_step": ..., "fused_speedup": ...,
         "bitwise_vs_oracle": true}, ...]}
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.kernels.plasticity import quant as Q

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def make_net(b: int, n: int, m: int, key: jax.Array, qc=None):
    """One-layer fleet: B per-stream weight sets, shared rule theta."""
    ks = jax.random.split(key, 6)
    if qc is None:
        w = jnp.zeros((b, n, m), jnp.float32)
        v = 0.1 * jax.random.normal(ks[1], (b, m))
        tr_pre = jax.random.uniform(ks[2], (b, n))
        tr_post = jax.random.uniform(ks[3], (b, m))
        w_scale = ()
        x = (jax.random.uniform(ks[0], (b, n)) > 0.5).astype(jnp.float32)
    else:
        w = jnp.zeros((b, n, m), jnp.int8)
        v = Q.to_fixed(0.1 * jax.random.normal(ks[1], (b, m)), qc)
        tr_pre = Q.to_fixed(jax.random.uniform(ks[2], (b, n)), qc)
        tr_post = Q.to_fixed(jax.random.uniform(ks[3], (b, m)), qc)
        w_scale = (jnp.full((b,), qc.w_scale, jnp.float32),)
        x = Q.to_fixed(
            (jax.random.uniform(ks[0], (b, n)) > 0.5).astype(jnp.float32),
            qc)
    theta = [0.05 * jax.random.normal(ks[4], (4, n, m))]
    net = engine.NetworkState(w=(w,), v=(v,), trace=(tr_pre, tr_post),
                              t=jnp.zeros((), jnp.int32), w_scale=w_scale)
    return net, theta, x


def _time_us(fn, *args, iters: int) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_cell(k: int, b: int, n: int, m: int, impl: str, block_b: int,
               qc, iters: int) -> dict:
    net, theta, x = make_net(b, n, m, jax.random.PRNGKey(k), qc=qc)
    params = engine.EngineParams(
        block_m=m, quant=qc, tau_m=qc.tau_m if qc else 2.0,
        trace_decay=qc.decay if qc else 0.8)
    drives = jnp.broadcast_to(x[None], (k, b, n))

    f_fused = jax.jit(functools.partial(engine.rollout, params=[params],
                                        impl=impl, block_b=block_b))
    f_oracle = jax.jit(functools.partial(engine.rollout, params=[params],
                                         impl="xla"))
    s_f, o_f = f_fused(net, theta, drives)
    s_x, o_x = f_oracle(net, theta, drives)
    pairs = list(zip(jax.tree.leaves((s_f, o_f)),
                     jax.tree.leaves((s_x, o_x))))
    bitwise = all(np.array_equal(np.asarray(a), np.asarray(c))
                  for a, c in pairs)
    if qc is not None and not bitwise:
        raise AssertionError(
            f"fixed-point fused rollout drifted from the scanned oracle "
            f"(k={k}, impl={impl}) — integer reductions must be bitwise")
    if qc is None:
        for a, c in pairs:
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(c), rtol=1e-6, atol=1e-6,
                err_msg=f"float fused rollout drifted beyond ULP noise "
                        f"(k={k}, impl={impl})")

    def per_step(l_net, xx):
        # the pre-fusion schedule: one layer_step launch per timestep
        layer = engine.LayerState(
            w=l_net.w[0], v=l_net.v[0], trace_pre=l_net.trace[0],
            trace_post=l_net.trace[1], theta=theta[0],
            w_scale=l_net.w_scale[0] if l_net.w_scale else None)
        for i in range(k):
            seed = (Q.fold_seed(l_net.t.astype(jnp.int32) + i, 0)
                    if qc is not None else None)
            layer, _o = engine.layer_step(layer, xx, params=params,
                                          impl=impl, seed=seed)
        return layer

    step_us = _time_us(jax.jit(per_step), net, x, iters=iters)
    fused_us = _time_us(f_fused, net, theta, drives, iters=iters)
    return {"k": k, "datapath": "int8" if qc else "float32",
            "per_step_us_per_step": step_us / k,
            "fused_us_per_step": fused_us / k,
            "fused_speedup": step_us / fused_us,
            "bitwise_vs_oracle": bitwise}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, not minutes)")
    ap.add_argument("--impl", default="pallas-interpret",
                    choices=["xla", "pallas", "pallas-interpret"])
    ap.add_argument("--batch", type=int, default=64,
                    help="fleet size; the fused win is stream blocking "
                         "(grid B/block_b vs B), so small pools that fit "
                         "one grid program understate it")
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--m", type=int, default=64)
    ap.add_argument("--block-b", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        name = "rollout_fused_smoke.json" if args.smoke \
            else "rollout_fused.json"
        args.out = os.path.join(RESULTS, name)

    # smoke keeps the full batch: the fused win comes from stream blocking
    # (grid B/block_b vs B), which a pool small enough to fit one grid
    # program cannot show
    ks = [1, 8] if args.smoke else [1, 2, 4, 8, 16]
    b = args.batch
    iters = 2 if args.smoke else 5
    sweep = []
    print("k,datapath,per_step_us_per_step,fused_us_per_step,fused_speedup")
    for qc in (None, Q.QuantConfig()):
        for k in ks:
            row = bench_cell(k, b, args.n, args.m, args.impl,
                             args.block_b, qc, iters)
            sweep.append(row)
            print(f"{k},{row['datapath']},"
                  f"{row['per_step_us_per_step']:.0f},"
                  f"{row['fused_us_per_step']:.0f},"
                  f"{row['fused_speedup']:.2f}")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"impl": args.impl, "batch": b, "n": args.n, "m": args.m,
                   "block_b": args.block_b, "smoke": bool(args.smoke),
                   "datapaths": ["float32", "int8"], "sweep": sweep},
                  f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
