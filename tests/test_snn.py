"""LIF dynamics + SNN controller behaviour (paper Secs. II, III-B)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import plasticity as P, snn


class TestLIF:
    def test_tau2_halves_gap(self):
        """tau_m = 2: V moves half-way toward I each step (the
        multiplier-free FPGA trick)."""
        cfg = snn.LIFConfig(tau_m=2.0, v_threshold=10.0)
        v, s = snn.lif_step(jnp.zeros(()), jnp.asarray(1.0), cfg)
        assert float(v) == 0.5 and float(s) == 0.0

    def test_spike_and_reset(self):
        cfg = snn.LIFConfig(tau_m=2.0, v_threshold=1.0, v_reset=0.0)
        v, s = snn.lif_step(jnp.asarray(0.9), jnp.asarray(2.0), cfg)
        assert float(s) == 1.0 and float(v) == 0.0

    @given(st.floats(-4, 4), st.floats(-4, 4))
    @settings(max_examples=30, deadline=None)
    def test_subthreshold_never_spikes(self, v0, i0):
        cfg = snn.LIFConfig(v_threshold=100.0)
        v, s = snn.lif_step(jnp.asarray(v0), jnp.asarray(i0), cfg)
        assert float(s) == 0.0
        # convex combination stays inside [min, max]
        assert min(v0, i0) - 1e-5 <= float(v) <= max(v0, i0) + 1e-5


class TestController:
    def _cfg(self, plastic=True):
        return snn.SNNConfig(layer_sizes=(6, 16, 4), timesteps=3,
                             plastic=plastic)

    def test_zero_weight_start(self):
        cfg = self._cfg()
        st_ = snn.init_state(cfg)
        assert all(float(jnp.abs(w).sum()) == 0.0 for w in st_.w)

    def test_controller_step_shapes_finite(self):
        cfg = self._cfg()
        state = snn.init_state(cfg)
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
        obs = jnp.linspace(-1, 1, 6)
        state, action = snn.controller_step(cfg, state, theta, obs)
        assert action.shape == (4,)
        assert bool(jnp.isfinite(action).all())
        assert float(jnp.abs(action).max()) <= 1.0  # tanh readout

    def test_plasticity_rewrites_weights(self):
        cfg = self._cfg(plastic=True)
        state = snn.init_state(cfg)
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.5)
        obs = jnp.ones((6,))
        state, _ = snn.controller_step(cfg, state, theta, obs)
        assert any(float(jnp.abs(w).sum()) > 0 for w in state.w)

    def test_fixed_weights_stay_fixed(self):
        cfg = self._cfg(plastic=False)
        state = snn.init_state(cfg)
        state = dataclasses.replace(
            state, w=tuple(jnp.ones_like(w) for w in state.w))
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.5)
        new_state, _ = snn.controller_step(cfg, state, theta, jnp.ones((6,)))
        for w0, w1 in zip(state.w, new_state.w):
            np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))

    def test_theta_flatten_roundtrip(self):
        cfg = self._cfg()
        theta = snn.init_theta(cfg, jax.random.PRNGKey(1))
        flat = snn.flatten_theta(theta)
        assert flat.shape == (snn.theta_size(cfg),)
        back = snn.unflatten_theta(cfg, flat)
        for a, b in zip(theta, back):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)

    def test_classify_window_counts_spikes(self):
        cfg = snn.SNNConfig(layer_sizes=(10, 12, 3), timesteps=5,
                            spiking_readout=True)
        state = snn.init_state(cfg)
        theta = snn.init_theta(cfg, jax.random.PRNGKey(2), scale=0.5)
        state, scores = snn.classify_window(cfg, state, theta, jnp.ones((10,)))
        assert scores.shape == (3,)
        assert float(scores.min()) >= 0.0  # spike counts are non-negative
