"""Quickstart: the FireFly-P plasticity rule in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Build a plastic SNN controller (zero-initialized weights).
2. Optimize the RULE (not the weights) offline with PEPG on 8 directions.
3. Deploy frozen rule on 72 unseen directions — weights rewrite online.
4. Re-run the deployed controller through the PlasticEngine's Pallas
   backend (the fused dual-engine TPU kernel, validated here in interpret
   mode) — the SAME `controller_step` code path, one `impl=` flip away.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro import envs
from repro.core import adaptation, engine, snn

# ---------------------------------------------------------------- phase 1
env = envs.make("direction", episode_len=40)
cfg = adaptation.AdaptationConfig(hidden=16, timesteps=2, pop_pairs=8,
                                  generations=10)
print("Phase 1: optimizing the plasticity rule offline (PEPG)...")
theta, history, scfg = adaptation.optimize_rule(env, cfg)
print(f"  fitness: {float(history[0]):.2f} -> {float(history[-1]):.2f}")

# ---------------------------------------------------------------- phase 2
print("Phase 2: frozen rule, ZERO weights, 72 unseen directions...")
returns = adaptation.evaluate_generalization(env, scfg, theta)
print(f"  mean return on unseen tasks: {float(returns.mean()):.2f}")

# -------------------------------------------------- the hardware backend
print("Same controller through the Pallas dual-engine kernel (interpret):")
pcfg = dataclasses.replace(scfg, impl="pallas-interpret")
state = snn.init_state(pcfg)
rule = snn.unflatten_theta(pcfg, theta)
obs = env.observe(env.reset(jax.random.PRNGKey(0), env.eval_tasks()[0]))
state, action = snn.controller_step(pcfg, state, rule, obs)
dw = sum(float(jnp.abs(w).sum()) for w in state.w)
print(f"  action={[round(float(a), 3) for a in action]}, |W| grown online="
      f"{dw:.4f}  (forward + four-term plasticity in ONE kernel per layer)")

# or drive a single layer directly through the engine API:
layer = engine.LayerState(w=jnp.zeros((8, 16)), v=jnp.zeros((16,)),
                          trace_pre=jnp.ones((8,)),
                          trace_post=jnp.zeros((16,)),
                          theta=0.05 * jax.random.normal(
                              jax.random.PRNGKey(0), (4, 8, 16)))
x = (jax.random.uniform(jax.random.PRNGKey(1), (8,)) > 0.5).astype(jnp.float32)
layer, spikes = engine.layer_step(layer, x, impl="pallas-interpret")
print(f"  layer_step: spikes={int(spikes.sum())}, "
      f"|dW|={float(jnp.abs(layer.w).sum()):.4f}")
print("done.")
