"""Session serving: the active-mask contract, the SessionStore, and the
FleetScheduler's continuous-batching guarantees.

Pins, in order of load-bearing-ness:

  1. `active (B,)` through the engine stack: inactive fleet slots are TRUE
     no-ops on every backend — weights/membranes/traces bit-frozen, events
     zero — and active slots are bit-identical to an unmasked step.
  2. Evict -> persist (disk) -> re-admit into a DIFFERENT slot: the
     session's subsequent trajectory is bit-identical to an uninterrupted
     run, on xla and on pallas-interpret (the validated lowering of the
     pallas TPU path).
  3. The fixed-shape contract: churn (admit/evict/occupancy changes) never
     recompiles anything after the warm-up cycle.
  4. Fleet-mode state-shape validation (the satellite bugfix): an unbatched
     membrane/trace no longer silently broadcasts across streams.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, snn
from repro.serving import FleetScheduler, SessionStore

IMPLS = ["xla", "pallas-interpret"]


def _fleet_layer(key, b, n, m, plastic=True):
    ks = jax.random.split(key, 6)
    x = (jax.random.uniform(ks[0], (b, n)) > 0.5).astype(jnp.float32)
    state = engine.LayerState(
        w=0.1 * jax.random.normal(ks[1], (b, n, m)),
        v=0.1 * jax.random.normal(ks[2], (b, m)),
        trace_pre=jax.random.uniform(ks[3], (b, n)),
        trace_post=jax.random.uniform(ks[4], (b, m)),
        theta=0.01 * jax.random.normal(ks[5], (4, n, m)) if plastic
        else None)
    return state, x


def _drive(uid, t, n):
    phase = (hash(uid) % 97) / 97.0
    return np.sin(0.3 * t + phase + np.arange(n)).astype(np.float32)


class TestActiveMask:
    """engine.layer_step(active=...): vacant slots are true no-ops."""

    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("b,n,m,block_m", [(4, 10, 30, 16),
                                               (3, 17, 40, 128)])
    def test_inactive_frozen_active_untouched(self, impl, b, n, m, block_m):
        state, x = _fleet_layer(jax.random.PRNGKey(b * 7 + m), b, n, m)
        act = jnp.arange(b) % 2 == 0
        params = engine.EngineParams(block_m=block_m)
        ns, out = engine.layer_step(state, x, params=params, impl=impl,
                                    active=act)
        ns0, out0 = engine.layer_step(state, x, params=params, impl=impl)
        for i in range(b):
            if act[i]:
                # active slot: bit-identical to the unmasked step
                np.testing.assert_array_equal(np.asarray(ns.w[i]),
                                              np.asarray(ns0.w[i]))
                np.testing.assert_array_equal(np.asarray(out[i]),
                                              np.asarray(out0[i]))
            else:
                # inactive slot: bit-frozen state, zero events
                for fld in ("w", "v", "trace_post"):
                    np.testing.assert_array_equal(
                        np.asarray(getattr(ns, fld)[i]),
                        np.asarray(getattr(state, fld)[i]), err_msg=fld)
                assert (np.asarray(out[i]) == 0).all()

    def test_backend_parity_with_mask(self):
        state, x = _fleet_layer(jax.random.PRNGKey(3), 5, 12, 40)
        act = jnp.array([1, 0, 1, 1, 0], jnp.int32)
        params = engine.EngineParams(block_m=16)
        rs, ro = engine.layer_step(state, x, params=params, impl="xla",
                                   active=act)
        ps, po = engine.layer_step(state, x, params=params,
                                   impl="pallas-interpret", active=act)
        np.testing.assert_allclose(np.asarray(rs.w), np.asarray(ps.w),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ro), np.asarray(po),
                                   rtol=1e-5, atol=1e-5)
        # the frozen slots agree BITWISE across backends (no compute ran)
        for i in (1, 4):
            np.testing.assert_array_equal(np.asarray(rs.w[i]),
                                          np.asarray(ps.w[i]))

    @pytest.mark.parametrize("impl", IMPLS)
    def test_readout_layer_zeroes_inactive_output(self, impl):
        """spiking=False: `out` is the membrane, and the state gate freezes
        v to its OLD (nonzero) value — the OUTPUT must still be zero for
        inactive slots, never a stale membrane."""
        state, x = _fleet_layer(jax.random.PRNGKey(21), 4, 10, 12)
        act = jnp.array([True, False, True, False])
        params = engine.EngineParams(spiking=False)
        ns, out = engine.layer_step(state, x, params=params, impl=impl,
                                    active=act)
        for i in (1, 3):
            assert (np.asarray(out[i]) == 0).all()
            # while the membrane STATE stays frozen (nonzero)
            np.testing.assert_array_equal(np.asarray(ns.v[i]),
                                          np.asarray(state.v[i]))
        ns0, out0 = engine.layer_step(state, x, params=params, impl=impl)
        for i in (0, 2):
            np.testing.assert_array_equal(np.asarray(out[i]),
                                          np.asarray(out0[i]))

    @pytest.mark.parametrize("impl", IMPLS)
    def test_all_inactive_is_identity(self, impl):
        state, x = _fleet_layer(jax.random.PRNGKey(5), 3, 8, 24)
        ns, out = engine.layer_step(
            state, x, params=engine.EngineParams(), impl=impl,
            active=jnp.zeros(3, bool))
        for fld in ("w", "v", "trace_post"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ns, fld)), np.asarray(getattr(state, fld)))
        assert (np.asarray(out) == 0).all()

    def test_shared_weights_reject_mask(self):
        b, n, m = 3, 8, 16
        ks = jax.random.split(jax.random.PRNGKey(7), 5)
        state = engine.LayerState(
            w=0.1 * jax.random.normal(ks[0], (n, m)),
            v=jnp.zeros((b, m)), trace_pre=jnp.zeros((b, n)),
            trace_post=jnp.zeros((b, m)),
            theta=0.01 * jax.random.normal(ks[1], (4, n, m)))
        with pytest.raises(ValueError, match="fleet-mode"):
            engine.layer_step(state, jnp.zeros((b, n)),
                              active=jnp.ones(b, bool))

    def test_bad_mask_shape_rejected(self):
        state, x = _fleet_layer(jax.random.PRNGKey(9), 4, 8, 16)
        with pytest.raises(ValueError, match="active slot mask"):
            engine.layer_step(state, x, active=jnp.ones(3, bool))

    def test_timestep_freezes_input_trace(self):
        cfg = snn.SNNConfig(layer_sizes=(6, 12, 4))
        st = snn.init_state(cfg, batch=3, fleet=True)
        st = dataclasses.replace(
            st, trace=tuple(jax.random.uniform(jax.random.PRNGKey(i), t.shape)
                            for i, t in enumerate(st.trace)))
        theta = snn.init_theta(cfg, jax.random.PRNGKey(1))
        drive = jax.random.normal(jax.random.PRNGKey(2), (3, 6))
        act = jnp.array([True, False, True])
        st1, _ = snn.timestep(cfg, st, theta, drive, active=act)
        np.testing.assert_array_equal(np.asarray(st1.trace[0][1]),
                                      np.asarray(st.trace[0][1]))
        assert not np.array_equal(np.asarray(st1.trace[0][0]),
                                  np.asarray(st.trace[0][0]))


class TestFleetShapeValidation:
    """Satellite bugfix: v/trace_pre/trace_post get the same treatment x got."""

    def _state(self, b=4, n=10, m=30):
        return _fleet_layer(jax.random.PRNGKey(0), b, n, m)

    @pytest.mark.parametrize("field,shape", [
        ("v", (30,)),                 # unbatched membrane
        ("trace_pre", (10,)),         # unbatched pre trace
        ("trace_post", (30,)),        # unbatched post trace
        ("v", (30, 4)),               # transposed
        ("trace_post", (5, 30)),      # wrong B
    ])
    def test_unbatched_or_wrong_state_raises(self, field, shape):
        state, x = self._state()
        bad = dataclasses.replace(state, **{field: jnp.zeros(shape)})
        with pytest.raises(ValueError, match=f"fleet mode needs {field}"):
            engine.layer_step(bad, x, params=engine.EngineParams())

    def test_m_equals_b_trap(self):
        # the silent-broadcast trap: with M == B an unbatched (M,) membrane
        # broadcast used to be shape-compatible with (B, M)
        state, x = _fleet_layer(jax.random.PRNGKey(1), 4, 10, 4)
        bad = dataclasses.replace(state, v=jnp.zeros((4,)))
        with pytest.raises(ValueError, match="fleet mode needs v"):
            engine.layer_step(bad, x, params=engine.EngineParams())

    def test_valid_fleet_state_still_accepted(self):
        state, x = self._state()
        engine.layer_step(state, x, params=engine.EngineParams())


class TestSessionStore:
    def _cfg(self):
        return snn.SNNConfig(layer_sizes=(6, 12, 4), timesteps=2)

    def _rand_state(self, cfg, seed):
        z = snn.init_state(cfg)
        ks = jax.random.split(jax.random.PRNGKey(seed), len(z.w))
        return dataclasses.replace(
            z, w=tuple(0.3 * jax.random.normal(k, w.shape)
                       for k, w in zip(ks, z.w)))

    def test_checkout_is_exclusive(self, tmp_path):
        store = SessionStore(root=str(tmp_path))
        cfg = self._cfg()
        store.checkin("a", self._rand_state(cfg, 1), 5)
        assert "a" in store
        state, step = store.checkout("a", lambda: snn.init_state(cfg))
        assert step == 5 and "a" not in store     # no stale second copy

    def test_disk_roundtrip_bit_identical(self, tmp_path):
        cfg = self._cfg()
        store = SessionStore(root=str(tmp_path))
        st = self._rand_state(cfg, 2)
        store.checkin("u", st, 17)
        store._warm.clear()                        # force the disk path
        out, step = store.checkout("u", lambda: snn.init_state(cfg))
        assert step == 17 and store.restores == 1
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_lru_capacity_drops_without_losing_durability(self, tmp_path):
        cfg = self._cfg()
        store = SessionStore(root=str(tmp_path), capacity=2)
        for i, uid in enumerate(("a", "b", "c")):
            store.checkin(uid, self._rand_state(cfg, i), i)
        assert store.cached == ["b", "c"]           # a LRU-dropped...
        _, step = store.checkout("a", lambda: snn.init_state(cfg))
        assert step == 0 and store.restores == 1    # ...but still durable

    def test_ram_archive_without_root(self):
        cfg = self._cfg()
        store = SessionStore(root=None)
        st = self._rand_state(cfg, 3)
        store.checkin("u", st, 4)
        store._warm.clear()
        out, step = store.checkout("u", lambda: snn.init_state(cfg))
        assert step == 4
        np.testing.assert_array_equal(np.asarray(st.w[0]),
                                      np.asarray(out.w[0]))

    def test_fresh_user_gets_factory_state(self, tmp_path):
        cfg = self._cfg()
        store = SessionStore(root=str(tmp_path))
        out, step = store.checkout("new", lambda: snn.init_state(cfg))
        assert step == 0 and store.creates == 1
        assert all((np.asarray(w) == 0).all() for w in out.w)


class TestFleetScheduler:
    def _cfg(self, impl="xla"):
        return snn.SNNConfig(layer_sizes=(6, 12, 4), timesteps=2, impl=impl)

    def _sched(self, impl="xla", slots=3, root=None):
        cfg = self._cfg(impl)
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
        return FleetScheduler(cfg, theta, slots=slots,
                              store=SessionStore(root=root))

    def test_admit_evict_bookkeeping(self):
        s = self._sched()
        assert s.admit("a") == 0 and s.admit("b") == 1
        with pytest.raises(ValueError, match="already in slot"):
            s.admit("a")
        s.evict("a")
        assert s.slot_user[0] is None and s.free_slots == 2
        with pytest.raises(KeyError):
            s.evict("a")
        assert s.admit("c") == 0                    # slot reuse

    def test_full_pool_raises_or_evicts_lru(self):
        s = self._sched(slots=2)
        s.admit("a"); s.admit("b")
        with pytest.raises(RuntimeError, match="pool is full"):
            s.admit("c")
        slot = s.admit("c", evict_lru=True)         # a is LRU
        assert slot == 0 and "a" not in s.user_slot
        assert s.store.known("a")                   # evicted durably

    def test_step_validates_drive_cover(self):
        s = self._sched()
        s.admit("a")
        with pytest.raises(ValueError, match="missing"):
            s.step({})
        with pytest.raises(ValueError, match="not admitted"):
            s.step({"a": np.zeros(6, np.float32),
                    "ghost": np.zeros(6, np.float32)})
        with pytest.raises(ValueError, match="teach signals"):
            s.step({"a": np.zeros(6, np.float32)},
                   teach={"ghost": np.zeros(4, np.float32)})

    @pytest.mark.parametrize("impl", IMPLS)
    def test_evict_restore_different_slot_bit_identical(self, impl,
                                                        tmp_path):
        """THE acceptance pin: interrupted == uninterrupted, per backend."""
        cfg = self._cfg(impl)
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
        steps = 10 if impl == "xla" else 6
        cut = steps // 2

        def trajectory(interrupt):
            sub = "int" if interrupt else "unint"
            sched = FleetScheduler(
                cfg, theta, slots=2,
                store=SessionStore(root=str(tmp_path / f"{impl}-{sub}")))
            assert sched.admit("probe") == 0
            outs, states = [], []
            for t in range(steps):
                if interrupt and t == cut:
                    sched.evict("probe")           # -> disk
                    sched.store._warm.clear()      # force the disk path
                    sched.admit("rival")           # rival takes slot 0
                    sched.step({"rival": _drive("rival", 99, 6)})
                    assert sched.admit("probe") == 1   # DIFFERENT slot
                outs.append(np.asarray(sched.step(
                    {u: _drive(u, t, 6) for u in sched.active_users}
                )["probe"]))
            sched.evict("probe")
            final, step = sched.store.checkout(
                "probe", lambda: snn.init_state(cfg))
            return outs, final, step

        o1, f1, s1 = trajectory(False)
        o2, f2, s2 = trajectory(True)
        assert s1 == s2 == steps
        for a, b in zip(o1, o2):
            np.testing.assert_array_equal(a, b)
        for a, b in zip(jax.tree.leaves(f1), jax.tree.leaves(f2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_churn_never_recompiles_after_warmup(self):
        s = self._sched(slots=3)
        # warm-up cycle: touches step, put, take once each
        s.admit("w"); s.step({"w": _drive("w", 0, 6)})
        s.evict("w"); s.admit("w"); s.step({"w": _drive("w", 1, 6)})
        s.evict("w")
        c0 = s.compile_count()
        users = [f"u{i}" for i in range(5)]
        for t in range(20):
            uid = users[t % len(users)]
            if uid in s.user_slot:
                s.evict(uid)
            else:
                s.admit(uid, evict_lru=True)
            s.step({u: _drive(u, t, 6) for u in s.active_users})
        assert s.compile_count() == c0

    def test_idle_slots_frozen_bitwise(self):
        s = self._sched(slots=3)
        s.admit("a"); s.admit("b")
        for t in range(4):
            s.step({u: _drive(u, t, 6) for u in s.active_users})
        s.evict("b")
        vacant = s.slot_user.index(None)
        before = [np.asarray(w[vacant]).copy() for w in s.fleet.w]
        for t in range(6):
            s.step({"a": _drive("a", 10 + t, 6)})
        for w, b in zip(s.fleet.w, before):
            np.testing.assert_array_equal(np.asarray(w[vacant]), b)

    def test_teach_routes_to_output_layer(self):
        s = self._sched()
        s.admit("a"); s.admit("b")
        d = {u: _drive(u, 0, 6) for u in ("a", "b")}
        out_plain = s.step(d)
        s2 = self._sched()
        s2.admit("a"); s2.admit("b")
        out_teach = s2.step(d, teach={"a": 5.0 * np.ones(4, np.float32),
                                      "b": np.zeros(4, np.float32)})
        assert not np.array_equal(np.asarray(out_plain["a"]),
                                  np.asarray(out_teach["a"]))
        np.testing.assert_array_equal(np.asarray(out_plain["b"]),
                                      np.asarray(out_teach["b"]))

    def test_control_step_matches_controller_step_solo(self):
        """Pool control_step == snn.controller_step for a lone fleet-of-1.

        Ties the scheduler's windowed API to the reference controller
        semantics (same engine path, fleet B=1 vs fleet B=1)."""
        cfg = self._cfg()
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
        s = FleetScheduler(cfg, theta, slots=1, store=SessionStore())
        s.admit("solo")
        obs = _drive("solo", 0, 6)
        a_pool = np.asarray(s.control_step({"solo": obs})["solo"])
        ref_state = snn.init_state(cfg, batch=1, fleet=True)
        _, a_ref = snn.controller_step(cfg, ref_state, theta, obs[None])
        np.testing.assert_allclose(a_pool, np.asarray(a_ref[0]),
                                   rtol=1e-6, atol=1e-6)
