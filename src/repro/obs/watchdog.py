"""Recompile watchdog: "zero recompiles after warmup" as a runtime monitor.

The serving benchmarks pin their no-recompile invariants offline
(`serving_churn.py` asserts `compile_count()` deltas are zero under
churn).  This module makes the same invariant observable at serve time:
after warmup, ANY backend compilation is a bug — a shape drifted, a
Python scalar leaked into a traced signature, a new entry point was hit —
and the watchdog reports it the moment it happens, with the offending
program's name.

Mechanics (jax 0.4.x):

  * `jax.monitoring.register_event_duration_secs_listener` delivers every
    `/jax/core/compile/backend_compile_duration` event — the authoritative
    "XLA compiled something" signal — but carries NO program name.
  * The name travels on the `jax._src.dispatch` logger instead:
    "Finished XLA compilation of {fun_name} in ..." is logged immediately
    BEFORE the monitoring event fires (same thread, same call), so a DEBUG
    `logging.Handler` on that logger pairs names with events.

`jax.monitoring` has no per-listener unregister (only a global
`clear_event_listeners`), so the watchdog is a process-wide singleton
(`obs.watchdog.watchdog`) whose `install()` is idempotent — importing or
re-installing never stacks listeners.

Usage:

    watchdog.install()
    ... warmup: admit sessions, run one step per entry point ...
    with watchdog.armed():
        serve()                       # any compile -> warning + counter
    assert watchdog.violations == 0, watchdog.violation_signatures
"""
from __future__ import annotations

import logging
import re
import threading
from contextlib import contextmanager
from typing import List, Optional

# Mirrors jax._src.dispatch.BACKEND_COMPILE_EVENT (a string constant; we
# keep our own copy rather than importing the private module).
BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_DISPATCH_LOGGER = "jax._src.dispatch"
_COMPILE_MSG = re.compile(r"Finished XLA compilation of (?P<name>.+?) in ")


class _NameCapture(logging.Handler):
    """DEBUG handler on the jax dispatch logger capturing program names."""

    def __init__(self, watchdog: "RecompileWatchdog"):
        super().__init__(level=logging.DEBUG)
        self._watchdog = watchdog

    def emit(self, record: logging.LogRecord) -> None:
        try:
            m = _COMPILE_MSG.match(record.getMessage())
        except Exception:       # pragma: no cover - malformed record
            return
        if m:
            self._watchdog._last_name = m.group("name")
        # install() lowers the dispatch logger to DEBUG and stops
        # propagation (so forcing DEBUG records into existence does not
        # spam whatever root handler the host app configured); anything
        # the logger would have surfaced anyway (--jax_log_compiles logs
        # at WARNING) is forwarded to the root handlers here.
        if record.levelno >= logging.WARNING:
            logging.getLogger().handle(record)


class RecompileWatchdog:
    """Singleton compile monitor: count compiles, flag them while armed."""

    def __init__(self):
        self._installed = False
        self._armed = 0                 # re-entrant arm depth
        self._lock = threading.Lock()
        self._last_name: Optional[str] = None
        self.compiles = 0               # all backend compiles since install
        self.violations = 0             # compiles observed while armed
        self.violation_signatures: List[str] = []
        self.last_signature: Optional[str] = None
        self._registry = None
        self._log = logging.getLogger("repro.obs.watchdog")

    # ---- installation ----------------------------------------------------

    def install(self, registry=None) -> "RecompileWatchdog":
        """Register the jax.monitoring listener + name-capture handler.

        Idempotent: jax.monitoring cannot unregister a single listener, so
        repeated calls must not stack.  An optional metrics registry gets
        `compiles_total` / `recompiles_after_warmup_total` counters.
        """
        if registry is not None:
            self._registry = registry
        if self._installed:
            return self
        from jax import monitoring

        dispatch_logger = logging.getLogger(_DISPATCH_LOGGER)
        # The compile message is logged at DEBUG (WARNING only under
        # --jax_log_compiles); the logger must pass DEBUG records to our
        # handler.  Stdlib default handlers sit at WARNING, so this does
        # not spam the console.
        if dispatch_logger.level == logging.NOTSET or \
                dispatch_logger.level > logging.DEBUG:
            dispatch_logger.setLevel(logging.DEBUG)
        # Forcing DEBUG records into existence must not spray compile
        # chatter through the host app's root handler; _NameCapture
        # forwards WARNING+ records (e.g. --jax_log_compiles) itself.
        dispatch_logger.propagate = False
        dispatch_logger.addHandler(_NameCapture(self))

        monitoring.register_event_duration_secs_listener(self._on_event)
        self._installed = True
        return self

    # ---- arming ----------------------------------------------------------

    def arm(self) -> None:
        """Enter the no-recompile regime (re-entrant)."""
        with self._lock:
            self._armed += 1

    def disarm(self) -> None:
        with self._lock:
            self._armed = max(0, self._armed - 1)

    @property
    def is_armed(self) -> bool:
        return self._armed > 0

    @contextmanager
    def armed(self):
        """Context manager: compiles inside the block are violations."""
        self.arm()
        try:
            yield self
        finally:
            self.disarm()

    def reset(self) -> None:
        """Clear counts (keeps installation and arm depth)."""
        with self._lock:
            self.compiles = 0
            self.violations = 0
            self.violation_signatures = []
            self.last_signature = None

    # ---- the listener ----------------------------------------------------

    def _on_event(self, event: str, duration_secs: float, **kw) -> None:
        if event != BACKEND_COMPILE_EVENT:
            return
        name = self._last_name or "<unknown>"
        self._last_name = None
        with self._lock:
            self.compiles += 1
            self.last_signature = name
            armed = self._armed > 0
            if armed:
                self.violations += 1
                self.violation_signatures.append(name)
        if self._registry is not None:
            self._registry.counter(
                "compiles_total", "backend compiles since install").inc()
        if armed:
            if self._registry is not None:
                self._registry.counter(
                    "recompiles_after_warmup_total",
                    "compiles observed while the watchdog was armed").inc()
            self._log.warning(
                "recompile after warmup: %r compiled in %.3fs "
                "(violation #%d) — a shape or static argument drifted",
                name, duration_secs, self.violations)


# Process-wide singleton (jax.monitoring listeners cannot be removed
# individually, so everything shares this instance).
watchdog = RecompileWatchdog()
