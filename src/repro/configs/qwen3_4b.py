"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936; qk_norm, GQA, head_dim=128 (decoupled from d_model/H).
[hf:Qwen/Qwen3-8B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=9728, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
    layout="dense",
)

SMOKE = ModelConfig(
    name="qwen3-4b-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=64,       # head_dim != d_model/H, as in full
    qk_norm=True, rope_theta=1_000_000.0,
    layout="dense", remat=False,
)
