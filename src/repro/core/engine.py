"""PlasticEngine: the backend-dispatched fused layer step (product hot path).

One `layer_step` = one SNN timestep for ONE synaptic layer, running the
Forward Engine (psum matmul -> neuron dynamics -> trace update) and the
Plasticity Engine (four-term dw, weights rewritten in place) as a single
fused program — the FireFly-P dual-engine overlap (Secs. III-B/C).

Every consumer of the rule — `core/snn.timestep`, the adaptation loops, the
LM plastic adapter, serving, examples, and benchmarks — routes layer steps
through this module, so the Pallas kernel is the single source of truth for
the hot path rather than a benchmark artifact.

Backends (`impl`):

  * ``"xla"``              — pure-jnp oracle (kernels/plasticity/ref).  What
                             CPU runs and dry-runs lower; bit-stable with the
                             historical hand-rolled jnp layer loop.
  * ``"pallas"``           — the fused Pallas TPU kernel
                             (kernels/plasticity/kernel).
  * ``"pallas-interpret"`` — same kernel body executed by the Pallas
                             interpreter; validates the TPU program on CPU.

`layer_step` accepts unbatched ``(N,)`` or batched ``(B, N)`` state.  Two
batched semantics, selected by the weight rank:

  * SHARED weights ``w (N, M)`` with batched activations — the dw is
    batch-averaged (delta_w semantics; e.g. batched MNIST online learning).
  * FLEET mode, ``w (B, N, M)`` — every request stream owns and rewrites
    its OWN synapses with a per-sample dw under one shared rule theta.
    All three backends run the whole fleet as ONE fused program (the Pallas
    kernel launches a ``(cdiv(M, bm), B)`` grid, streams innermost so the
    shared theta tile is fetched once per tile); this replaces the old
    recipe of `jax.vmap`-ing `layer_step` per stream, which broadcast the
    shared rule theta B-fold and never lowered through `pallas_call` at
    all (the batching rule rejects unmapped operands).

Fleet mode additionally accepts an ``active (B,)`` slot mask (the session-
serving contract, `repro.serving`): streams whose flag is false are frozen
bit-exactly — weights, membrane, and traces unchanged, events zero — so a
fixed-shape slot pool under continuous batching never drifts in its vacant
slots and occupancy changes never recompile.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.plasticity import kernel as _kernel
from repro.kernels.plasticity import ref as _ref
from repro.kernels.plasticity.quant import QuantConfig

IMPLS = ("xla", "pallas", "pallas-interpret")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LayerState:
    """State slice the dual-engine step reads and rewrites for one layer.

    ``trace_pre`` is the *already-updated* presynaptic trace for the current
    timestep (the predecessor layer's Trace Update Unit runs upstream);
    ``trace_post`` is the previous timestep's postsynaptic trace, which
    `layer_step` advances and returns.  ``theta`` is the packed
    ``(4, n_pre, n_post)`` rule; ``None`` for non-plastic layers.

    A leading batch rank on ``w`` (``(B, N, M)``) puts the layer in FLEET
    mode: per-request weights, per-sample dw (see `layer_step`).
    """

    w: jax.Array                        # (N, M) | (B, N, M) synaptic weights
    v: jax.Array                        # (M,) | (B, M) membrane potential
    trace_pre: jax.Array                # (N,) | (B, N)
    trace_post: jax.Array               # (M,) | (B, M)
    theta: Optional[jax.Array] = None   # (4, N, M) packed rule coefficients
    w_scale: Optional[jax.Array] = None  # () | (B,) int8 weight scale (quant)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NetworkState:
    """Whole-network state: per-layer weights/membranes, per-population traces.

    Replaces the historical raw ``{"w": [...], "v": [...], "trace": [...]}``
    dict; registered as a pytree so it threads through jit/scan/vmap.
    ``trace`` has ``num_layers + 1`` entries — ``trace[i]`` is layer i's
    presynaptic population (``trace[0]`` is the input drive's trace).
    """

    w: Tuple[jax.Array, ...]
    v: Tuple[jax.Array, ...]
    trace: Tuple[jax.Array, ...]
    t: jax.Array
    # Fixed-point mode only: per-layer int8 weight scales (() shared /
    # (B,) fleet — one scale per slot).  Empty tuple in float mode, so the
    # pytree stays leaf-compatible with pre-quant states and checkpoints.
    w_scale: Tuple[jax.Array, ...] = ()

    @property
    def num_layers(self) -> int:
        return len(self.w)

    def layer(self, i: int, theta=None) -> LayerState:
        """View layer i as a LayerState (traces must be current-timestep)."""
        return LayerState(w=self.w[i], v=self.v[i], trace_pre=self.trace[i],
                          trace_post=self.trace[i + 1], theta=theta,
                          w_scale=self.w_scale[i] if self.w_scale else None)


@dataclasses.dataclass(frozen=True)
class EngineParams:
    """Static per-layer parameters of the fused step (hashable; jit-static)."""

    tau_m: float = 2.0
    v_th: float = 1.0
    v_reset: float = 0.0
    trace_decay: float = 0.8
    w_clip: float = 4.0
    plastic: bool = True
    spiking: bool = True        # False => leaky readout (event = tanh(V))
    block_m: int = 128          # Pallas postsynaptic tile width
    quant: Optional[QuantConfig] = None  # fixed-point mode (None = float32)


def layer_step(state: LayerState, x: jax.Array, *,
               params: EngineParams = EngineParams(),
               impl: str = "xla",
               teach: Optional[jax.Array] = None,
               active: Optional[jax.Array] = None,
               seed: Optional[jax.Array] = None
               ) -> tuple[LayerState, jax.Array]:
    """One fused forward+plasticity step for one layer.

    Args:
      state: layer state; rewritten functionally (w, v, trace_post advance).
             ``state.w`` of rank 3 (``(B, N, M)``) selects FLEET mode: one
             fused launch steps B per-request weight sets with per-sample dw.
      x:     presynaptic events ``(N,)`` or ``(B, N)``.
      params: static engine parameters.
      impl:  ``"xla"`` | ``"pallas"`` | ``"pallas-interpret"``.
      teach: optional teaching current added to the psum ``(M,)``/``(B, M)``
             (supervised online learning on the output layer).  In fleet
             mode an unbatched ``(M,)`` teach broadcasts to every stream.
      active: optional fleet-only ``(B,)`` slot mask (bool or 0/1).  Streams
             with a false flag are TRUE no-ops: weights, membrane, and
             traces come back bit-identical and their events are zero.
             This is the contract the session-serving scheduler uses to run
             a partially occupied fixed-shape slot pool without recompiling
             or letting vacant slots drift.
      seed:  fixed-point mode only — the step counter driving the
             deterministic stochastic round of dw (scalar; fleet mode takes
             a ``(B,)`` vector of per-SESSION counters so a session's
             update stream is invariant to its slot).  Defaults to 0.

    Returns:
      ``(new_state, out)`` — ``out`` is the layer's output events: spikes for
      spiking layers, the membrane potential for the leaky readout.
    """
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    plastic = params.plastic and state.theta is not None
    qc = params.quant
    if qc is not None:
        # Loud contracts: the fixed-point datapath implements power-of-two
        # dynamics; a float EngineParams that silently disagrees would make
        # "float vs quant" comparisons measure the wrong thing.
        if params.tau_m != qc.tau_m:
            raise ValueError(
                f"quant mode implements tau_m = 2**tau_shift = {qc.tau_m}; "
                f"set EngineParams.tau_m to match (got {params.tau_m})")
        if abs(params.trace_decay - qc.decay) > 1e-9:
            raise ValueError(
                f"quant mode implements trace_decay = 1 - 2**-trace_shift "
                f"= {qc.decay}; set EngineParams.trace_decay to match "
                f"(got {params.trace_decay})")
        checks = [("w", state.w, jnp.int8), ("x", x, jnp.int32),
                  ("v", state.v, jnp.int32),
                  ("trace_pre", state.trace_pre, jnp.int32),
                  ("trace_post", state.trace_post, jnp.int32)]
        if teach is not None:
            # a float teach would be silently truncated toward zero by the
            # fixed-point cast (|teach| < 1 -> exactly 0); demand the same
            # int32 event-bus format as every other operand
            checks.append(("teach", teach, jnp.int32))
        for name, arr, want in checks:
            if arr.dtype != want:
                raise ValueError(
                    f"quant mode needs {name} of dtype {jnp.dtype(want).name} "
                    f"(build state with snn.init_state on a quant config or "
                    f"snn.quantize_state; quantize drive/teach with "
                    f"kernels.plasticity.quant.to_fixed); got {arr.dtype}")
        kw = dict(qcfg=qc, v_th=params.v_th, v_reset=params.v_reset,
                  w_clip=params.w_clip, plastic=plastic,
                  spiking=params.spiking, seed=seed)
    else:
        kw = dict(tau_m=params.tau_m, v_th=params.v_th,
                  v_reset=params.v_reset, trace_decay=params.trace_decay,
                  w_clip=params.w_clip, plastic=plastic,
                  spiking=params.spiking)

    fleet = state.w.ndim == 3                   # fleet: per-request weights
    if fleet:
        b, n, m = state.w.shape
        if x.ndim != 2 or x.shape[0] != b:
            raise ValueError(
                f"fleet mode needs x of shape (B, N) matching w (B, N, M); "
                f"got x {x.shape} vs w {state.w.shape}")
        # Per-stream state must be batched too: an unbatched (M,) membrane
        # or trace would silently broadcast ONE user's state across every
        # stream (and, for M == B, transpose the axes without an error).
        for name, arr, want in (("v", state.v, (b, m)),
                                ("trace_pre", state.trace_pre, (b, n)),
                                ("trace_post", state.trace_post, (b, m))):
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"fleet mode needs {name} of shape {want} matching "
                    f"w (B, N, M) = {state.w.shape}; got {name} "
                    f"{tuple(arr.shape)}")
        if active is not None and tuple(active.shape) != (b,):
            raise ValueError(
                f"active slot mask must have shape (B,) = ({b},); got "
                f"{tuple(active.shape)}")
        # an unbatched (M,) teach broadcasts to every stream inside the
        # fleet wrappers (ref.dual_engine_fleet_step / the Pallas wrapper)
        kw["active"] = active
    elif active is not None:
        raise ValueError(
            "active slot masks are a fleet-mode (w (B, N, M)) contract; "
            f"got w {state.w.shape} with an active mask")

    # Select the backend function; the quant variants take the per-tile
    # weight scale as an extra positional between w and theta.
    if qc is not None:
        w_scale = (state.w_scale if state.w_scale is not None
                   else jnp.float32(qc.w_scale))
        scale_args = (w_scale,)
        fn = {("xla", False): _ref.dual_engine_step_q,
              ("xla", True): _ref.dual_engine_fleet_step_q,
              ("pallas", False): _kernel.dual_engine_step_q_pallas,
              ("pallas", True): _kernel.dual_engine_fleet_step_q_pallas}
    else:
        scale_args = ()
        fn = {("xla", False): _ref.dual_engine_step,
              ("xla", True): _ref.dual_engine_fleet_step,
              ("pallas", False): _kernel.dual_engine_step_pallas,
              ("pallas", True): _kernel.dual_engine_fleet_step_pallas}
    if impl == "xla":
        fn = fn[("xla", fleet)]
        spikes, v, tpost, w = fn(
            x, state.w, *scale_args, state.theta, state.v, state.trace_pre,
            state.trace_post, teach=teach, **kw)
    else:
        # The Pallas kernels are rank-(B, N); promote unbatched state to B=1.
        unbatched = not fleet and x.ndim == 1
        up = (lambda a: a[None]) if unbatched else (lambda a: a)
        fn = fn[("pallas", fleet)]
        spikes, v, tpost, w = fn(
            up(x), state.w, *scale_args, state.theta, up(state.v),
            up(state.trace_pre), up(state.trace_post),
            teach=None if teach is None else up(teach),
            block_m=params.block_m, interpret=(impl == "pallas-interpret"),
            **kw)
        if unbatched:
            spikes, v, tpost = spikes[0], v[0], tpost[0]

    new_state = dataclasses.replace(state, w=w, v=v, trace_post=tpost)
    out = spikes if params.spiking else v
    if active is not None and not params.spiking:
        # The readout's output IS the membrane; the state gate correctly
        # freezes v to its OLD value for inactive slots, but the output
        # contract ("inactive events are zero") must hold for readout
        # layers too — a pooled consumer must never see a stale membrane.
        out = jnp.where(active.astype(bool)[:, None], out,
                        jnp.zeros_like(out))
    return new_state, out
