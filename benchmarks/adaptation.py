"""Paper Fig. 3 analogue: FireFly-P (learned plasticity rule, zero-init
weights) vs weight-trained SNN on the three continuous-control tasks,
evaluated on UNSEEN task variants (direction/velocity/position
generalization).

Writes benchmarks/results/adaptation.json and prints a CSV:
    env,method,gen,train_fitness,eval_mean,eval_std
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro import envs
from repro.core import adaptation

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def run(env_name: str, generations: int = 30, hidden: int = 32,
        episode_len: int = 60, seed: int = 0, impl: str = "xla") -> dict:
    env = envs.make(env_name, episode_len=episode_len)
    out = {"env": env_name}
    # actuator-failure stress: actuator 0 dies 1/3 into every eval episode
    # (the paper's "simulated leg failure", Sec. II-B)
    fail_mask = jnp.ones((env.act_dim,)).at[0].set(0.0)
    for method, plastic in (("fireflyp", True), ("weight-trained", False)):
        cfg = adaptation.AdaptationConfig(
            hidden=hidden, timesteps=2, pop_pairs=12,
            generations=generations, seed=seed, impl=impl)
        t0 = time.time()
        params, hist, scfg = adaptation.optimize_rule(env, cfg,
                                                      plastic=plastic)
        rets = adaptation.evaluate_generalization(env, scfg, params)
        damaged = adaptation.evaluate_generalization(
            env, scfg, params, actuator_mask=fail_mask,
            mask_after=episode_len // 3)
        out[method] = {
            "train_history": [float(h) for h in hist],
            "eval_mean": float(rets.mean()),
            "eval_std": float(rets.std()),
            "eval_min": float(rets.min()),
            "damaged_mean": float(damaged.mean()),
            "damage_delta": float(damaged.mean() - rets.mean()),
            "wall_s": time.time() - t0,
        }
    return out


def main(quick: bool = False, impl: str = "xla"):
    os.makedirs(RESULTS, exist_ok=True)
    gens = 10 if quick else 30
    rows = []
    print("env,method,gens,final_train_fitness,eval72_mean,eval72_std,"
          "damaged_mean,damage_delta")
    for env_name in ("direction", "velocity", "position"):
        r = run(env_name, generations=gens, impl=impl)
        rows.append(r)
        for method in ("fireflyp", "weight-trained"):
            m = r[method]
            print(f"{env_name},{method},{gens},"
                  f"{m['train_history'][-1]:.2f},"
                  f"{m['eval_mean']:.2f},{m['eval_std']:.2f},"
                  f"{m['damaged_mean']:.2f},{m['damage_delta']:.2f}")
    with open(os.path.join(RESULTS, "adaptation.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"])
    args = ap.parse_args()
    main(quick=args.quick, impl=args.impl)
