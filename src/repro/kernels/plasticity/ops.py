"""Jit'd public wrapper for the fused dual-engine step.

`impl` selects the backend: "xla" (the ref oracle — what dry-runs and CPU
benchmarks lower), "pallas" (TPU target), or "pallas-interpret" (the Pallas
kernel body executed by the interpreter for CPU validation; equivalent to
``impl="pallas", interpret=True``).

Weight rank selects the mode: ``w.ndim == 2`` is the shared-weight step
(batch-averaged dw); ``w.ndim == 3`` is FLEET mode — per-request weights
``(B, N, M)`` with per-sample dw, one fused launch over all streams.

Network-level code should not call this directly — `core.engine.layer_step`
is the product entry point and adds LayerState plumbing and unbatched-state
support.  This wrapper is the kernel-level API used by kernel tests and
one-off comparisons.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.plasticity import kernel as _kernel
from repro.kernels.plasticity import ref as _ref


@functools.partial(
    jax.jit,
    static_argnames=("tau_m", "v_th", "v_reset", "trace_decay", "w_clip",
                     "plastic", "spiking", "impl", "interpret", "block_m"))
def dual_engine_step(x, w, theta, v, trace_pre, trace_post, teach=None,
                     active=None, *,
                     tau_m: float = 2.0, v_th: float = 1.0,
                     v_reset: float = 0.0, trace_decay: float = 0.8,
                     w_clip: float = 4.0, plastic: bool = True,
                     spiking: bool = True, impl: str = "xla",
                     interpret: bool = False, block_m: int = 128):
    kw = dict(tau_m=tau_m, v_th=v_th, v_reset=v_reset,
              trace_decay=trace_decay, w_clip=w_clip, plastic=plastic,
              spiking=spiking, teach=teach)
    fleet = w.ndim == 3
    if active is not None and not fleet:
        raise ValueError(
            "active slot masks are a fleet-mode (w (B, N, M)) contract; "
            f"got w {w.shape} with an active mask")
    if fleet:
        kw["active"] = active
    if impl in ("pallas", "pallas-interpret"):
        fn = (_kernel.dual_engine_fleet_step_pallas if fleet
              else _kernel.dual_engine_step_pallas)
        return fn(x, w, theta, v, trace_pre, trace_post, block_m=block_m,
                  interpret=interpret or impl == "pallas-interpret", **kw)
    fn = _ref.dual_engine_fleet_step if fleet else _ref.dual_engine_step
    return fn(x, w, theta, v, trace_pre, trace_post, **kw)
