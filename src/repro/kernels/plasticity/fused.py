"""Time-fused rollout megakernel: K timesteps x N layers in ONE pallas_call.

The per-step kernels in `kernel.py` are faithful to the FPGA's dual-engine
*datapath*, but not to its *schedule*: FireFly-P streams every timestep
through a single pipeline whose membranes and traces never leave on-chip
BRAM, while the per-step path issues one `pallas_call` per layer per
timestep — K * L launches per control window, each re-reading and
re-writing the full state through HBM.  `benchmarks/results/
fleet_throughput.json` shows the cost: per-launch overhead collapses fleet
throughput super-linearly with B.  FireFly v2 (arXiv:2309.16158) fixes the
same problem in hardware with spatiotemporal fusion; this module is the
Pallas analogue.

One `rollout_pallas` call executes the ENTIRE window:

  * weights, membranes, and all L+1 population traces are loaded into
    VMEM/registers ONCE per grid program and written back ONCE — dw
    accumulates locally across all K steps (HBM traffic is K-independent);
  * the inter-layer event bus (layer i's spikes feeding layer i+1) is a
    register value, never a memory round-trip;
  * the K input drives / teach rows and the K readout rows are the only
    time-major staging buffers, streamed through the same block.

Modes — the same body serves all four datapaths:

  * SHARED weights (w (N, M), batched activations, batch-averaged dw):
    grid (1,), the whole batch in one program.
  * FLEET (w (B, N, M), per-sample dw, shared theta, optional `active`
    slot mask): grid (cdiv(B, block_b),) — `block_b` request streams per
    program, the stream axis carried *inside* the block (one einsum
    forward, broadcast outer-product Hebbian), which divides the dominant
    per-grid-iteration overhead of interpret mode by block_b while staying
    bit-identical to per-stream execution (streams never interact).
  * float32 and the PR-4 fixed-point datapath (int8 weights promoted to
    int32 registers for the window, int32 membranes/traces, deterministic
    stochastic rounding seeded per session and per STEP: step k of the
    window draws from ``fold_seed(base_seed + k, layer)`` — exactly the
    per-step kernels' seed sequence, so evict -> re-admit mid-window stays
    bit-identical).

Time iteration: `unroll_k` chunks the K-step loop — steps run in a
`lax.fori_loop` over chunks of `unroll_k` fully-unrolled steps (1 = rolled
loop, 0 or >= K = full unroll).  On the fixed-point datapath every setting
computes identical bits (integer arithmetic is association-free).  On
float32 the BIT-PINNED setting is the default ``unroll_k=1``: each loop
body holds exactly one timestep, matching the scanned oracle's computation
boundaries, so parity with `engine.rollout(impl="xla")` is bit equality at
controller-scale layer widths (tests/test_fused.py pins it).  Two float
caveats, both ULP-level (~1e-7) and both inherent FMA-contraction freedom
rather than kernel drift: unrolling several steps into one body lets XLA
contract FMAs ACROSS steps, and at wide layers (~64+) XLA may contract
the dw chain differently in the two programs even at ``unroll_k=1`` (the
same freedom the per-step float kernels have always had — their parity
tests are tolerance-based).  Where bit-reproducibility must be
unconditional, the fixed-point datapath is the contract.

No postsynaptic tiling: layer i+1's forward pass needs ALL of layer i's
output events, so a fused program must hold every layer's full (N_i, M_i)
extent — `block_m` does not apply here.  The VMEM budget is therefore
per-program working set
``block_b * sum_i(5 * N_i * M_i) * 4B  +  K * (N_0 + M_L) * block_b * 4B``
(w + 4 theta planes dominate); pick block_b/K to fit ~16 MB on real TPUs.
Bit-parity (K=1 vs the per-step kernels, K>1 vs the scanned xla oracle in
`engine.rollout`) is pinned by tests/test_fused.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.plasticity import ALPHA, BETA, GAMMA, DELTA
from repro.kernels.plasticity import quant as Q
from repro.obs.telemetry import sat_threshold, sat_threshold_q


def _rollout_kernel(*refs, n_layers, k_steps, spiking, plastic, fleet,
                    batch, tau_m, v_th, v_reset, trace_decay, w_clip, qcfg,
                    has_teach, has_active, unroll_k, telemetry):
    """One grid program = the FULL K-step window for its block of streams
    (fleet) or the whole batch (shared weights).

    ``telemetry`` (fleet only) extends the time-loop carry with a (bb, 2)
    [spike, saturation] accumulator and appends one finalized (bb, 3)
    output — the per-slot MEANS of `obs.FleetTelemetry`.  The |dw| column
    is NET weight motion |w_end - w_start| from the already-resident
    carry, not a per-step accumulation: per-step deltas would add a
    (bb, N, M) reduction to every iteration of the hot loop (and on the
    fixed-point grid per-step dw is mostly sub-quantum noise anyway),
    while net motion costs one reduction per WINDOW on registers the
    write-back touches regardless.
    """
    it = iter(refs)
    drives_ref = next(it)
    w_refs = [next(it) for _ in range(n_layers)]
    th_refs = [next(it) if plastic[i] else None for i in range(n_layers)]
    v_refs = [next(it) for _ in range(n_layers)]
    tr_refs = [next(it) for _ in range(n_layers + 1)]
    teach_ref = next(it) if has_teach else None
    active_ref = next(it) if has_active else None
    if qcfg is not None:
        scale_refs = [next(it) for _ in range(n_layers)]
        seed_ref = next(it)
    out_ref = next(it)
    w_outs = [next(it) for _ in range(n_layers)]
    v_outs = [next(it) for _ in range(n_layers)]
    tr_outs = [next(it) for _ in range(n_layers + 1)]
    tel_out = next(it) if telemetry else None

    compute = jnp.float32 if qcfg is None else jnp.int32
    # Load the window's whole working set ONCE: weight tiles, membranes and
    # every population trace stay VMEM/register-resident across all K steps
    # (the paper's on-chip state residency); HBM sees one read and one
    # write per state tensor regardless of K.
    ws0 = tuple(w_refs[i][...].astype(compute) for i in range(n_layers))
    vs0 = tuple(v_refs[i][...].astype(compute) for i in range(n_layers))
    trs0 = tuple(tr_refs[i][...].astype(compute)
                 for i in range(n_layers + 1))
    ths = [None if th_refs[i] is None
           else th_refs[i][...].astype(jnp.float32) for i in range(n_layers)]
    gate = None if active_ref is None else active_ref[...] > 0   # (bb, 1)
    if qcfg is not None:
        if fleet:
            scales = [scale_refs[i][...] for i in range(n_layers)]  # (bb, 1)
            base_seed = seed_ref[...]                               # (bb, 1)
        else:
            scales = [scale_refs[i][0, 0] for i in range(n_layers)]
            base_seed = seed_ref[0, 0]

    def one_step(k, carry):
        if telemetry:
            ws, vs, trs, tel = carry
        else:
            (ws, vs, trs), tel = carry, None
        ws, vs, trs = list(ws), list(vs), list(trs)
        x = drives_ref[pl.ds(k, 1)][0].astype(compute)   # (bb, N0) event bus
        # input-population Trace Update Unit (gated exactly as snn.timestep)
        if qcfg is None:
            tr0_new = trace_decay * trs[0] + x
        else:
            tr0_new = Q.trace_update_q(trs[0], x, qcfg)
        if gate is not None:
            tr0_new = jnp.where(gate, tr0_new, trs[0])
        trs[0] = tr0_new
        for i in range(n_layers):
            w, v, tpost = ws[i], vs[i], trs[i + 1]
            # ---- Forward Engine: psum on the resident weight tile --------
            if fleet:
                acc = jnp.einsum("bn,bnm->bm", x, w,
                                 preferred_element_type=compute)
            else:
                acc = jnp.dot(x, w, preferred_element_type=compute)
            current = acc if qcfg is None else Q.current_fx(acc, scales[i],
                                                            qcfg)
            if teach_ref is not None and i == n_layers - 1:
                current = current + teach_ref[pl.ds(k, 1)][0].astype(compute)
            if qcfg is None:
                v_new = v + (current - v) * (1.0 / tau_m)
                if spiking[i]:
                    events = (v_new >= v_th).astype(jnp.float32)
                    v_upd = jnp.where(events > 0, v_reset, v_new)
                else:                       # non-spiking leaky readout
                    events = jnp.tanh(v_new)
                    v_upd = v_new
                tpost_new = trace_decay * tpost + events
            else:
                events, v_upd = Q.neuron_update_q(v, current, qcfg, v_th,
                                                  v_reset, spiking[i])
                tpost_new = Q.trace_update_q(tpost, events, qcfg)
            # Plasticity consumes the UNGATED post-trace, exactly like the
            # xla oracle (ref gates outputs after the vmapped step): for
            # active slots the values are identical and inactive slots'
            # dw is discarded by the weight gate below — but keeping the
            # oracle's dataflow keeps XLA's FMA contraction identical, so
            # float parity stays BITWISE rather than ulp-close.
            tpost_raw = tpost_new
            if gate is not None:
                events = jnp.where(gate, events, jnp.zeros_like(events))
                v_upd = jnp.where(gate, v_upd, v)
                tpost_new = jnp.where(gate, tpost_new, tpost)
            # ---- Plasticity Engine (same resident tiles, no HBM pass) ----
            if plastic[i]:
                th, tpre = ths[i], trs[i]
                tpost_p = tpost_raw
                if qcfg is None:
                    if fleet:   # per-stream outer-product dw, shared rule
                        hebb = tpre[:, :, None] * tpost_p[:, None, :]
                        dw = (th[ALPHA] * hebb + th[BETA] * tpre[:, :, None]
                              + th[GAMMA] * tpost_p[:, None, :]
                              + th[DELTA])
                    else:       # shared weights: batch-averaged dw
                        hebb = jnp.dot(
                            tpre.T, tpost_p,
                            preferred_element_type=jnp.float32) / batch
                        pre_m = jnp.mean(tpre, axis=0)
                        post_m = jnp.mean(tpost_p, axis=0)
                        dw = (th[ALPHA] * hebb + th[BETA] * pre_m[:, None]
                              + th[GAMMA] * post_m[None, :] + th[DELTA])
                    w_new = jnp.clip(w + dw, -w_clip, w_clip)
                else:
                    if fleet:
                        hebb_i = tpre[:, :, None] * tpost_p[:, None, :]
                        dw = Q.dw_from_int_reductions(hebb_i, tpre,
                                                      tpost_p, th, 1, qcfg)
                        scale = scales[i][:, :, None]             # (bb,1,1)
                        seed_i = Q.fold_seed(base_seed + k, i)[:, :, None]
                    else:
                        hebb_i = jnp.dot(tpre.T, tpost_p,
                                         preferred_element_type=jnp.int32)
                        dw = Q.dw_from_int_reductions(
                            hebb_i, tpre.sum(0), tpost_p.sum(0), th,
                            batch, qcfg)
                        scale = scales[i]
                        seed_i = Q.fold_seed(base_seed + k, i)
                    n_i, m_i = w.shape[-2], w.shape[-1]
                    idx = (jax.lax.broadcasted_iota(jnp.int32,
                                                    (n_i, m_i), 0) * m_i
                           + jax.lax.broadcasted_iota(jnp.int32,
                                                      (n_i, m_i), 1))
                    steps = Q.round_steps(dw / scale, seed_i, idx, qcfg)
                    qmax = Q.qclip(w_clip, scale)
                    w_new = jnp.clip(w + steps, -qmax, qmax)
                if gate is not None:
                    w_new = jnp.where(gate[:, :, None], w_new, w)
                ws[i] = w_new
            vs[i] = v_upd
            trs[i + 1] = tpost_new
            if telemetry:
                # Per-layer means accumulate step by step; events are
                # already gated (zeros for vacant slots), the saturation
                # term is gated once at finalize.
                m_i = events.shape[-1]
                ev_f = jnp.abs(events).astype(jnp.float32)
                if qcfg is not None:
                    ev_f = ev_f * (1.0 / qcfg.one)
                    sat = jnp.abs(v_upd) >= sat_threshold_q(v_th, qcfg)
                else:
                    sat = jnp.abs(v_upd) >= sat_threshold(v_th)
                tel = tel + jnp.stack(
                    [jnp.sum(ev_f, axis=1) / m_i,
                     jnp.sum(sat.astype(jnp.float32), axis=1) / m_i],
                    axis=1)
            out = events if spiking[i] else v_upd
            if gate is not None and not spiking[i]:
                # readout output IS the membrane; inactive slots must still
                # emit zero events (same contract as engine.layer_step)
                out = jnp.where(gate, out, jnp.zeros_like(out))
            x = out
        out_ref[pl.ds(k, 1)] = x[None].astype(out_ref.dtype)
        new = (tuple(ws), tuple(vs), tuple(trs))
        return new + ((tel,) if telemetry else ())

    carry = (ws0, vs0, trs0)
    if telemetry:
        carry = carry + (jnp.zeros((ws0[0].shape[0], 2), jnp.float32),)
    if unroll_k <= 0 or unroll_k >= k_steps:
        for k in range(k_steps):                      # full unroll
            carry = one_step(k, carry)
    else:
        n_chunks = k_steps // unroll_k

        def chunk(c, carry):
            for j in range(unroll_k):
                carry = one_step(c * unroll_k + j, carry)
            return carry

        carry = jax.lax.fori_loop(0, n_chunks, chunk, carry)
        for k in range(n_chunks * unroll_k, k_steps):  # remainder
            carry = one_step(k, carry)
    ws, vs, trs = carry[0], carry[1], carry[2]
    # single write-back: K steps of dw land in HBM as ONE weight store
    for i in range(n_layers):
        w_outs[i][...] = ws[i].astype(w_outs[i].dtype)
        v_outs[i][...] = vs[i].astype(v_outs[i].dtype)
    for i in range(n_layers + 1):
        tr_outs[i][...] = trs[i].astype(tr_outs[i].dtype)

    if telemetry:
        tel_acc = carry[3]
        kl = float(k_steps * n_layers)
        spike_rate = tel_acc[:, 0] / kl
        sat_frac = tel_acc[:, 1] / kl
        plast = [i for i in range(n_layers) if plastic[i]]
        if plast:
            dw_sum = jnp.zeros_like(spike_rate)
            for i in plast:
                n_i, m_i = ws[i].shape[-2], ws[i].shape[-1]
                d = jnp.abs(ws[i] - ws0[i]).astype(jnp.float32)
                per_slot = jnp.sum(d, axis=(1, 2))
                if qcfg is not None:
                    per_slot = per_slot * scales[i][:, 0]
                dw_sum = dw_sum + per_slot / (n_i * m_i)
            mean_dw = dw_sum / float(k_steps * len(plast))
        else:
            mean_dw = jnp.zeros_like(spike_rate)
        row = jnp.stack([spike_rate, mean_dw, sat_frac], axis=1)  # (bb, 3)
        if gate is not None:
            row = row * gate.astype(jnp.float32)      # (bb, 1) broadcast
        tel_out[...] = row


def rollout_pallas(drives, ws, thetas, vs, traces, *, spiking, plastic,
                   tau_m: float = 2.0, v_th: float = 1.0,
                   v_reset: float = 0.0, trace_decay: float = 0.8,
                   w_clip: float = 4.0, qcfg=None, scales=None, seed=None,
                   teach=None, active=None, telemetry: bool = False,
                   block_b: int = 8, unroll_k: int = 1,
                   interpret: bool = False):
    """K fused timesteps of the whole layer stack in one pallas_call.

    Args:
      drives:  (K, B, N0) time-major input window (int32 fixed point when
               ``qcfg``; float otherwise).
      ws:      per-layer weights — (N_i, M_i) shared or (B, N_i, M_i) fleet
               (int8 in quant mode).
      thetas:  per-layer packed (4, N_i, M_i) rules; None for non-plastic
               layers.
      vs:      per-layer membranes (B, M_i).
      traces:  L+1 population traces (B, N_i); traces[0] is the input
               population.
      spiking/plastic: per-layer static bool tuples.
      qcfg/scales/seed: fixed-point mode — per-layer weight scales
               ((B,)/() f32) and the base step counter ((B,)/() int32);
               step k of the window draws its stochastic round from
               ``fold_seed(seed + k, layer)``.
      teach:   optional (K, B, M_last) teaching current (already
               normalized by engine.rollout).
      active:  fleet-only (B,) slot mask; inactive streams are bit-frozen
               across the whole window and emit zero events.
      telemetry: fleet-only static flag — append a finalized (B, 3)
               float32 output of per-slot means [spike_rate, mean |dw|
               (net window motion), sat_frac] (`obs.telemetry` schema;
               vacant slots all-zero).  Off keeps the program
               byte-identical to the unistrumented one.
      block_b: fleet streams per grid program (stream-blocked execution).
      unroll_k: time-loop chunking (see module docstring); bit-pinned vs
               the oracle at 1 (and at every setting in quant mode).

    Returns ``(outs, ws, vs, traces)`` with outs (K, B, M_last), plus the
    (B, 3) telemetry row when ``telemetry=True``.
    """
    k_steps, b, n0 = drives.shape
    n_layers = len(ws)
    fleet = ws[0].ndim == 3
    sizes = [n0] + [w.shape[-1] for w in ws]
    spiking = tuple(bool(s) for s in spiking)
    plastic = tuple(bool(p) for p in plastic)
    for i in range(n_layers):
        if plastic[i] and thetas[i] is None:
            raise ValueError(f"layer {i} marked plastic but theta is None")
    has_teach = teach is not None
    has_active = active is not None
    if telemetry and not fleet:
        raise ValueError("telemetry is a fleet-mode contract "
                         "(per-slot rows need a leading stream rank)")

    if fleet:
        bb = min(block_b, b)
        grid = (pl.cdiv(b, bb),)
        tmap = lambda i: (0, i, 0)      # time-major staging (K, bb, n)
        wmap = lambda i: (i, 0, 0)      # per-stream weight block
        thmap = lambda i: (0, 0, 0)     # shared rule: constant index =>
        rmap = lambda i: (i, 0)         # one theta DMA for the whole fleet
    else:
        bb = b
        grid = (1,)
        tmap = lambda i: (0, 0, 0)
        wmap = lambda i: (0, 0)
        thmap = lambda i: (0, 0, 0)
        rmap = lambda i: (0, 0)

    in_specs = [pl.BlockSpec((k_steps, bb, n0), tmap)]
    operands = [drives]
    for i in range(n_layers):
        shape = ((bb, sizes[i], sizes[i + 1]) if fleet
                 else (sizes[i], sizes[i + 1]))
        in_specs.append(pl.BlockSpec(shape, wmap))
        operands.append(ws[i])
    for i in range(n_layers):
        if plastic[i]:
            in_specs.append(
                pl.BlockSpec((4, sizes[i], sizes[i + 1]), thmap))
            operands.append(thetas[i])
    for i in range(n_layers):
        in_specs.append(pl.BlockSpec((bb, sizes[i + 1]), rmap))
        operands.append(vs[i])
    for i in range(n_layers + 1):
        in_specs.append(pl.BlockSpec((bb, sizes[i]), rmap))
        operands.append(traces[i])
    if has_teach:
        in_specs.append(pl.BlockSpec((k_steps, bb, sizes[-1]), tmap))
        operands.append(teach)
    if has_active:
        in_specs.append(pl.BlockSpec((bb, 1), rmap))
        operands.append(
            jnp.asarray(active).reshape(b, 1).astype(jnp.float32))
    if qcfg is not None:
        for i in range(n_layers):
            sc = jnp.asarray(scales[i], jnp.float32)
            if fleet:
                if sc.ndim == 0:
                    sc = jnp.broadcast_to(sc, (b,))
                sc = sc.reshape(b, 1)
                in_specs.append(pl.BlockSpec((bb, 1), rmap))
            else:
                sc = sc.reshape(1, 1)
                in_specs.append(pl.BlockSpec((1, 1), rmap))
            operands.append(sc)
        sd = jnp.asarray(0 if seed is None else seed, jnp.int32)
        if fleet:
            if sd.ndim == 0:
                sd = jnp.broadcast_to(sd, (b,))
            sd = sd.reshape(b, 1)
            in_specs.append(pl.BlockSpec((bb, 1), rmap))
        else:
            sd = sd.reshape(1, 1)
            in_specs.append(pl.BlockSpec((1, 1), rmap))
        operands.append(sd)

    out_dtype = jnp.int32 if qcfg is not None else drives.dtype
    out_specs = [pl.BlockSpec((k_steps, bb, sizes[-1]), tmap)]
    out_shape = [jax.ShapeDtypeStruct((k_steps, b, sizes[-1]), out_dtype)]
    for i in range(n_layers):
        shape = ((bb, sizes[i], sizes[i + 1]) if fleet
                 else (sizes[i], sizes[i + 1]))
        out_specs.append(pl.BlockSpec(shape, wmap))
        out_shape.append(jax.ShapeDtypeStruct(ws[i].shape, ws[i].dtype))
    for i in range(n_layers):
        out_specs.append(pl.BlockSpec((bb, sizes[i + 1]), rmap))
        out_shape.append(jax.ShapeDtypeStruct(vs[i].shape, vs[i].dtype))
    for i in range(n_layers + 1):
        out_specs.append(pl.BlockSpec((bb, sizes[i]), rmap))
        out_shape.append(
            jax.ShapeDtypeStruct(traces[i].shape, traces[i].dtype))
    if telemetry:
        out_specs.append(pl.BlockSpec((bb, 3), rmap))
        out_shape.append(jax.ShapeDtypeStruct((b, 3), jnp.float32))

    kernel = functools.partial(
        _rollout_kernel, n_layers=n_layers, k_steps=k_steps,
        spiking=spiking, plastic=plastic, fleet=fleet, batch=b,
        tau_m=tau_m, v_th=v_th, v_reset=v_reset, trace_decay=trace_decay,
        w_clip=w_clip, qcfg=qcfg, has_teach=has_teach,
        has_active=has_active, unroll_k=int(unroll_k),
        telemetry=telemetry)
    res = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret)(*operands)
    outs = res[0]
    ws_new = tuple(res[1:1 + n_layers])
    vs_new = tuple(res[1 + n_layers:1 + 2 * n_layers])
    trs_new = tuple(res[1 + 2 * n_layers:2 + 3 * n_layers])
    base = (outs, ws_new, vs_new, trs_new)
    return base + ((res[2 + 3 * n_layers],) if telemetry else ())
