from repro.kernels.attention.ops import attention

__all__ = ["attention"]
