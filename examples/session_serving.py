"""Continuous batching of plastic controller sessions into a fixed slot pool.

    PYTHONPATH=src python examples/session_serving.py [--impl xla] [--slots 4]

More users than slots: sessions arrive, learn online (every pool step
rewrites each occupant's own synapses through ONE fused fleet launch per
layer), get evicted under admission pressure — their learned weights
persisted through `checkpoint.manager` — and later RESUME bit-identically
in whatever slot is free.  The pool tensor's shape never changes: occupancy
lives in the ``active (B,)`` mask, so vacant slots are frozen no-ops and
the whole run compiles a pinned handful of programs (printed at the end).

The demo closes with the headline guarantee: one user's full output
trajectory, interrupted by eviction + slot migration mid-run, is
bit-identical to the same user's uninterrupted trajectory.
"""
import argparse
import json
import tempfile
import time

import jax
import numpy as np

from repro.core import snn
from repro.serving import FleetScheduler, SessionStore


def drive_for(uid: str, t: int, n: int) -> np.ndarray:
    """Deterministic per-user observation stream (stands in for an env)."""
    phase = (hash(uid) % 97) / 97.0
    return np.sin(0.3 * t + phase + np.arange(n)).astype(np.float32)


def run_pool(cfg, theta, root, slots, users, steps, churn_every):
    store = SessionStore(root=root, capacity=2 * slots)
    sched = FleetScheduler(cfg, theta, slots=slots, store=store)
    n_in = cfg.layer_sizes[0]
    t0 = time.perf_counter()
    for t in range(steps):
        # admission pressure: rotate the next absent user in every
        # churn_every steps, evicting the least-recently-admitted occupant
        # when the pool is full — evicted users re-enter the rotation and
        # resume from their persisted synapses
        if t % churn_every == 0:
            uid = users[(t // churn_every) % len(users)]
            if uid not in sched.user_slot:
                sched.admit(uid, evict_lru=True)
        sched.step({u: drive_for(u, t, n_in) for u in sched.active_users})
    dt = time.perf_counter() - t0
    return sched, store, steps / dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--users", type=int, default=10)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--session-dir", default=None,
                    help="durable session directory (default: a tempdir)")
    args = ap.parse_args(argv)

    cfg = snn.SNNConfig(layer_sizes=(16, 32, 8), timesteps=2, impl=args.impl)
    theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
    users = [f"user{i}" for i in range(args.users)]

    with tempfile.TemporaryDirectory() as tmp:
        root = args.session_dir or tmp
        sched, store, sps = run_pool(
            cfg, theta, root, args.slots, users, args.steps, churn_every=5)
        print(json.dumps({
            "slots": args.slots, "users": args.users,
            "pool_steps_per_s": round(sps, 1),
            "evictions": sched.evictions,
            "restores": store.restores, "creates": store.creates,
            "compiled_programs": sched.compile_count(),
        }, indent=1))

        # ---- the headline guarantee: interrupted == uninterrupted --------
        n_in = cfg.layer_sizes[0]

        def trajectory(interrupt: bool):
            st = SessionStore(root=None)
            sc = FleetScheduler(cfg, theta, slots=2, store=st)
            sc.admit("probe")
            outs = []
            for t in range(20):
                if interrupt and t == 8:
                    sc.evict("probe")          # persisted mid-run...
                    sc.admit("rival")          # ...someone takes the slot
                    sc.step({"rival": drive_for("rival", 0, n_in)})
                    sc.admit("probe")          # resumes in the OTHER slot
                outs.append(sc.step(
                    {u: drive_for(u, t, n_in) for u in sc.active_users}
                )["probe"])
            return np.stack([np.asarray(o) for o in outs])

        a, b = trajectory(False), trajectory(True)
        bit_identical = bool((a == b).all())
        print(json.dumps({"evict_restore_bit_identical": bit_identical}))
        assert bit_identical, "evict->restore trajectory diverged!"


if __name__ == "__main__":
    main()
