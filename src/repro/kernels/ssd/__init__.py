from repro.kernels.ssd.ops import ssd, ssd_decode_step

__all__ = ["ssd", "ssd_decode_step"]
