"""Mixture-of-Experts FFN with capacity-bounded sort-based dispatch.

Fine-grained MoE per deepseek-moe (shared + routed experts, top-k) and
grok-1 (8 experts top-2).  Dispatch is the argsort/capacity scheme: tokens
are sorted by assigned expert, each expert processes a (E, C, D) buffer, and
outputs scatter back weighted by the router gate.  Under expert parallelism
the (E, C, D) buffer is sharded E->"model", so the token->expert resharding
lowers to the all-to-all pattern; compiled FLOPs track ACTIVE experts
(T * top_k * capacity_factor), not the dense all-experts product.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import get_mesh, shard_constraint
from repro.models.config import ModelConfig
from repro.models.layers import ParamDesc, rms_norm, swiglu


def plan(cfg: ModelConfig, stack: int = 0) -> dict:
    moe = cfg.moe
    d, f, e = cfg.d_model, moe.d_expert, moe.num_experts
    dt = cfg.dtype

    def desc(shape, spec, **kw):
        if stack:
            shape, spec = (stack, *shape), (None, *spec)
        kw.setdefault("dtype", dt)
        return ParamDesc(shape, spec, **kw)

    # expert-parallel ("model" on E) with automatic fallback to ffn-sharding
    # ("model" on F) when num_experts does not divide the model axis — see
    # sharding.logical_to_physical dedup (deepseek 64e vs grok 8e on 16-way).
    p = {
        "norm": desc((d,), (None,), init="ones"),
        "router": desc((d, e), (None, None), fan_in=d, dtype="float32"),
        "w_gate": desc((e, d, f), ("model", "data", "model"), fan_in=d),
        "w_up": desc((e, d, f), ("model", "data", "model"), fan_in=d),
        "w_down": desc((e, f, d), ("model", "model", "data"), fan_in=f),
    }
    if moe.n_shared:
        fs = moe.n_shared * moe.d_expert
        p["ws_gate"] = desc((d, fs), ("data", "model"), fan_in=d)
        p["ws_up"] = desc((d, fs), ("data", "model"), fan_in=d)
        p["ws_down"] = desc((fs, d), ("model", "data"), fan_in=fs)
    return p


def apply(params, x, cfg: ModelConfig, groups: int = 0, token_mask=None):
    """x (B,S,D) -> (B,S,D) residual-added MoE FFN.

    ``token_mask`` (B,S) bool marks VALID tokens: masked tokens are routed
    to the trash row with zero gate weight and — because their expert
    assignment is rewritten to a sentinel before the dispatch sort — they
    never consume expert capacity.  This is the continuous-batching pool's
    no-op contract: a vacant slot's garbage token must not displace an
    active stream's token from an expert buffer (capacity coupling is the
    one cross-row interaction in the whole decode path), so active-slot
    outputs are bit-invariant to neighbour churn.

    GROUPED LOCAL DISPATCH (EXPERIMENTS.md §Perf, deepseek/grok cells):
    tokens split into `groups` dispatch groups aligned with the data axis;
    the sort/scatter runs independently per group over a (G, E, C/G, D)
    buffer whose G dim shards over "data".  GSPMD keeps every
    scatter/gather SHARD-LOCAL and the only cross-device movement is the
    (G, E, ...) <-> expert-parallel reshard (the all-to-all pattern).  The
    original ungrouped global sort forced an all-gather of every token to
    every device, which made the MoE train cells ~100x collective-bound
    (baseline rows in EXPERIMENTS.md §Perf).  Capacity is enforced per
    group (standard local-dispatch semantics).
    """
    import math

    moe = cfg.moe
    b, s, d = x.shape
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    t = b * s
    e, k = moe.num_experts, moe.top_k
    if groups <= 0:
        # one dispatch group per data shard — MUST track the mesh: a fixed
        # group count that does not divide the (pod x data) axis silently
        # replicates the dispatch buffer (caught on the multi-pod sweep)
        mesh = get_mesh()
        groups = (mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
                  if mesh is not None else 1)
    g_n = max(1, math.gcd(b, groups))                # groups ride the batch dim
    tg = t // g_n                                    # tokens per group
    xt = h.reshape(g_n, tg, d)

    # ---- routing (fp32) ----------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, moe.top_k)          # (G,Tg,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    if token_mask is not None:
        # invalid tokens: expert id -> sentinel e, so the stable sort parks
        # them BEHIND every real assignment — they cannot occupy a capacity
        # position a valid token would otherwise get
        mask_g = token_mask.reshape(g_n, tg)
        expert_idx = jnp.where(mask_g[..., None], expert_idx, e)

    cap = max(int(moe.capacity_factor * tg * k / e), 1)

    def dispatch_one(xt_g, idx_g, gate_g):
        """Per-group sort-based dispatch (shard-local under vmap)."""
        flat_e = idx_g.reshape(-1)                               # (Tg*K,)
        order = jnp.argsort(flat_e)                              # stable
        sorted_e = flat_e[order]
        pos = jnp.arange(tg * k) - jnp.searchsorted(sorted_e, sorted_e,
                                                    side="left")
        keep = (pos < cap) & (sorted_e < e)
        # dropped slots write to (and read from) a trash row so they never
        # clobber a kept token's buffer slot
        dest = jnp.where(keep, sorted_e * cap + pos, e * cap)
        tok = order // k
        buf = jnp.zeros((e * cap + 1, d), xt_g.dtype)
        buf = buf.at[dest].set(xt_g[tok])
        w = jnp.where(keep, gate_g.reshape(-1)[order], 0.0)
        return buf[:e * cap].reshape(e, cap, d), dest, tok, w

    buf, dest, tok, w = jax.vmap(dispatch_one)(xt, expert_idx, gates)
    # Pin the scatter output DATA-LOCAL first (G over data, E replicated):
    # without this anchor GSPMD partitions the scatter over the model axis
    # and must all-reduce (T*k, D)-sized partials (plus a u32 index-mask
    # reduction) — the 385s-collective baseline in EXPERIMENTS.md §Perf.
    buf = shard_constraint(buf, ("data", None, None, None))

    mesh = get_mesh()
    model_ax = mesh.shape.get("model", 1) if mesh is not None else 1
    expert_parallel = e % model_ax == 0
    if expert_parallel:
        # grouped all-to-all reshard onto the expert-parallel layout
        buf = shard_constraint(buf, ("data", "model", None, None))

    # ---- expert compute (batched over G, E) ---------------------------------
    gt = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["w_gate"]))
    u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    eo = jnp.einsum("gecf,efd->gecd", gt * u, params["w_down"])
    if expert_parallel:
        eo = shard_constraint(eo, ("data", "model", None, None))
        # reshard back so the combine gather is shard-local on the data axis
        eo = shard_constraint(eo, ("data", None, None, None))
    # else (dense-TP experts, e.g. grok 8e on a 16-way axis): w_down's
    # model-axis contraction leaves eo PARTIAL-summed; the combine gather
    # and scatter-add are linear, so the partial flows through them and one
    # all-reduce fires at token granularity (G,Tg,D) — 1/(k*capacity_factor)
    # of the buf-granularity volume an eo anchor would force.

    # ---- combine back (per group, shard-local) ------------------------------
    def combine_one(eo_g, dest_g, tok_g, w_g):
        eflat = jnp.concatenate([eo_g.reshape(e * cap, d),
                                 jnp.zeros((1, d), eo_g.dtype)], 0)
        vals = eflat[dest_g]                                     # (Tg*K, D)
        out = jnp.zeros((tg, d), jnp.float32)
        return out.at[tok_g].add(vals.astype(jnp.float32) * w_g[:, None])

    out = jax.vmap(combine_one)(eo, dest, tok, w)
    out = out.reshape(b, s, d).astype(x.dtype)

    if moe.n_shared:
        out = out + swiglu(h, params["ws_gate"], params["ws_up"],
                           params["ws_down"])
    return x + shard_constraint(out, cfg.act_spec)


def aux_load_balance_loss(params, x, cfg: ModelConfig):
    """Switch-style load-balance auxiliary (mean over layers handled by caller)."""
    moe = cfg.moe
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,de->bse", h.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    top1 = jnp.argmax(probs, -1)
    frac = jnp.mean(jax.nn.one_hot(top1, moe.num_experts, dtype=jnp.float32),
                    axis=(0, 1))
    imp = probs.mean((0, 1))
    return moe.num_experts * jnp.sum(frac * imp)
