"""Device-side fleet telemetry: the per-slot health vector and its schema.

The fused dual-engine programs (ref / Pallas, float / fixed-point, per-step
/ time-fused rollout) optionally emit one extra reduced output per slot —
raw per-slot sums ``(B, 3) float32``:

    col 0   spike_sum   sum of |events| over the layer, in EVENT units
                        (spikes are 1.0; the fixed-point datapath's
                        0/``one`` events are pre-divided by ``one`` so both
                        datapaths report in the same units)
    col 1   abs_dw_sum  sum of |dw| over the (N, M) synapse block, in
                        FLOAT weight units (int8 grid steps x w_scale on
                        the quantized path)
    col 2   sat_cnt     number of postsynaptic membranes with
                        |v| >= SAT_FRACTION * v_th after the update — the
                        fixed-point clip diagnostic (a membrane parked
                        near threshold saturates the int32 grid first)

Vacant slots (``active == 0``) report exact zeros: the raw row is gated by
the same mask that bit-freezes the slot's state, so telemetry can never
leak a frozen slot's stale membrane or trace values.

`engine.layer_step` / `engine.rollout` normalize the raw sums into a
`FleetTelemetry` — per-slot MEANS that are comparable across layer widths,
window lengths, and datapaths.  Telemetry is a static trace variant: the
``telemetry=`` flag is a Python bool (part of the jit static signature),
never a traced value, so the off-path program is byte-identical to the
uninstrumented one and the on-path adds exactly one stable executable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# A membrane counts as "saturated" when |v| reaches this fraction of the
# firing threshold after the update.  0.9 flags the pile-up region where
# the fixed-point datapath's int32 membrane grid loses headroom, while
# staying below the reset discontinuity at v_th itself.
SAT_FRACTION = 0.9


def sat_threshold(v_th: float) -> float:
    """Float-datapath saturation threshold on |v|."""
    return SAT_FRACTION * float(v_th)


def sat_threshold_q(v_th: float, qcfg) -> int:
    """Fixed-point saturation threshold on the int32 membrane |v_fx|.

    Rounded once on the host so both backends compare against the same
    integer constant (mirrors how `quant.py` materializes ``vth_fx``).
    """
    return int(round(SAT_FRACTION * float(v_th) * qcfg.one))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FleetTelemetry:
    """Per-slot fleet health vector — all fields ``(B,) float32``.

    spike_rate   mean |event| per postsynaptic neuron per step (0..1 for
                 spiking layers; mean |readout| for readout layers)
    mean_abs_dw  mean |dw| per synapse per step, float weight units.  For
                 windowed rollouts this is the NET weight motion over the
                 window, |w_end - w_start| / (N*M) / (K * n_plastic) — the
                 quantity that survives the fixed-point grid (per-step dw
                 below one grid step rounds stochastically, so net motion
                 is the honest activity measure on both datapaths).
    sat_frac     fraction of postsynaptic membranes at >= SAT_FRACTION of
                 threshold after the step (fixed-point headroom monitor)
    occupancy    the slot's active flag as 0.0/1.0 (so host-side rollups
                 can mask and occupancy-weight without a second transfer)

    Vacant slots report exact zeros in every field.
    """

    spike_rate: jax.Array
    mean_abs_dw: jax.Array
    sat_frac: jax.Array
    occupancy: jax.Array

    @staticmethod
    def zeros(batch: int) -> "FleetTelemetry":
        z = jnp.zeros((batch,), jnp.float32)
        return FleetTelemetry(spike_rate=z, mean_abs_dw=z, sat_frac=z,
                              occupancy=z)


def adapter_telemetry(before: dict, after: dict, active,
                      *, qcfg=None, trace_decay: float = 0.8,
                      v_th: float = 1.0) -> FleetTelemetry:
    """`FleetTelemetry` for the LM fast-weight adapter, from cache deltas.

    The adapter's decode step is one fleet `engine.layer_step` buried
    inside the backbone's jitted decode program, so instead of threading a
    flag through every layout's forward pass we recover the same three
    signals as a pure function of the adapter cache before/after the step
    (both already live in the decode program, so this traces into the SAME
    launch — no extra transfer):

      * spikes: the postsynaptic trace update is ``tr2' = decay*tr2 + s2``
        (fixed-point: ``tr2' = tr2 - (tr2 >> trace_shift) + ev``), so the
        event vector is recovered EXACTLY as ``tr2' - decay(tr2)``.
      * |dw|: straight from the ``w_fast`` delta (x per-slot ``w_scale``
        on the int8 grid).
      * saturation: from the postsynaptic membrane ``v2``.

    Everything is gated by ``active``: a frozen slot's unchanged traces
    would otherwise "recover" a phantom event ``(1-decay)*tr2`` != 0.

    ``before``/``after`` are adapter cache dicts (`models/plastic.py`
    ``plan_cache`` schema: w_fast, v2, tr2, w_scale, ...).
    """
    act = jnp.asarray(active).astype(jnp.float32)
    n = before["tr2"].shape[-1]

    if qcfg is not None:
        tr2_b = before["tr2"]
        decayed = tr2_b - (tr2_b >> qcfg.trace_shift)
        s2 = (after["tr2"] - decayed).astype(jnp.float32) / qcfg.one
        dw_steps = (after["w_fast"].astype(jnp.int32)
                    - before["w_fast"].astype(jnp.int32))
        abs_dw = jnp.abs(dw_steps).astype(jnp.float32) * \
            before["w_scale"][:, None, None]
        sat = (jnp.abs(after["v2"]) >= sat_threshold_q(v_th, qcfg))
    else:
        s2 = after["tr2"] - trace_decay * before["tr2"]
        abs_dw = jnp.abs(after["w_fast"] - before["w_fast"])
        sat = (jnp.abs(after["v2"]) >= sat_threshold(v_th))

    spike_rate = jnp.mean(jnp.abs(s2), axis=-1).astype(jnp.float32)
    mean_abs_dw = (jnp.sum(abs_dw, axis=(-2, -1)) / (n * n)
                   ).astype(jnp.float32)
    sat_frac = jnp.mean(sat.astype(jnp.float32), axis=-1)
    return FleetTelemetry(spike_rate=spike_rate * act,
                          mean_abs_dw=mean_abs_dw * act,
                          sat_frac=sat_frac * act,
                          occupancy=act)


def record_fleet_telemetry(registry, tel: FleetTelemetry,
                           prefix: str = "fleet") -> dict:
    """Fold a device `FleetTelemetry` into host gauges (one transfer).

    Gauges are occupancy-weighted means over ACTIVE slots — vacant slots'
    mandated zeros must not dilute the fleet's health numbers:

        {prefix}_spike_rate   {prefix}_mean_abs_dw
        {prefix}_sat_frac     {prefix}_occupancy (fraction of slots active)

    Returns the scalar values as a dict for callers that also log them.
    """
    import numpy as np

    occ = np.asarray(tel.occupancy, dtype=np.float64)
    n_active = float(occ.sum())
    b = max(1, occ.shape[0])

    def active_mean(x) -> float:
        if n_active == 0:
            return 0.0
        return float(np.asarray(x, dtype=np.float64).sum() / n_active)

    vals = {
        f"{prefix}_spike_rate": active_mean(tel.spike_rate),
        f"{prefix}_mean_abs_dw": active_mean(tel.mean_abs_dw),
        f"{prefix}_sat_frac": active_mean(tel.sat_frac),
        f"{prefix}_occupancy": n_active / b,
    }
    help_text = {
        f"{prefix}_spike_rate": "mean |event|/neuron/step over active slots",
        f"{prefix}_mean_abs_dw": "mean |dw|/synapse/step over active slots",
        f"{prefix}_sat_frac": "fraction of membranes near threshold",
        f"{prefix}_occupancy": "fraction of pool slots active",
    }
    for name, v in vals.items():
        registry.gauge(name, help_text[name]).set(v)
    return vals
