"""musicgen-medium [audio] — 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048; decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

Backbone only, per the shape spec: the EnCodec frontend is a STUB —
input_specs() provides precomputed frame embeddings (input_mode=
"embeddings"), the transformer + 2048-way codebook head is fully real."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048,
    layout="dense", input_mode="embeddings",
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=6,
    d_ff=192, vocab=128,
    layout="dense", input_mode="embeddings", remat=False,
)
