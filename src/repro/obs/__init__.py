"""Observability: in-band fleet telemetry, host metrics, recompile watchdog.

Three layers, matching how FireFly-P itself is measured (the paper's 8 us /
0.713 W headline numbers come from instrumenting the RUNNING accelerator,
not from offline benchmarks):

  * `obs.telemetry`  — DEVICE-side per-slot fleet telemetry (spike rate,
    mean |dw|, membrane saturation, occupancy) computed INSIDE the fused
    dual-engine programs as extra reduced outputs.  Telemetry is a static
    trace variant (a `telemetry=` flag on `engine.layer_step` /
    `engine.rollout` and the schedulers), never a runtime branch: the
    telemetry-off program is byte-identical to the uninstrumented one and
    telemetry-on adds exactly one stable executable per entry point.
  * `obs.metrics`    — HOST-side counters/gauges/histograms with
    Prometheus-text + JSON snapshot exporters; the serving stack
    (SessionStore, SessionPool, launch/serve.py, scenarios/harness) records
    admit/evict/checkout latencies, warm-cache hit rate, occupancy, and
    tokens/s into per-component registries.
  * `obs.watchdog`   — the RECOMPILE WATCHDOG: a `jax.monitoring` compile
    listener that turns the benchmarks' "zero recompiles after warmup"
    assertion into a runtime monitor (warn + counter + offending program
    name on any unexpected cache miss while armed).

`benchmarks/obs_overhead.py` gates the cost: telemetry-on fleet stepping
within 5% of telemetry-off at B=256, exactly one extra program per used
entry point, watchdog silent under churn.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               REGISTRY, phase)
from repro.obs.telemetry import (SAT_FRACTION, FleetTelemetry,
                                 adapter_telemetry, record_fleet_telemetry)
from repro.obs.watchdog import RecompileWatchdog, watchdog

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY", "phase",
    "SAT_FRACTION", "FleetTelemetry", "adapter_telemetry",
    "record_fleet_telemetry", "RecompileWatchdog", "watchdog",
]
