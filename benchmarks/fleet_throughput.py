"""Fleet-mode throughput: native batched-weights launch vs the vmap recipe.

The many-user serving path steps B independent plastic memories — one per
request stream — every decode/control step.  Historically that was
``jax.vmap(engine.layer_step)``: vmapping a `pallas_call` stamps out B
logical kernel instances and broadcasts the shared rule theta to
``(B, 4, N, M)``.  Fleet mode instead gives the kernel first-class
per-request weights ``(B, N, M)`` and launches ONE program over a
``(cdiv(M, bm), B)`` grid with theta fetched once per tile.

This benchmark sweeps the fleet size B and times both paths on the SAME
fused dual-engine step (weights evolve under the rule across iterations,
as in production).  ``--impl pallas-interpret`` (default) validates the
TPU program on CPU; on TPU pass ``--impl pallas``.

Baseline honesty notes:

  * On the Pallas backends the vmap baseline MUST materialize theta per
    stream (``in_axes theta=0``): jax 0.4.37's pallas_call batching rule
    cannot carry an unmapped operand — ``in_axes=None`` fails to lower
    ("ValueError: Block shape for refs[...] must have the same number of
    dimensions as the array shape (B, 4, N, M)"), i.e. the historical
    recipe was never runnable on pallas/pallas-interpret at all, and the
    broadcast is what its batching rule attempts internally anyway.
  * On ``--impl xla`` the two paths are the SAME lowering by construction
    (the fleet oracle in kernels/plasticity/ref.py is defined as the vmap
    of the unbatched step), so expect parity there — the kernel-launch and
    theta-broadcast win this benchmark measures is a Pallas-path property.

The sweep also times the TIME-FUSED path (`engine.rollout`, the rollout
megakernel of kernels/plasticity/fused): K timesteps of the same layer in
ONE launch, with state resident across the window.  Per-step launches are
exactly what makes the per-step rows collapse super-linearly with B on the
interpret backend; fusing K steps and blocking ``block_b`` streams per
grid program divides that overhead by K * block_b.

The DEVICE sweep (``--device-counts``, on by default) measures the sharded
session pool: for each D in the sweep a fresh subprocess forces D host
devices (``XLA_FLAGS=--xla_force_host_platform_device_count=D`` must be set
before jax initializes, hence the subprocess), runs a meshed
`serving.scheduler.FleetScheduler` (`distributed.sharding.fleet_mesh`), and
reports the fused pool-step rate, scaling efficiency vs D=1, and the
device-loss drain latency (`fail_device` -> `drain_failed`; D=1 has no
surviving shard, so its drain cells are null).  Zero recompiles across the
timed section AND the drain are asserted in every cell.  On forced host
devices all D shards share one physical CPU, so efficiency ~1/D is
expected — the sweep pins the mechanism and the drain path, not a speedup.

    PYTHONPATH=src python benchmarks/fleet_throughput.py [--smoke] [--impl ...]

Writes benchmarks/results/fleet_throughput.json:
    {"sweep": [{"batch": B, "native_steps_per_s": ..., "vmap_steps_per_s":
    ..., "native_speedup": ..., "native_controller_steps_per_s": ...,
    "vmap_controller_steps_per_s": ..., "collapse_ratio": ...,
    "fused_steps_per_s": ..., "fused_controller_steps_per_s": ...,
    "fused_speedup_vs_per_step": ...}, ...], "fused_k": K, ...,
    "device_counts": [1, 2, 4, 8],
    "device_sweep": [{"devices": D, "slots": B, "resident": ...,
    "pool_steps_per_s": ..., "controller_steps_per_s": ...,
    "speedup_vs_1dev": ..., "scaling_efficiency": ..., "drain_ms": ...,
    "drained": ..., "steps_lost": ..., "recompiles": 0}, ...]}

``collapse_ratio`` is (B * steps/s at B) / (steps/s at B=1) — the
aggregate-throughput scaling a flat per-launch cost would hold at B; a
value far below B is the launch-overhead collapse this benchmark exposes
(and the fused rows repair).
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import engine

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# child -> parent protocol for the device sweep: the cell subprocess prints
# exactly one line with this prefix followed by the cell JSON
_CELL_MARK = "DEVICE_CELL_JSON:"


def make_fleet(b: int, n: int, m: int, key: jax.Array):
    """B request streams: per-stream weights/membranes/traces, shared rule."""
    ks = jax.random.split(key, 5)
    x = (jax.random.uniform(ks[0], (b, n)) > 0.5).astype(jnp.float32)
    state = engine.LayerState(
        w=jnp.zeros((b, n, m), jnp.float32),           # zero-start (Phase 2)
        v=0.1 * jax.random.normal(ks[1], (b, m)),
        trace_pre=jax.random.uniform(ks[2], (b, n)),
        trace_post=jax.random.uniform(ks[3], (b, m)),
        theta=0.05 * jax.random.normal(ks[4], (4, n, m)))
    return state, x


def _native_step(state, x, params, impl):
    return engine.layer_step(state, x, params=params, impl=impl)


def _vmap_step(state, x, params, impl):
    # The historical recipe.  theta is materialized per stream because the
    # pallas_call batching rule rejects unmapped operands outright in this
    # JAX version (see module docstring) — and broadcasting is what the
    # rule attempts for mapped operands anyway; that B-fold coefficient
    # traffic is exactly what fleet mode eliminates.
    b = x.shape[0]
    vstate = engine.LayerState(
        w=state.w, v=state.v, trace_pre=state.trace_pre,
        trace_post=state.trace_post,
        theta=jnp.broadcast_to(state.theta, (b, *state.theta.shape)))
    new_state, out = jax.vmap(
        lambda l, xx: engine.layer_step(l, xx, params=params, impl=impl),
        in_axes=(engine.LayerState(w=0, v=0, trace_pre=0, trace_post=0,
                                   theta=0), 0))(vstate, x)
    # Hand back the shared rule so iterations don't re-broadcast a broadcast.
    return dataclasses.replace(new_state, theta=state.theta), out


def bench_steps_per_s(step_fn, state, x, iters: int) -> float:
    """Steady-state fused-step rate; weights thread through (plasticity on)."""
    fn = jax.jit(step_fn)
    state, out = fn(state, x)                  # compile + warm-up
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        state, out = fn(state, x)
    jax.block_until_ready(out)
    return iters / (time.perf_counter() - t0)


def bench_fused_steps_per_s(layer, x, params, impl: str, k: int,
                            block_b: int, iters: int) -> float:
    """Per-TIMESTEP rate of the time-fused rollout (K steps per launch)."""
    b, n = x.shape
    m = layer.v.shape[-1]
    net = engine.NetworkState(
        w=(layer.w,), v=(layer.v,),
        trace=(layer.trace_pre, layer.trace_post),
        t=jnp.zeros((), jnp.int32))
    drives = jnp.broadcast_to(x[None], (k, b, n)).astype(jnp.float32)
    fn = jax.jit(functools.partial(
        engine.rollout, params=[params], impl=impl, block_b=block_b))
    theta = [layer.theta]
    net2, out = fn(net, theta, drives)         # compile + warm-up
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        net2, out = fn(net2, theta, drives)
    jax.block_until_ready(out)
    return iters * k / (time.perf_counter() - t0)


# ---- the sharded-pool device sweep -----------------------------------------


def _device_cell(args) -> int:
    """One device-sweep cell, run in a subprocess with D forced devices:
    meshed pool-step throughput + device-loss drain latency, with zero
    recompiles asserted across both."""
    import numpy as np

    from repro.core import snn
    from repro.distributed import sharding as dsh
    from repro.serving.scheduler import FleetScheduler

    d = int(args.devices)
    if len(jax.devices()) < d:
        raise RuntimeError(
            f"device cell needs {d} devices but jax sees "
            f"{len(jax.devices())} — the parent must set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={d} before spawn")
    slots = args.slots if args.slots else (8 if args.smoke else 16)
    cfg = snn.SNNConfig(layer_sizes=(args.n, args.m), impl=args.impl,
                        block_m=args.block_m)
    theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
    sched = FleetScheduler(cfg, theta, slots=slots, mesh=dsh.fleet_mesh(d))
    # half-occupied: drain needs free healthy slots on the survivors
    users = [f"u{i}" for i in range(slots // 2)]
    for u in users:
        sched.admit(u)
    rng = np.random.RandomState(0)
    drives = {u: rng.rand(args.n).astype(np.float32) for u in users}
    k = args.k
    # warm-up: the step program, then one churn cycle so every slot
    # program the drain reuses is compiled before the recompile gate arms
    jax.block_until_ready(sched.pool_step(dict(drives), timesteps=k))
    sched.evict(users[0])
    sched.admit(users[0])
    jax.block_until_ready(sched.pool_step(dict(drives), timesteps=k))
    warm = sched.compile_count()

    iters = 3 if args.smoke else 10
    t0 = time.perf_counter()
    for _ in range(iters):
        out = sched.pool_step(dict(drives), timesteps=k)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    cell = {"devices": d, "impl": args.impl, "slots": slots,
            "resident": len(users),
            "pool_steps_per_s": iters * k / dt,
            "controller_steps_per_s": iters * k * len(users) / dt}

    if d > 1:
        # drain latency: snapshot, kill device 0's shard (poisoned, so the
        # drain provably never reads it), re-home onto survivors
        sched.persist_resident()
        t0 = time.perf_counter()
        stranded = sched.fail_device(0, poison=True)
        report = sched.drain_failed()
        drain_s = time.perf_counter() - t0
        assert {r["uid"] for r in report} == set(stranded)
        assert all(r["to_device"] != 0 for r in report), report
        # the drained pool must still serve
        jax.block_until_ready(sched.pool_step(dict(drives), timesteps=k))
        # reconcile the metrics registry against the drain's own event log:
        # the counters are the externally scraped record of this incident,
        # so they must agree with what the benchmark just observed
        snap = sched.metrics.snapshot()
        failures = snap["pool_device_failures_total"]["value"]
        drained = snap["pool_drained_sessions_total"]["value"]
        assert failures == 1.0, snap
        assert drained == float(len(report)), (drained, len(report))
        cell.update(drain_ms=drain_s * 1e3, drained=len(report),
                    steps_lost=int(sum(r["steps_lost"] for r in report)),
                    device_failures_total=failures,
                    drained_sessions_total=drained)
    else:
        # a 1-device pool has no surviving shard to drain onto
        cell.update(drain_ms=None, drained=0, steps_lost=0)

    cell["recompiles"] = sched.compile_count() - warm
    assert cell["recompiles"] == 0, sched.compiled_programs()
    print(_CELL_MARK + json.dumps(cell))
    return 0


def _run_device_sweep(args):
    """Spawn one `--device-cell` subprocess per device count (the forced-
    host-device flag is per-process and pre-import) and aggregate scaling
    efficiency vs the first count (1 by default)."""
    counts = [int(c) for c in str(args.device_counts).split(",") if c]
    cells = []
    print("devices,pool_steps_per_s,scaling_efficiency,drain_ms,recompiles")
    for d in counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
        cmd = [sys.executable, os.path.abspath(__file__), "--device-cell",
               "--devices", str(d), "--impl", args.impl,
               "--n", str(args.n), "--m", str(args.m),
               "--block-m", str(args.block_m), "--k", str(args.k)]
        if args.slots:
            cmd += ["--slots", str(args.slots)]
        if args.smoke:
            cmd.append("--smoke")
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=1800, env=env)
        if proc.returncode != 0:
            raise RuntimeError(f"device cell D={d} failed:\n"
                               f"{proc.stdout}\n{proc.stderr}")
        lines = [ln for ln in proc.stdout.splitlines()
                 if ln.startswith(_CELL_MARK)]
        cells.append(json.loads(lines[-1][len(_CELL_MARK):]))
    base = cells[0]["pool_steps_per_s"]
    for c in cells:
        c["speedup_vs_1dev"] = c["pool_steps_per_s"] / base
        c["scaling_efficiency"] = c["speedup_vs_1dev"] / c["devices"]
        drain = ("" if c["drain_ms"] is None else f'{c["drain_ms"]:.1f}')
        print(f'{c["devices"]},{c["pool_steps_per_s"]:.2f},'
              f'{c["scaling_efficiency"]:.3f},{drain},{c["recompiles"]}')
    return counts, cells


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, not minutes)")
    ap.add_argument("--impl", default="pallas-interpret",
                    choices=["xla", "pallas", "pallas-interpret"])
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--m", type=int, default=64)
    ap.add_argument("--block-m", type=int, default=64)
    ap.add_argument("--k", type=int, default=8,
                    help="fused-rollout window length (timesteps per launch)")
    ap.add_argument("--block-b", type=int, default=8,
                    help="fused-rollout streams per grid program")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="cap the B sweep (the aggregate benchmarks/run.py "
                         "harness uses 256 to bound interpret-mode wall "
                         "time; the B=1024 point is minutes on CPU)")
    ap.add_argument("--out", default=None,
                    help="results path; defaults to results/"
                         "fleet_throughput.json, or a separate _smoke file "
                         "under --smoke so CI/quick runs never clobber the "
                         "checked-in full-sweep artifact")
    ap.add_argument("--device-counts", default="1,2,4,8",
                    help="comma-separated device counts for the sharded-"
                         "pool sweep (each runs in a subprocess with that "
                         "many forced host devices); the first count is "
                         "the scaling-efficiency baseline")
    ap.add_argument("--devices-only", action="store_true",
                    help="run ONLY the device sweep and merge it into the "
                         "--out artifact, preserving an existing B sweep "
                         "(CI regenerates device cells without re-running "
                         "the minutes-long B=1024 rows)")
    ap.add_argument("--device-cell", action="store_true",
                    help=argparse.SUPPRESS)  # internal: subprocess mode
    ap.add_argument("--devices", type=int, default=None,
                    help=argparse.SUPPRESS)  # internal: cell device count
    ap.add_argument("--slots", type=int, default=None,
                    help="device-sweep pool size (default 8 smoke / 16)")
    args = ap.parse_args(argv)
    if args.device_cell:
        return _device_cell(args)
    if args.out is None:
        capped = args.max_batch is not None and args.max_batch < 1024
        name = ("fleet_throughput_smoke.json" if args.smoke else
                "fleet_throughput_capped.json" if capped else
                "fleet_throughput.json")
        args.out = os.path.join(RESULTS, name)

    batches = [1, 16] if args.smoke else [1, 16, 64, 256, 1024]
    if args.max_batch is not None:
        batches = [b for b in batches if b <= args.max_batch]
    params = engine.EngineParams(block_m=args.block_m)
    sweep = []
    if args.devices_only:
        batches = []
    else:
        print("batch,native_steps_per_s,vmap_steps_per_s,native_speedup,"
              "fused_steps_per_s,fused_speedup_vs_per_step")
    native_b1 = None
    for b in batches:
        state, x = make_fleet(b, args.n, args.m, jax.random.PRNGKey(b))
        iters = max(2, min(30, 4096 // b)) if not args.smoke else 2
        native = bench_steps_per_s(
            functools.partial(_native_step, params=params, impl=args.impl),
            state, x, iters)
        vmapped = bench_steps_per_s(
            functools.partial(_vmap_step, params=params, impl=args.impl),
            state, x, iters)
        # time-fused path: same workload, K timesteps per launch.  Window
        # iters scale by K since each launch does K steps of work.
        fused_iters = max(2, iters // 2) if not args.smoke else 2
        fused = bench_fused_steps_per_s(state, x, params, args.impl,
                                        args.k, args.block_b, fused_iters)
        if native_b1 is None:
            native_b1 = native                 # batches always start at B=1
        row = {"batch": b, "native_steps_per_s": native,
               "vmap_steps_per_s": vmapped,
               "native_speedup": native / vmapped,
               "native_controller_steps_per_s": native * b,
               # satellite bugfix: the baseline's per-controller number and
               # the aggregate-scaling ratio were missing from the schema,
               # hiding the collapse this PR's fused path repairs
               "vmap_controller_steps_per_s": vmapped * b,
               "collapse_ratio": (native * b) / native_b1,
               "fused_k": args.k,
               "fused_steps_per_s": fused,
               "fused_controller_steps_per_s": fused * b,
               "fused_collapse_ratio": None,   # filled after the sweep
               "fused_speedup_vs_per_step": fused / native}
        sweep.append(row)
        print(f"{b},{native:.2f},{vmapped:.2f},{native / vmapped:.2f},"
              f"{fused:.2f},{fused / native:.2f}")
    if sweep:
        fused_b1 = sweep[0]["fused_steps_per_s"]
        for row in sweep:
            row["fused_collapse_ratio"] = (
                row["fused_controller_steps_per_s"] / fused_b1)

    counts, dev_cells = _run_device_sweep(args)

    payload = {"impl": args.impl, "n": args.n, "m": args.m,
               "block_m": args.block_m, "fused_k": args.k,
               "block_b": args.block_b, "smoke": bool(args.smoke),
               "sweep": sweep,
               "device_counts": counts, "device_sweep": dev_cells}
    if args.devices_only and os.path.exists(args.out):
        # refresh ONLY the device cells; keep the existing B sweep rows
        with open(args.out) as f:
            payload = json.load(f)
        payload["device_counts"] = counts
        payload["device_sweep"] = dev_cells

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
