"""Multi-device session pools: sharding, fault tolerance, elastic re-mesh.

Pins, converting the `distributed/` seed modules' contracts into gates:

  1. MESH TRANSPARENCY — a pool on a single-device mesh is bitwise
     identical to the unmeshed pool (the `sharding.py` docstring contract:
     without/with a trivial mesh the identical code runs), per backend and
     datapath, with `shard_constraint` a no-op when no mesh is active.
  2. DEVICE PARITY — on D=2/4 forced host devices the sharded pool's
     trajectories are bit-identical to D=1 (slot rows are mutually
     independent; `engine.fleet_spmd` runs the same program per shard),
     and churn after warmup stays at ZERO recompiles.
  3. DEVICE-LOSS RECOVERY — `fail_device`/`fail_slots` poison a shard,
     `drain_failed` re-homes its sessions onto surviving devices from
     `SessionStore` checkpoints, and every drained session's subsequent
     trajectory is bit-identical to an uninterrupted control pool (the
     evict -> re-admit invariant extended across devices).  Poisoned rows
     never leak into survivors' math.
  4. ELASTIC RE-MESH — `save_pool` at D devices + `load_pool` at D'
     (including unmeshed) resumes occupancy, step counters, and bits.
  5. SESSION HEALTH UNDER MESH — the ``record=`` trace variants, the
     flight-recorder state, and quarantine -> rollback remediation are
     bit-identical between meshed and unmeshed pools, and churn through
     the record variants (elastic re-mesh restores included) stays silent
     under the armed recompile watchdog.

The D>1 cells need forced host devices and run under the `multidevice-
smoke` CI lane (``XLA_FLAGS=--xla_force_host_platform_device_count=4``);
in a single-device session they skip.  One subprocess test forces 4
devices from inside tier-1 so the sharded path never goes ungated.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import snn
from repro.distributed import sharding as dsh
from repro.obs.health import HealthConfig
from repro.serving import SessionStore
from repro.serving.scheduler import SHARED, FleetScheduler

IMPLS = ["xla", "pallas-interpret"]
DATAPATHS = ["float32", "int8"]
CELLS = [(i, d) for i in IMPLS for d in DATAPATHS]

N_DEV = len(jax.devices())
multidevice = pytest.mark.skipif(
    N_DEV < 4, reason="needs XLA_FLAGS=--xla_force_host_platform_"
                      "device_count=4 (the multidevice-smoke CI lane)")


def _cfg(impl, datapath):
    cfg = snn.SNNConfig(layer_sizes=(8, 16, 4), impl=impl, block_m=16)
    if datapath == "int8":
        cfg = snn.quant_config(cfg, impl=impl, block_m=16)
    return cfg


def _drive(uid, t, n=8):
    phase = (hash(uid) % 97) / 97
    return np.sin(0.3 * t + phase + np.arange(n)).astype(np.float32)


def _sched(impl, datapath, slots=4, mesh=None, store=None, health=None):
    cfg = _cfg(impl, datapath)
    theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
    return FleetScheduler(cfg, theta, slots=slots, mesh=mesh, store=store,
                          health=health)


# recording enabled, every detector disabled: the mesh-parity tests want
# the flight recorder running without any verdict-driven divergence
HEALTH_OFF = HealthConfig(z_threshold=1e9, bounds=((-1e9, 1e9),) * 4,
                          dead_floor=-1.0, hysteresis=(9999,) * 4)


def _assert_outputs_equal(a, b):
    assert a.keys() == b.keys()
    for u in a:
        np.testing.assert_array_equal(np.asarray(a[u]), np.asarray(b[u]))


def _assert_pools_equal(a, b):
    for x, y in zip(jax.tree.leaves(a.pool), jax.tree.leaves(b.pool)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _lm_model():
    """A dense smoke LM with a float32 plastic adapter (the
    tests/test_serving_lm.py idiom; mesh parity needs just one cell — the
    sharded-jit wrapper is datapath-blind)."""
    from repro.models import factory
    cfg = factory.build("qwen3-4b", smoke=True).cfg.with_(
        plastic_adapter=True, adapter_neurons=8, adapter_impl="xla",
        adapter_quant=False)
    model = factory.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params["adapter"]["scale"] = jnp.float32(0.5)
    return model, params


class TestShardingHelpers:
    def test_fleet_mesh_shape_and_axis(self):
        mesh = dsh.fleet_mesh(1)
        assert mesh.axis_names == ("data",)
        assert mesh.shape["data"] == 1
        assert dsh.fleet_mesh().shape["data"] == N_DEV

    def test_fleet_mesh_rejects_bad_counts(self):
        with pytest.raises(ValueError):
            dsh.fleet_mesh(0)
        with pytest.raises(ValueError):
            dsh.fleet_mesh(N_DEV + 1)

    def test_slot_pspec(self):
        assert dsh.slot_pspec(0) == P("data")
        assert dsh.slot_pspec(2) == P(None, None, "data")
        assert dsh.slot_pspec(SHARED) == P()
        assert dsh.slot_pspec(None) == P()
        # bool is an int subclass but never a slot axis
        assert dsh.slot_pspec(True) == P()

    def test_pool_shardings_follow_axes_pytree(self):
        mesh = dsh.fleet_mesh(1)
        axes = {"w": (0, 0), "cache": 2, "clock": SHARED}
        sh = dsh.pool_shardings(mesh, axes)
        assert sh["w"][0].spec == P("data")
        assert sh["cache"].spec == P(None, None, "data")
        assert sh["clock"].spec == P()
        assert all(s.mesh.shape["data"] == 1
                   for s in jax.tree.leaves(sh))

    def test_shard_constraint_noop_without_mesh(self):
        """The sharding.py docstring contract, previously unpinned: with no
        active mesh every constraint is an identity pass-through, so unit
        tests run the identical code on one device."""
        assert dsh.get_mesh() is None
        x = jnp.arange(8.0)
        assert dsh.shard_constraint(x, ("data",)) is x

    def test_pool_mesh_validation(self):
        from jax.sharding import Mesh
        with pytest.raises(ValueError, match="data"):
            _sched("xla", "float32",
                   mesh=Mesh(np.array(jax.devices()[:1]), ("model",)))
        if N_DEV >= 4:
            with pytest.raises(ValueError, match="divide"):
                _sched("xla", "float32", slots=6, mesh=dsh.fleet_mesh(4))


class TestSingleDeviceMesh:
    """A trivial (D=1) mesh must not change a single bit anywhere."""

    @pytest.mark.parametrize("impl,datapath", CELLS)
    def test_bitwise_vs_unmeshed(self, impl, datapath):
        ref = _sched(impl, datapath)
        m = _sched(impl, datapath, mesh=dsh.fleet_mesh(1))
        for s in (ref, m):
            for u in ("a", "b", "c"):
                s.admit(u)
        for t in range(3):
            d = {u: _drive(u, t) for u in ("a", "b", "c")}
            _assert_outputs_equal(ref.step(dict(d)), m.step(dict(d)))
        d = {u: _drive(u, 9) for u in ("a", "b", "c")}
        _assert_outputs_equal(ref.pool_step(dict(d), timesteps=3),
                              m.pool_step(dict(d), timesteps=3))
        # churn parity: evict -> re-admit into the meshed pool round-trips
        for s in (ref, m):
            s.evict("b")
            s.admit("b")
        d = {u: _drive(u, 20) for u in ("a", "b", "c")}
        _assert_outputs_equal(ref.step(dict(d)), m.step(dict(d)))
        _assert_pools_equal(ref, m)

    def test_telemetry_variant_parity(self):
        ref = _sched("xla", "float32")
        m = _sched("xla", "float32", mesh=dsh.fleet_mesh(1))
        for s in (ref, m):
            s.admit("a")
            s.admit("b")
        d = {u: _drive(u, 0) for u in ("a", "b")}
        o1, t1 = ref.step(dict(d), telemetry=True)
        o2, t2 = m.step(dict(d), telemetry=True)
        _assert_outputs_equal(o1, o2)
        for x, y in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_record_variant_parity(self):
        """The record= trace variant on a single-device mesh: outputs,
        pool state, the whole flight-recorder pytree, and the latched
        verdict are bitwise identical to the unmeshed recording pool."""
        ref = _sched("xla", "float32", health=HEALTH_OFF)
        m = _sched("xla", "float32", mesh=dsh.fleet_mesh(1),
                   health=HEALTH_OFF)
        users = ("a", "b", "c")
        for s in (ref, m):
            for u in users:
                s.admit(u)
        for t in range(3):
            d = {u: _drive(u, t) for u in users}
            _assert_outputs_equal(ref.step(dict(d), record=True),
                                  m.step(dict(d), record=True))
        d = {u: _drive(u, 9) for u in users}
        _assert_outputs_equal(
            ref.pool_step(dict(d), timesteps=3, record=True),
            m.pool_step(dict(d), timesteps=3, record=True))
        _assert_pools_equal(ref, m)
        for x, y in zip(jax.tree.leaves(ref._rec), jax.tree.leaves(m._rec)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        np.testing.assert_array_equal(np.asarray(ref.last_verdict),
                                      np.asarray(m.last_verdict))


class TestFailureDrain:
    """Slot-level failure injection + drain (mesh-free machinery: the same
    path the device-level tests drive at D=4)."""

    @pytest.mark.parametrize("impl,datapath",
                             [("xla", "float32"), ("xla", "int8"),
                              ("pallas-interpret", "int8")])
    def test_drain_bit_identity_vs_uninterrupted(self, impl, datapath):
        ctrl = _sched(impl, datapath)
        vict = _sched(impl, datapath)
        for s in (ctrl, vict):
            s.admit("a")
            s.admit("b")
        for t in range(3):
            d = {u: _drive(u, t) for u in ("a", "b")}
            _assert_outputs_equal(ctrl.step(dict(d)), vict.step(dict(d)))
        vict.persist_resident()
        stranded = vict.fail_slots([0], poison=True)
        assert stranded == ["a"]
        assert vict.stranded_sessions() == ["a"]
        rep = vict.drain_failed()
        assert [r["uid"] for r in rep] == ["a"]
        assert rep[0]["from_slot"] == 0 and rep[0]["to_slot"] != 0
        assert rep[0]["steps_lost"] == 0
        for t in range(3, 6):
            d = {u: _drive(u, t) for u in ("a", "b")}
            _assert_outputs_equal(ctrl.step(dict(d)), vict.step(dict(d)))

    def test_poison_isolated_from_survivors(self):
        """While a failed slot is stranded (before drain), the survivors'
        math must not see its NaN rows: the active mask freezes and
        isolates it exactly like a vacant slot."""
        ctrl = _sched("xla", "float32")
        vict = _sched("xla", "float32")
        for s in (ctrl, vict):
            s.admit("a")
            s.admit("b")
        vict.fail_slots([vict.user_slot["a"]], poison=True)
        d = {u: _drive(u, 0) for u in ("a", "b")}
        ov = vict.step(dict(d))
        oc = ctrl.step({"b": d["b"], "a": d["a"]})
        np.testing.assert_array_equal(np.asarray(ov["b"]),
                                      np.asarray(oc["b"]))
        # the stranded session's output is masked to zeros, not NaN
        assert np.all(np.asarray(ov["a"]) == 0)

    def test_lost_slot_never_admits_and_refuses_evict(self):
        s = _sched("xla", "float32", slots=2)
        s.admit("a")
        s.fail_slots([s.user_slot["a"]], poison=True)
        with pytest.raises(RuntimeError, match="drain_failed"):
            s.evict("a")
        s.admit("b")                       # lands in the surviving slot
        assert s.user_slot["b"] != s.user_slot["a"]
        assert s.free_slots == 0           # lost slot is not free
        with pytest.raises(RuntimeError, match="full"):
            s.admit("c")
        # LRU eviction must never pick the lost slot either
        s2 = _sched("xla", "float32", slots=2)
        s2.admit("x")
        s2.admit("y")
        s2.fail_slots([s2.user_slot["x"]], poison=True)
        s2.admit("z", evict_lru=True)      # evicts y, never lost x
        assert "x" in s2.user_slot and "y" not in s2.user_slot

    def test_steps_lost_reporting(self):
        """Steps taken after the last durable snapshot are the blast
        radius of a failure, and the drain report says exactly how many."""
        s = _sched("xla", "float32")
        s.admit("a")
        for t in range(3):
            s.step({"a": _drive("a", t)})
        s.persist_resident()
        for t in range(3, 7):              # 4 steps past the snapshot
            s.step({"a": _drive("a", t)})
        s.fail_slots([s.user_slot["a"]])
        rep = s.drain_failed()
        assert rep[0]["steps_lost"] == 4
        assert int(s._steps[s.user_slot["a"]]) == 3   # resumed at snapshot

    def test_fresh_session_drains_to_zero_state(self):
        """A never-persisted session has no checkpoint: drain restarts it
        from the factory state and reports every step lost."""
        s = _sched("xla", "float32")
        s.admit("a")
        for t in range(2):
            s.step({"a": _drive("a", t)})
        s.fail_slots([s.user_slot["a"]])
        rep = s.drain_failed()
        assert rep[0]["steps_lost"] == 2
        fresh = _sched("xla", "float32")
        fresh.admit("a")
        o1 = s.step({"a": _drive("a", 0)})
        o2 = fresh.step({"a": _drive("a", 0)})
        _assert_outputs_equal(o1, o2)


class TestPoolCheckpoint:
    def test_save_load_round_trip(self, tmp_path):
        s = _sched("xla", "int8")
        s.admit("a")
        s.admit("b")
        for t in range(3):
            s.step({u: _drive(u, t) for u in ("a", "b")})
        s.evict("b")
        s.save_pool(str(tmp_path))
        fresh = _sched("xla", "int8")
        fresh.load_pool(str(tmp_path))
        assert fresh.slot_user == s.slot_user
        assert fresh.user_slot == s.user_slot
        np.testing.assert_array_equal(fresh._steps, s._steps)
        _assert_pools_equal(fresh, s)
        o1 = s.step({"a": _drive("a", 9)})
        o2 = fresh.step({"a": _drive("a", 9)})
        _assert_outputs_equal(o1, o2)

    def test_save_refuses_stranded_sessions(self, tmp_path):
        s = _sched("xla", "float32")
        s.admit("a")
        s.fail_slots([s.user_slot["a"]])
        with pytest.raises(RuntimeError, match="drain"):
            s.save_pool(str(tmp_path))
        s.drain_failed()
        s.save_pool(str(tmp_path))         # drained pool checkpoints fine

    def test_load_rejects_slot_count_mismatch(self, tmp_path):
        s = _sched("xla", "float32", slots=4)
        s.save_pool(str(tmp_path))
        other = _sched("xla", "float32", slots=2)
        # the manager's leaf-shape validation fires first (slot rows are
        # leading dims); the pool's own slots gate backstops sharded loads
        with pytest.raises(ValueError, match="slots|shape mismatch"):
            other.load_pool(str(tmp_path))


class TestLMSingleDeviceMesh:
    def test_token_parity(self):
        from repro.serving import LMScheduler
        model, params = _lm_model()
        rng = np.random.RandomState(7)
        prompts = {u: rng.randint(0, model.cfg.vocab,
                                  size=5).astype(np.int32)
                   for u in ("u", "v")}
        ref = LMScheduler(model, params, slots=2, max_len=16)
        m = LMScheduler(model, params, slots=2, max_len=16,
                        mesh=dsh.fleet_mesh(1))
        for s in (ref, m):
            for u, p in prompts.items():
                s.admit_prompt(u, p)
        assert {u: ref.pending(u) for u in prompts} == \
               {u: m.pending(u) for u in prompts}
        for _ in range(5):
            assert ref.step() == m.step()
        w = {u: np.asarray([ref.pending(u), 3, 5], np.int32)
             for u in prompts}
        la, lb = ref.decode_window(dict(w)), m.decode_window(dict(w))
        for u in la:
            np.testing.assert_array_equal(
                np.argmax(np.asarray(la[u]), -1),
                np.argmax(np.asarray(lb[u]), -1))


@multidevice
class TestMultiDevice:
    """The D=2/4 cells (the multidevice-smoke CI lane)."""

    @pytest.mark.parametrize("impl,datapath",
                             [("xla", "float32"), ("xla", "int8"),
                              ("pallas-interpret", "float32")])
    @pytest.mark.parametrize("d", [2, 4])
    def test_pool_parity_vs_single_device(self, impl, datapath, d):
        users = [f"u{i}" for i in range(6)]
        ref = _sched(impl, datapath, slots=8)
        m = _sched(impl, datapath, slots=8, mesh=dsh.fleet_mesh(d))
        for s in (ref, m):
            for u in users:
                s.admit(u)
        for t in range(2):
            dd = {u: _drive(u, t) for u in users}
            _assert_outputs_equal(ref.step(dict(dd)), m.step(dict(dd)))
        dd = {u: _drive(u, 5) for u in users}
        _assert_outputs_equal(ref.pool_step(dict(dd), timesteps=3),
                              m.pool_step(dict(dd), timesteps=3))
        _assert_pools_equal(ref, m)

    def test_zero_recompiles_under_churn(self):
        m = _sched("xla", "float32", slots=8, mesh=dsh.fleet_mesh(4))
        users = [f"u{i}" for i in range(6)]
        for u in users:
            m.admit(u)
        m.step({u: _drive(u, 0) for u in users})
        m.pool_step({u: _drive(u, 1) for u in users}, timesteps=3)
        m.evict("u0")
        m.admit("u0")
        warm = m.compile_count()
        for t in range(5):
            m.evict("u0")
            m.admit("u0")
            m.evict("u3")
            m.admit(f"g{t}")
            m.step({u: _drive(u, t) for u in m.active_users})
            m.pool_step({u: _drive(u, 50 + t) for u in m.active_users},
                        timesteps=3)
            m.evict(f"g{t}")
            m.admit("u3")
        assert m.compile_count() == warm, m.compiled_programs()

    @pytest.mark.parametrize("impl,datapath", CELLS)
    def test_device_drain_bit_identity(self, impl, datapath):
        """Kill device 0's shard; its sessions drain onto surviving
        devices and every subsequent trajectory is bit-identical to an
        uninterrupted single-device control — both backends, float32 and
        int8 (the PR's acceptance gate)."""
        users = [f"u{i}" for i in range(6)]
        ctrl = _sched(impl, datapath, slots=8)
        m = _sched(impl, datapath, slots=8, mesh=dsh.fleet_mesh(4))
        for s in (ctrl, m):
            for u in users:
                s.admit(u)
        for t in range(2):
            d = {u: _drive(u, t) for u in users}
            _assert_outputs_equal(ctrl.step(dict(d)), m.step(dict(d)))
        warm = m.compile_count()
        m.persist_resident()
        stranded = m.fail_device(0, poison=True)
        assert stranded                     # device 0 held slots 0-1
        rep = m.drain_failed()
        assert {r["uid"] for r in rep} == set(stranded)
        assert all(r["from_device"] == 0 and r["to_device"] != 0
                   for r in rep)
        assert all(r["steps_lost"] == 0 for r in rep)
        for t in range(2, 5):
            d = {u: _drive(u, t) for u in users}
            _assert_outputs_equal(ctrl.step(dict(d)), m.step(dict(d)))
        assert m.compile_count() == warm    # drain reuses warm programs

    def test_elastic_restore_across_device_counts(self, tmp_path):
        """A pool checkpointed at D=4 resumes at D'=2 and unmeshed with
        identical occupancy and bits (`ft.elastic_restore` under
        `load_pool`: leaves are stored unsharded, restore is a pure
        device_put onto the new NamedShardings)."""
        users = [f"u{i}" for i in range(6)]
        src = _sched("xla", "int8", slots=8, mesh=dsh.fleet_mesh(4))
        for u in users:
            src.admit(u)
        for t in range(3):
            src.step({u: _drive(u, t) for u in users})
        src.save_pool(str(tmp_path))
        for mesh in (dsh.fleet_mesh(2), None):
            tgt = _sched("xla", "int8", slots=8, mesh=mesh)
            tgt.load_pool(str(tmp_path))
            assert tgt.slot_user == src.slot_user
            np.testing.assert_array_equal(tgt._steps, src._steps)
            d = {u: _drive(u, 9) for u in users}
            _assert_outputs_equal(src.pool_step(dict(d), timesteps=2),
                                  tgt.pool_step(dict(d), timesteps=2))
            src.load_pool(str(tmp_path))   # rewind the source for the
            #                                next target's comparison

    def test_lm_pool_parity_d2(self):
        from repro.serving import LMScheduler
        model, params = _lm_model()
        rng = np.random.RandomState(11)
        prompts = {u: rng.randint(0, model.cfg.vocab,
                                  size=5).astype(np.int32)
                   for u in ("u", "v", "w")}
        ref = LMScheduler(model, params, slots=4, max_len=16)
        m = LMScheduler(model, params, slots=4, max_len=16,
                        mesh=dsh.fleet_mesh(2))
        for s in (ref, m):
            for u, p in prompts.items():
                s.admit_prompt(u, p)
        for _ in range(5):
            assert ref.step() == m.step()

    @pytest.mark.parametrize("impl,datapath",
                             [("xla", "float32"), ("xla", "int8")])
    def test_meshed_record_parity_and_rollback(self, impl, datapath):
        """Recording, quarantine, and rollback on a D=4 pool are bitwise
        identical to the unmeshed pool: the recorder state shards over the
        slot axis, the quarantine freeze is the same runtime mask, and the
        rolled-back session resumes the same checkpoint bits."""
        users = [f"u{i}" for i in range(6)]
        ref = _sched(impl, datapath, slots=8, health=HEALTH_OFF)
        m = _sched(impl, datapath, slots=8, mesh=dsh.fleet_mesh(4),
                   health=HEALTH_OFF)
        for s in (ref, m):
            for u in users:
                s.admit(u)
        for t in range(3):
            d = {u: _drive(u, t) for u in users}
            _assert_outputs_equal(ref.step(dict(d), record=True),
                                  m.step(dict(d), record=True))
        for x, y in zip(jax.tree.leaves(ref._rec), jax.tree.leaves(m._rec)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        for s in (ref, m):
            assert s.health_checkpoint() == len(users)
            s.quarantine("u2")
        for t in range(3, 5):       # u2 frozen on both pools
            d = {u: _drive(u, t) for u in users}
            _assert_outputs_equal(ref.step(dict(d), record=True),
                                  m.step(dict(d), record=True))
        ra, rb = ref.rollback("u2"), m.rollback("u2")
        assert ra["steps_lost"] == rb["steps_lost"] == 2
        for t in range(5, 8):
            d = {u: _drive(u, t) for u in users}
            _assert_outputs_equal(ref.step(dict(d), record=True),
                                  m.step(dict(d), record=True))
        _assert_pools_equal(ref, m)

    def test_record_churn_and_remesh_watchdog_silent(self, tmp_path):
        """Armed-watchdog gate over the meshed health path: session churn
        through the record variants AND an elastic re-mesh restore into an
        already-warmed pool compile nothing."""
        from repro.obs.watchdog import watchdog as watch
        users = [f"u{i}" for i in range(6)]
        m = _sched("xla", "float32", slots=8, mesh=dsh.fleet_mesh(4),
                   health=HEALTH_OFF)
        for u in users:
            m.admit(u)
        m.step({u: _drive(u, 0) for u in users}, record=True)
        m.pool_step({u: _drive(u, 1) for u in users}, timesteps=3,
                    record=True)
        m.evict("u0")               # warms recorder_reset under the mesh
        m.admit("u0")
        m.save_pool(str(tmp_path))
        tgt = _sched("xla", "float32", slots=8, mesh=dsh.fleet_mesh(2),
                     health=HEALTH_OFF)
        tgt.load_pool(str(tmp_path))
        tgt.step({u: _drive(u, 2) for u in tgt.active_users}, record=True)
        tgt.evict("u0")
        tgt.admit("u0")
        warm_m, warm_t = m.compile_count(), tgt.compile_count()
        watch.install()
        watch.reset()
        with watch.armed():
            for t in range(3):
                m.evict("u1")
                m.admit(f"g{t}")
                m.step({u: _drive(u, t) for u in m.active_users},
                       record=True)
                m.pool_step({u: _drive(u, 50 + t) for u in m.active_users},
                            timesteps=3, record=True)
                m.evict(f"g{t}")
                m.admit("u1")
            # elastic re-mesh restore into the warmed D=2 pool (load_pool
            # rebuilds the recorder lazily; same shapes, same shardings)
            tgt.load_pool(str(tmp_path))
            tgt.step({u: _drive(u, 9) for u in tgt.active_users},
                     record=True)
        assert watch.violations == 0, watch.violation_signatures
        assert m.compile_count() == warm_m, m.compiled_programs()
        assert tgt.compile_count() == warm_t, tgt.compiled_programs()

    def test_drained_session_survives_durable_store(self, tmp_path):
        """Drain from an on-disk SessionStore (not just the RAM archive):
        the recovery path CI exercises is the deployment path."""
        store_a = SessionStore(root=str(tmp_path / "a"))
        store_b = SessionStore(root=str(tmp_path / "b"))
        ctrl = _sched("xla", "float32", slots=8, store=store_a)
        m = _sched("xla", "float32", slots=8, mesh=dsh.fleet_mesh(4),
                   store=store_b)
        for s in (ctrl, m):
            for u in ("a", "b", "c"):
                s.admit(u)
        for t in range(2):
            d = {u: _drive(u, t) for u in ("a", "b", "c")}
            _assert_outputs_equal(ctrl.step(dict(d)), m.step(dict(d)))
        m.persist_resident()
        m.fail_device(0, poison=True)
        m.drain_failed()
        for t in range(2, 4):
            d = {u: _drive(u, t) for u in ("a", "b", "c")}
            _assert_outputs_equal(ctrl.step(dict(d)), m.step(dict(d)))


class TestForcedMultiDeviceSubprocess:
    """Tier-1's view of the multi-device path: force 4 host devices in a
    subprocess (the flag must be set before jax initializes, so it cannot
    run in-process) and assert the core sharding contracts end to end."""

    def test_sharded_pool_parity_drain_and_elastic(self):
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = (
                "--xla_force_host_platform_device_count=4")
            import tempfile
            import jax
            import numpy as np
            assert len(jax.devices()) == 4, jax.devices()
            from repro.core import snn
            from repro.distributed import sharding as dsh
            from repro.serving.scheduler import FleetScheduler

            cfg = snn.SNNConfig(layer_sizes=(8, 16, 4), impl="xla")
            theta = snn.init_theta(cfg, jax.random.PRNGKey(0))

            def drive(uid, t, n=8):
                ph = (hash(uid) % 97) / 97
                return np.sin(0.3 * t + ph + np.arange(n)).astype(
                    np.float32)

            users = ["u%d" % i for i in range(6)]
            ref = FleetScheduler(cfg, theta, slots=8)
            m = FleetScheduler(cfg, theta, slots=8,
                               mesh=dsh.fleet_mesh(4))
            for s in (ref, m):
                for u in users:
                    s.admit(u)
            for t in range(2):
                d = {u: drive(u, t) for u in users}
                o1, o2 = ref.step(dict(d)), m.step(dict(d))
                for u in users:
                    np.testing.assert_array_equal(
                        np.asarray(o1[u]), np.asarray(o2[u]))
            warm = m.compile_count()
            m.persist_resident()
            stranded = m.fail_device(0, poison=True)
            rep = m.drain_failed()
            assert {r["uid"] for r in rep} == set(stranded)
            assert all(r["to_device"] != 0 for r in rep), rep
            for t in range(2, 5):
                d = {u: drive(u, t) for u in users}
                o1, o2 = ref.step(dict(d)), m.step(dict(d))
                for u in users:
                    np.testing.assert_array_equal(
                        np.asarray(o1[u]), np.asarray(o2[u]))
            assert m.compile_count() == warm
            with tempfile.TemporaryDirectory() as td:
                m.save_pool(td)
                tgt = FleetScheduler(cfg, theta, slots=8,
                                     mesh=dsh.fleet_mesh(2))
                tgt.load_pool(td)
                d = {u: drive(u, 9) for u in users}
                o1, o2 = m.pool_step(dict(d), timesteps=2), \\
                    tgt.pool_step(dict(d), timesteps=2)
                for u in users:
                    np.testing.assert_array_equal(
                        np.asarray(o1[u]), np.asarray(o2[u]))
            print("multidevice-ok")
        """)
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)         # the child sets its own
        proc = subprocess.run([sys.executable, "-c", code],
                              capture_output=True, text=True, timeout=600,
                              env=env)
        assert proc.returncode == 0, proc.stderr
        assert "multidevice-ok" in proc.stdout
