"""Data pipeline determinism/shard-coherence + logical-sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.data import (TokenPipelineConfig, batch_at_step, mnist_batch,
                        render_digit, spike_encode)
from repro.distributed import sharding as shd


class TestTokens:
    CFG = TokenPipelineConfig(vocab=512, seq_len=32, global_batch=8, seed=1)

    def test_deterministic(self):
        a = batch_at_step(self.CFG, 17)
        b = batch_at_step(self.CFG, 17)
        np.testing.assert_array_equal(np.asarray(a["inputs"]),
                                      np.asarray(b["inputs"]))

    def test_steps_differ(self):
        a = batch_at_step(self.CFG, 1)["inputs"]
        b = batch_at_step(self.CFG, 2)["inputs"]
        assert bool((np.asarray(a) != np.asarray(b)).any())

    def test_labels_are_shifted_inputs(self):
        b = batch_at_step(self.CFG, 0)
        np.testing.assert_array_equal(np.asarray(b["inputs"][:, 1:]),
                                      np.asarray(b["labels"][:, :-1]))

    def test_shards_partition_batch(self):
        s0 = batch_at_step(self.CFG, 5, shard=(0, 2))["inputs"]
        s1 = batch_at_step(self.CFG, 5, shard=(1, 2))["inputs"]
        assert s0.shape == (4, 32) and s1.shape == (4, 32)
        assert bool((np.asarray(s0) != np.asarray(s1)).any())

    @given(st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_tokens_in_vocab(self, step):
        b = batch_at_step(self.CFG, step)
        assert int(b["inputs"].min()) >= 0
        assert int(b["inputs"].max()) < self.CFG.vocab


class TestMnist:
    def test_batch_shapes(self):
        imgs, labels = mnist_batch(jax.random.PRNGKey(0), 8)
        assert imgs.shape == (8, 28, 28) and labels.shape == (8,)
        assert float(imgs.min()) >= 0.0 and float(imgs.max()) <= 1.0

    def test_digits_distinguishable(self):
        """Same jitter, different digits => visibly different images."""
        k = jax.random.PRNGKey(3)
        imgs = [render_digit(k, jnp.asarray(d)) for d in (0, 1, 8)]
        d01 = float(jnp.abs(imgs[0] - imgs[1]).mean())
        assert d01 > 0.01

    def test_spike_encode_rate_tracks_intensity(self):
        img = jnp.concatenate([jnp.zeros(392), jnp.ones(392)]).reshape(28, 28)
        sp = spike_encode(jax.random.PRNGKey(0), img, 64, max_rate=0.8)
        lo, hi = sp[:, :392].mean(), sp[:, 392:].mean()
        assert float(lo) < 0.05 and 0.6 < float(hi) < 0.95


class TestShardingRules:
    @pytest.fixture
    def mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_divisible_axes_kept(self, mesh):
        spec = shd.logical_to_physical(mesh, ("data", "model"), (4, 8))
        assert spec == P("data", "model")

    def test_non_dividing_axis_dropped(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # fake a bigger mesh via shape checks: use actual 1-sized mesh, all divides
        spec = shd.logical_to_physical(mesh, ("data", "model"), (3, 5))
        assert spec == P("data", "model")  # 1 divides everything

    def test_dedup_first_claimant_wins(self, mesh):
        spec = shd.logical_to_physical(mesh, ("model", "data", "model"),
                                       (4, 4, 4))
        assert spec == P("model", "data", None)

    def test_combined_axes(self, mesh):
        spec = shd.logical_to_physical(mesh, (("data", "model"), None), (8, 2))
        assert spec == P(("data", "model"), None)

    def test_no_mesh_constraint_is_noop(self):
        shd.set_mesh(None)
        x = jnp.ones((4, 4))
        y = shd.shard_constraint(x, ("data", None))
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestShardingDivisibility:
    """Divisibility fallback against a simulated 16-way axis (pure logic,
    no devices needed — exercised through _axis_size arithmetic)."""

    def test_axis_size_math(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        assert shd._axis_size(mesh, "model") == 1
        assert shd._axis_size(mesh, ("data", "model")) == 1
        assert shd._axis_size(mesh, None) == 1
