"""Jit'd public wrapper for the fused dual-engine step.

`impl` selects the backend: "xla" (the ref oracle — what dry-runs and CPU
benchmarks lower), "pallas" (TPU target), or "pallas-interpret" (the Pallas
kernel body executed by the interpreter for CPU validation; equivalent to
``impl="pallas", interpret=True``).

Weight rank selects the mode: ``w.ndim == 2`` is the shared-weight step
(batch-averaged dw); ``w.ndim == 3`` is FLEET mode — per-request weights
``(B, N, M)`` with per-sample dw, one fused launch over all streams.

Network-level code should not call this directly — `core.engine.layer_step`
is the product entry point and adds LayerState plumbing and unbatched-state
support.  This wrapper is the kernel-level API used by kernel tests and
one-off comparisons.

Fixed-point mode (``quant=QuantConfig(...)``)
---------------------------------------------

FireFly-P's headline numbers (8 us latency, 0.713 W, ~10K LUTs) come from a
fixed-point datapath; passing a `quant.QuantConfig` runs that datapath
instead of float32.  The scheme, end to end:

  * **Weights** are int8 ``w_q`` with a per-tile fp32 scale ``w_scale``
    (one scale per (N, M) weight matrix; in fleet mode one PER SLOT,
    shape ``(B,)``): real weight = ``w_q * w_scale``.  The default scale is
    the power of two ``2**-w_frac_bits`` (1/32), so the int8 grid spans the
    paper's clip range (+-127/32 ~= +-3.97 for w_clip = 4) and dequant is a
    shift on hardware.  The ``(B, N, M)`` fleet pool stays int8 in HBM —
    ~4x more resident sessions per byte — and is promoted to int32 IN
    REGISTERS inside the kernel (dequant-in-registers).
  * **Membrane and traces** are int32 fixed point with ``frac_bits``
    fractional bits; the inter-layer event bus is the same format (a spike
    is ``2**frac_bits``).  Neuron dynamics are integer and multiplier-free:
    ``v += (I - v) >> tau_shift`` (the paper's tau_m = 2), hard reset,
    trace decay ``tp -= tp >> trace_shift`` (power-of-two decay
    ``1 - 2**-trace_shift``).  Non-spiking readout layers emit the
    saturating-linear event ``clip(v, -1, 1)`` (the piecewise-linear tanh
    an FPGA ships).
  * **Where dequant happens**: exactly twice per layer step, both
    elementwise-in-registers — the psum accumulator ``x_fx @ w_q`` (an
    EXACT integer matmul) is scaled by ``w_scale`` into membrane fixed
    point, and the plasticity engine's dw (computed in f32 from exact
    integer trace reductions) is divided by ``w_scale`` into int8 grid
    units.  Weights themselves are never materialized in float.
  * **Rounding**: dw -> integer grid steps uses a DETERMINISTIC stochastic
    round — the uniform comes from an avalanche hash of (session step
    counter ``seed``, flat weight index), never from the fleet slot — so
    sub-grid updates accumulate unbiasedly while the whole path stays
    bit-deterministic across backends AND across evict/restore into a
    different slot.  ``w_q`` then advances by whole steps, clipped to
    ``min(floor(w_clip / w_scale), 127)``.

Because every reduction in the quant path is integer (order-independent)
and every float op is elementwise, "xla" and "pallas(-interpret)" agree
BIT-for-bit on the int32/int8 outputs — pinned in tests/test_quant.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.plasticity import kernel as _kernel
from repro.kernels.plasticity import ref as _ref
from repro.kernels.plasticity.quant import QuantConfig


@functools.partial(
    jax.jit,
    static_argnames=("tau_m", "v_th", "v_reset", "trace_decay", "w_clip",
                     "plastic", "spiking", "impl", "interpret", "block_m",
                     "quant"))
def dual_engine_step(x, w, theta, v, trace_pre, trace_post, teach=None,
                     active=None, w_scale=None, seed=None, *,
                     tau_m: float = 2.0, v_th: float = 1.0,
                     v_reset: float = 0.0, trace_decay: float = 0.8,
                     w_clip: float = 4.0, plastic: bool = True,
                     spiking: bool = True, impl: str = "xla",
                     interpret: bool = False, block_m: int = 128,
                     quant: Optional[QuantConfig] = None):
    fleet = w.ndim == 3
    if active is not None and not fleet:
        raise ValueError(
            "active slot masks are a fleet-mode (w (B, N, M)) contract; "
            f"got w {w.shape} with an active mask")

    if quant is not None:
        if w_scale is None:
            w_scale = quant.w_scale
        kw = dict(qcfg=quant, v_th=v_th, v_reset=v_reset, w_clip=w_clip,
                  plastic=plastic, spiking=spiking, teach=teach, seed=seed)
        if fleet:
            kw["active"] = active
        if impl in ("pallas", "pallas-interpret"):
            fn = (_kernel.dual_engine_fleet_step_q_pallas if fleet
                  else _kernel.dual_engine_step_q_pallas)
            return fn(x, w, w_scale, theta, v, trace_pre, trace_post,
                      block_m=block_m,
                      interpret=interpret or impl == "pallas-interpret",
                      **kw)
        fn = (_ref.dual_engine_fleet_step_q if fleet
              else _ref.dual_engine_step_q)
        return fn(x, w, w_scale, theta, v, trace_pre, trace_post, **kw)

    kw = dict(tau_m=tau_m, v_th=v_th, v_reset=v_reset,
              trace_decay=trace_decay, w_clip=w_clip, plastic=plastic,
              spiking=spiking, teach=teach)
    if fleet:
        kw["active"] = active
    if impl in ("pallas", "pallas-interpret"):
        fn = (_kernel.dual_engine_fleet_step_pallas if fleet
              else _kernel.dual_engine_step_pallas)
        return fn(x, w, theta, v, trace_pre, trace_post, block_m=block_m,
                  interpret=interpret or impl == "pallas-interpret", **kw)
    fn = _ref.dual_engine_fleet_step if fleet else _ref.dual_engine_step
    return fn(x, w, theta, v, trace_pre, trace_post, **kw)
