"""Paper Table II analogue: online-learning throughput on the MNIST task.

The paper's claim is ARCHITECTURAL: pipelining inference with plasticity
gives end-to-end FPS ~= forward-only FPS, where prior hardware ran the two
stages sequentially (A/B FPS split in Table II).  We reproduce the
methodology on the 784-1024-10 network: the fused path is the PRODUCT path
— `snn.timestep` routed through the PlasticEngine (`--impl` selects the
backend) — measured against a forward-only stack and an explicitly
sequential forward-then-update baseline (plasticity re-reads the weights,
the unfused architecture the paper improves on).

Accuracy uses the PROCEDURAL digit set (see data/mnist.py) — not
comparable to real-MNIST numbers; the throughput ratio is the deliverable.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import plasticity as P, snn
from repro.data import mnist_batch, spike_encode
from repro.kernels import lif_forward

RESULTS = os.path.join(os.path.dirname(__file__), "results")
CFG = snn.SNNConfig(layer_sizes=(784, 1024, 10), timesteps=8,
                    spiking_readout=True)


def _setup(batch: int, key):
    imgs, labels = mnist_batch(key, batch)
    spikes = jax.vmap(lambda k, im: spike_encode(k, im, CFG.timesteps))(
        jax.random.split(key, batch), imgs)          # (B, T, 784)
    state = snn.init_state(CFG, batch=1)             # engine takes (B, N)
    theta = snn.init_theta(CFG, key, scale=0.05)
    return spikes, labels, state, theta


@functools.partial(jax.jit, static_argnames=("impl",))
def fused_step(state, theta, x, impl="xla"):
    """One PRODUCT timestep: all layers through the fused PlasticEngine."""
    cfg = dataclasses.replace(CFG, impl=impl)
    return snn.timestep(cfg, state, theta, x)


@jax.jit
def forward_only_step(state, x):
    """Inference-only baseline: generic layer stack, no plasticity engine."""
    v, tr = list(state.v), list(state.trace)
    for i in range(CFG.num_layers):
        x, v[i], tr[i + 1] = lif_forward(x, state.w[i], v[i], tr[i + 1])
    return dataclasses.replace(state, v=tuple(v), trace=tuple(tr),
                               t=state.t + 1), x


@jax.jit
def sequential_step(state, theta, x):
    """Unfused baseline: forward fully completes, THEN plasticity re-reads
    every weight matrix (the two-pass architecture the paper eliminates)."""
    w, v, tr = list(state.w), list(state.v), list(state.trace)
    tr[0] = P.update_trace(tr[0], x, CFG.trace_decay)
    for i in range(CFG.num_layers):
        x, v[i], tr[i + 1] = lif_forward(x, w[i], v[i], tr[i + 1])
    for i in range(CFG.num_layers):
        w[i] = P.apply_plasticity(w[i], theta[i], tr[i], tr[i + 1],
                                  CFG.layer_plasticity_cfg(i))
    return dataclasses.replace(state, w=tuple(w), v=tuple(v), trace=tuple(tr),
                               t=state.t + 1), x


def _time(fn, args, iters):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _theta_from_scalars(cfg, scalars, key):
    """Structured per-synapse rule from 9 scalars (the ES search space).

    L1's delta term is scalar c1 TIMES a fixed random sign matrix R — the
    per-synapse delta_ij is how the offline phase encodes a feature
    projection INTO the rule (weights still start at zero online; the rule
    grows them toward +-c1-paced random features).  All other terms are
    per-layer scalars, matching the paper's four functional roles.
    """
    a1, b1, g1, c1, a2, b2, g2, d2, _ = scalars
    r = jax.random.rademacher(key, (cfg.layer_sizes[0], cfg.layer_sizes[1]),
                              dtype=jnp.float32)
    th1 = jnp.stack([
        jnp.full_like(r, a1), jnp.full_like(r, b1),
        jnp.full_like(r, g1), c1 * r])
    shp2 = (cfg.layer_sizes[1], cfg.layer_sizes[2])
    th2 = jnp.stack([jnp.full(shp2, a2), jnp.full(shp2, b2),
                     jnp.full(shp2, g2), jnp.full(shp2, d2)])
    return [th1, th2]


def make_online_eval(cfg, n_stream: int, key):
    """jit-able online-learning eval: stream of digits, predict-then-learn.

    Returns fn(scalars) -> accuracy over the last 4/5 of the stream."""
    imgs, labels = mnist_batch(key, n_stream)
    xs = imgs.reshape(n_stream, -1)

    def run(scalars):
        theta = _theta_from_scalars(cfg, scalars, jax.random.PRNGKey(7))
        teach_amp = scalars[-1]
        state0 = snn.init_state(cfg)

        def step(state, inp):
            x, label = inp
            _, scores = snn.classify_window(cfg, state, theta, x)
            teach = teach_amp * jax.nn.one_hot(label, cfg.layer_sizes[-1])
            state, _ = snn.classify_window(cfg, state, theta, x, teach=teach)
            return state, (jnp.argmax(scores) == label)

        _, hits = jax.lax.scan(step, state0, (xs, labels))
        warm = n_stream // 5
        return hits[warm:].mean()

    return run


def es_optimize_rule(n_stream: int = 96, gens: int = 12, pop_pairs: int = 8,
                     key=None):
    """Phase-1 for the MNIST task: PEPG over the 9 rule scalars, fitness =
    online predict-before-learn accuracy (the paper's offline/online split
    applied to classification)."""
    from repro.core import es
    key = jax.random.PRNGKey(3) if key is None else key
    import dataclasses as _dc
    cfg = _dc.replace(CFG, w_clip=1.0, timesteps=6)
    evaluate = jax.jit(make_online_eval(cfg, n_stream, key))

    mu0 = jnp.asarray([0.01, 0.004, -0.003, 0.002,
                       0.05, -0.002, -0.005, -0.0005, 2.0])
    scale = jnp.asarray([0.01, 0.005, 0.005, 0.002,
                         0.05, 0.005, 0.005, 0.001, 1.0])

    pcfg = es.PEPGConfig(num_params=9, pop_pairs=pop_pairs, sigma_init=0.5,
                         lr_mu=0.3)

    def fitness(pop, k):
        return jax.vmap(lambda p: evaluate(mu0 + p * scale))(pop)

    st, hist = es.run(pcfg, fitness, key, gens)
    best = mu0 + st.best_theta * scale
    return best, float(st.best_fitness), [float(h) for h in hist], cfg


def online_accuracy(n_samples: int, key, teach_amp: float = 2.0) -> float:
    """Supervised online learning: PREDICT each digit first (no teaching
    signal), then learn on it with the label injected as a teaching current
    into the output layer (supervised-STDP protocol).  Running accuracy of
    the predict-before-learn stream is returned — a true online metric.

    The rule here is hand-set Hebbian-dominant (alpha>0, delta<0) rather
    than ES-trained; the paper's 97.5% uses an ES-optimized rule on real
    MNIST, so this number demonstrates the ONLINE-LEARNING MECHANISM, not
    the accuracy claim (DESIGN.md §8)."""
    import dataclasses
    cfg = dataclasses.replace(CFG, w_clip=1.0)
    imgs, labels = mnist_batch(key, n_samples)
    state = snn.init_state(cfg)
    # hand-set rule.  Weights start at ZERO (Phase-2 semantics), so the
    # Hebbian term alone can never bootstrap — the paper's presynaptic term
    # is what grows synapses from activity before any postsynaptic spike
    # exists.  L1: bootstrap + prune; L2: Hebbian binding to the taught
    # class with presynaptic depression of non-causal features.
    coeffs = [
        # (alpha, beta, gamma, delta)
        (0.010, 0.004, -0.0030, -0.0010),   # L1: 784 -> 1024
        (0.050, -0.002, -0.0050, -0.0005),  # L2: 1024 -> 10
    ]
    theta = []
    for i in range(cfg.num_layers):
        shp = (cfg.layer_sizes[i], cfg.layer_sizes[i + 1])
        th = jnp.zeros((4, *shp))
        for j, c in enumerate(coeffs[i]):
            th = th.at[j].set(c)
        theta.append(th)

    @jax.jit
    def predict_then_learn(state, img, label):
        x = img.reshape(-1)
        _, scores = snn.classify_window(cfg, state, theta, x)   # no learning leak
        teach = teach_amp * jax.nn.one_hot(label, cfg.layer_sizes[-1])
        state, _ = snn.classify_window(cfg, state, theta, x, teach=teach)
        return state, jnp.argmax(scores)

    correct = 0
    for i in range(n_samples):
        state, pred = predict_then_learn(state, imgs[i], labels[i])
        if i >= n_samples // 5:                 # skip the cold-start fifth
            correct += int(pred == int(labels[i]))
    return correct / (n_samples - n_samples // 5)


def main(quick: bool = False, impl: str = "xla"):
    os.makedirs(RESULTS, exist_ok=True)
    key = jax.random.PRNGKey(0)
    spikes, labels, state, theta = _setup(4, key)
    x = spikes[0, 0][None]                           # (1, 784)

    iters = 3 if quick else 10
    t_fused = _time(functools.partial(fused_step, impl=impl),
                    (state, theta, x), iters)
    t_fwd = _time(forward_only_step, (state, x), iters)
    t_seq = _time(sequential_step, (state, theta, x), iters)

    # FPS = 1 / (timesteps * per-timestep latency)
    fps = {k: 1.0 / (CFG.timesteps * t)
           for k, t in (("fused", t_fused), ("forward_only", t_fwd),
                        ("sequential", t_seq))}
    acc = online_accuracy(40 if quick else 120, key)
    out = {
        "impl": impl,
        "per_timestep_ms": {"fused": t_fused * 1e3,
                            "forward_only": t_fwd * 1e3,
                            "sequential": t_seq * 1e3},
        "fps": fps,
        "fused_vs_sequential_speedup": t_seq / t_fused,
        "learning_overhead_vs_forward": t_fused / t_fwd,
        "procedural_digit_accuracy": acc,
        "note": ("CPU wall-clock; paper Table II methodology — end-to-end "
                 "FPS with learning ~ forward-only FPS when stages fuse, "
                 "which is THE claim this harness reproduces. The accuracy "
                 "field is a mechanism demo only and sits AT CHANCE (~0.1): "
                 "a hand-set/random-searched scalar rule cannot separate "
                 "classes without lateral inhibition or the paper's full "
                 "per-synapse ES (3.2M coefficients on real MNIST -> "
                 "97.5%); --es runs a small PEPG search over the 9-scalar "
                 "structured rule (modestly above chance on the train "
                 "stream). See DESIGN.md §8."),
    }
    import sys
    if "--es" in sys.argv:
        best, fit, hist, cfg_es = es_optimize_rule(
            n_stream=64, gens=8, pop_pairs=6)
        held = jax.jit(make_online_eval(cfg_es, 96,
                                        jax.random.PRNGKey(99)))(best)
        out["es_rule"] = {"train_stream_acc": fit,
                          "heldout_stream_acc": float(held),
                          "history": hist,
                          "scalars": [float(b) for b in best]}
    print(json.dumps(out, indent=1))
    with open(os.path.join(RESULTS, "mnist_throughput.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"])
    ap.add_argument("--es", action="store_true",
                    help="run the small PEPG rule search too")
    args = ap.parse_args()
    main(quick=args.quick, impl=args.impl)
