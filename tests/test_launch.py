"""Launch-layer regression tests: specs/steps stay eval_shape-clean for the
FULL configs (no allocation), and the end-to-end drivers run at smoke scale.
The 512-device lowering itself is exercised by launch/dryrun.py (its own
process owns the XLA device-count flag)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.distributed import sharding as shd
from repro.launch import steps
from repro.launch.mesh import HW, make_local_mesh
from repro.launch.specs import TRAIN_SETUP, input_specs
from repro.models.config import SHAPES
from repro.optim import adamw

LM_ARCHS = [a for a in ARCHS if a != "firefly-snn"]


def test_train_setup_covers_every_arch():
    for a in LM_ARCHS:
        assert a in TRAIN_SETUP, a
        mb = TRAIN_SETUP[a].get("microbatches", 1)
        assert SHAPES["train_4k"].global_batch % mb == 0


def test_hw_constants():
    assert HW["peak_flops_bf16"] == 197e12
    assert HW["hbm_bw"] == 819e9
    assert HW["hbm_bytes"] == 16 * 2**30


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-moe-16b",
                                  "mamba2-1.3b", "zamba2-7b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_full_config_eval_shape(arch, shape):
    """FULL configs trace through the step functions abstractly on a
    1-device mesh — catches shape bugs without the 512-device compile."""
    mesh = make_local_mesh()
    with shd.use_mesh(mesh), mesh:
        spec = input_specs(arch, shape, mesh)
        assert spec["kind"] != "skip"
        if spec["kind"] == "train":
            opt = adamw(lr=1e-4)
            fn = steps.make_train_step(
                cfg=spec["cfg"], opt=opt,
                microbatches=spec["setup"].get("microbatches", 1))
        else:
            fn = steps.make_decode_step(spec["cfg"])
        out = jax.eval_shape(fn, *spec["args"])
        assert out is not None


def test_skip_cells_marked():
    mesh = make_local_mesh()
    with shd.use_mesh(mesh), mesh:
        spec = input_specs("qwen2-72b", "long_500k", mesh)
        assert spec["kind"] == "skip" and "quadratic" in spec["why"]
        spec2 = input_specs("mamba2-1.3b", "long_500k", mesh)
        assert spec2["kind"] == "decode"


@pytest.mark.slow
def test_train_driver_end_to_end(tmp_path):
    """The real CLI: 6 steps of a smoke model with checkpointing."""
    import os
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-4b",
         "--smoke", "--steps", "6", "--global-batch", "4",
         "--seq-len", "32", "--ckpt", str(tmp_path), "--save-every", "3"],
        capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert '"steps": 6' in r.stdout
