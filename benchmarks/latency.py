"""Paper 8 µs end-to-end latency analogue.

The FPGA completes one control step (inference + plasticity, both layers,
all timesteps pipelined) in 8 µs at 0.713 W.  On TPU v5e the same
controller is minuscule; the honest comparison is the ROOFLINE latency of
the fused dual-engine program at controller scale plus measured CPU wall
time of the PRODUCT path — `snn.controller_step`, every layer routed
through the PlasticEngine (--impl selects the backend; "xla" default, an
upper bound — CPU is not the target).

Since the time-fused rollout landed, `snn.controller_step` executes its
whole ``timesteps x layers`` window as ONE `engine.rollout` launch (a
single `pallas_call` on the Pallas backends) — the measured wall time here
is the fused path, the software analogue of the paper's single-pipeline
8 µs datapath.

Prints a CSV: scale,roofline_us,cpu_wall_us,paper_fpga_us.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import snn
from repro.launch.mesh import HW

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def controller_roofline_us(obs: int, hidden: int, act: int,
                           timesteps: int) -> float:
    """Roofline latency of one control step on one v5e core."""
    d = 2
    total = 0.0
    for (n, m) in ((obs, hidden), (hidden, act)):
        flops = 2 * n * m + 2 * n * m + 10 * m          # fwd + hebb + pointwise
        byts = d * (5 * n * m + 2 * n + 4 * m)          # w + theta(4) + traces
        total += max(flops / HW["peak_flops_bf16"], byts / HW["hbm_bw"]) * 1e6
    return total * timesteps


def measured_wall_us(cfg: snn.SNNConfig, iters: int = 20) -> float:
    state = snn.init_state(cfg)
    theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.05)
    obs = jnp.linspace(-1, 1, cfg.layer_sizes[0])
    step = jax.jit(lambda s, o: snn.controller_step(cfg, s, theta, o))
    s, a = step(state, obs)
    jax.block_until_ready(a)
    t0 = time.perf_counter()
    for _ in range(iters):
        s, a = step(s, obs)
        jax.block_until_ready(a)
    return (time.perf_counter() - t0) / iters * 1e6


def main(quick: bool = False, impl: str = "xla"):
    os.makedirs(RESULTS, exist_ok=True)
    rows = {"impl": impl, "fused_rollout": True}
    print("scale,roofline_us,cpu_wall_us,paper_fpga_us")
    for name, (o, h, a, t) in {
        "control_8_128_8": (8, 128, 8, 4),
        "mnist_784_1024_10": (784, 1024, 10, 8),
    }.items():
        cfg = snn.SNNConfig(layer_sizes=(o, h, a), timesteps=t, impl=impl)
        roof = controller_roofline_us(o, h, a, t)
        wall = measured_wall_us(cfg, iters=5 if quick else 20)
        rows[name] = {"roofline_us": roof, "cpu_wall_us": wall,
                      "paper_fpga_us": 8.0}
        print(f"{name},{roof:.3f},{wall:.1f},8.0")
    with open(os.path.join(RESULTS, "latency.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"])
    args = ap.parse_args()
    main(quick=args.quick, impl=args.impl)
