"""Online learning on streaming digits via the PlasticEngine pipeline.

    PYTHONPATH=src python examples/online_mnist.py [--impl pallas-interpret]

The paper's Table II scenario at reduced demo scale (784-256-10 here vs
the paper's 784-1024-10 — see benchmarks/mnist_throughput.py for full
scale): the network processes a digit stream while its synapses update
online — `snn.timestep` routes every layer through the fused dual-engine
step (forward AND plasticity in ONE program per layer), so learning adds
no separate pass over the weights.  `--impl` selects the engine backend
("xla" CPU oracle by default; "pallas" is the TPU kernel,
"pallas-interpret" validates it on CPU).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import snn
from repro.data import mnist_batch, spike_encode

_ap = argparse.ArgumentParser()
_ap.add_argument("--impl", default="xla",
                 choices=["xla", "pallas", "pallas-interpret"])
IMPL = _ap.parse_args().impl

CFG = snn.SNNConfig(layer_sizes=(784, 256, 10), timesteps=6,
                    spiking_readout=True, impl=IMPL)


@jax.jit
def fused_timestep(state, theta, x):
    """One product timestep: all layers through the fused engine."""
    return snn.timestep(CFG, state, theta, x)


def main():
    key = jax.random.PRNGKey(0)
    state = snn.init_state(CFG, batch=1)
    theta = snn.init_theta(CFG, key, scale=0.05)

    imgs, labels = mnist_batch(key, 32)
    t0 = time.time()
    frames = 0
    drift = []
    for i in range(imgs.shape[0]):
        sp = spike_encode(jax.random.fold_in(key, i), imgs[i], CFG.timesteps)
        counts = jnp.zeros((10,))
        w_before = state.w[0]
        for t in range(CFG.timesteps):
            state, s2 = fused_timestep(state, theta, sp[t][None])
            counts = counts + s2[0]
        drift.append(float(jnp.abs(state.w[0] - w_before).mean()))
        frames += 1
    dt = time.time() - t0
    print(f"processed {frames} digits in {dt:.2f}s "
          f"({frames/dt:.1f} FPS end-to-end incl. learning, impl={IMPL})")
    print(f"mean |dW| per frame (online plasticity active): "
          f"{sum(drift)/len(drift):.5f}")
    print("weights started at zero; the stream itself built the synapses.")


if __name__ == "__main__":
    main()
