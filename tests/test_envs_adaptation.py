"""Control environments + the two-phase learning loop (paper Secs. II-B, IV)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import envs
from repro.core import adaptation, es, snn


@pytest.mark.parametrize("name", ["direction", "velocity", "position"])
class TestEnvs:
    def test_reset_step_shapes(self, name):
        env = envs.make(name)
        state = env.reset(jax.random.PRNGKey(0), env.train_tasks()[0])
        obs = env.observe(state)
        assert obs.shape == (env.obs_dim,)
        state, r = env.step(state, jnp.zeros((env.act_dim,)))
        assert jnp.isfinite(r)

    def test_task_protocol_8_train_72_eval(self, name):
        env = envs.make(name)
        assert env.train_tasks().shape[0] == 8
        assert env.eval_tasks().shape[0] == 72

    def test_actuator_mask_disables(self, name):
        env = envs.make(name)
        mask = jnp.zeros((env.act_dim,))
        state = env.reset(jax.random.PRNGKey(0), env.train_tasks()[0],
                          actuator_mask=mask)
        s1, _ = env.step(state, jnp.ones((env.act_dim,)))
        s2, _ = env.step(state, -jnp.ones((env.act_dim,)))
        np.testing.assert_allclose(np.asarray(s1.phys), np.asarray(s2.phys),
                                   atol=1e-6)

    @given(seed=st.integers(0, 100))
    @settings(max_examples=5, deadline=None)
    def test_rollout_finite(self, name, seed):
        env = envs.make(name)
        state = env.reset(jax.random.PRNGKey(seed), env.train_tasks()[0])

        def body(s, t):
            a = jnp.sin(t * jnp.ones((env.act_dim,)))
            s, r = env.step(s, a)
            return s, r

        _, rs = jax.lax.scan(body, state, jnp.arange(50))
        assert bool(jnp.isfinite(rs).all())


class TestPEPG:
    def test_optimizes_quadratic(self):
        cfg = es.PEPGConfig(num_params=4, pop_pairs=16, lr_mu=0.3,
                            sigma_init=0.3, rank_shaping=True)
        target = jnp.asarray([1.0, -2.0, 0.5, 3.0])

        def fitness(pop, key):
            return -jnp.sum((pop - target) ** 2, axis=-1)

        state, hist = es.run(cfg, fitness, jax.random.PRNGKey(0), 150)
        assert float(jnp.sum((state.mu - target) ** 2)) < 0.5
        assert float(hist[-1]) > float(hist[0])

    def test_antithetic_layout(self):
        cfg = es.PEPGConfig(num_params=3, pop_pairs=5)
        state = es.init(cfg, jax.random.PRNGKey(0))
        pop, eps = es.ask(cfg, state, jax.random.PRNGKey(1))
        assert pop.shape == (10, 3)
        np.testing.assert_allclose(
            np.asarray(pop[:5] + pop[5:]),
            np.broadcast_to(np.asarray(2 * state.mu[None]), (5, 3)),
            atol=1e-6)

    def test_elitism_tracks_best(self):
        cfg = es.PEPGConfig(num_params=2, pop_pairs=4)
        state = es.init(cfg, jax.random.PRNGKey(0))
        pop, eps = es.ask(cfg, state, jax.random.PRNGKey(1))
        fit = jnp.arange(8.0)
        state = es.tell(cfg, state, eps, fit)
        assert float(state.best_fitness) == 7.0
        np.testing.assert_allclose(np.asarray(state.best_theta),
                                   np.asarray(pop[7]), atol=1e-6)


class TestTwoPhase:
    def test_phase1_improves_fitness(self):
        """A short offline ES run on the direction task must improve mean
        return (the paper's Phase 1, miniaturized)."""
        env = envs.make("direction", episode_len=40)
        cfg = adaptation.AdaptationConfig(hidden=16, timesteps=2,
                                          pop_pairs=8, generations=8)
        theta, hist, scfg = adaptation.optimize_rule(env, cfg)
        # 8 generations is tiny; the mean fitness is noisy generation-to-
        # generation, so assert the search FOUND better rules than it
        # started with rather than that the last generation is the best.
        assert float(max(hist)) > float(hist[0])

    def test_phase2_zero_shot_generalization(self):
        """The learned rule (not weights) transfers to unseen tasks with
        weights starting from zero."""
        env = envs.make("direction", episode_len=40)
        cfg = adaptation.AdaptationConfig(hidden=16, timesteps=2,
                                          pop_pairs=8, generations=8)
        theta, _, scfg = adaptation.optimize_rule(env, cfg)
        rets = adaptation.evaluate_generalization(env, scfg, theta)
        assert rets.shape == (72,)
        assert bool(jnp.isfinite(rets).all())

    def test_actuator_failure_mask_applies(self):
        env = envs.make("direction", episode_len=30)
        cfg = adaptation.AdaptationConfig(hidden=8, timesteps=2)
        scfg = adaptation.make_snn_config(env, cfg)
        theta = snn.flatten_theta(snn.init_theta(scfg, jax.random.PRNGKey(0)))
        mask = jnp.ones((env.act_dim,)).at[0].set(0.0)
        r = adaptation.episode_return(env, scfg, theta,
                                      env.train_tasks()[0],
                                      jax.random.PRNGKey(1),
                                      actuator_mask=mask, mask_after=10)
        assert jnp.isfinite(r)
