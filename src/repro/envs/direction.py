"""Direction-generalization task (Brax `ant` stand-in).

A planar body with 8 radial thrusters ("legs") at 45-degree spacing.  Each
thruster pushes the body along its own fixed axis; dynamics are damped
point-mass.  Reward is velocity projected onto the target direction.  Train
on 8 cardinal/diagonal directions, evaluate on 72 unseen headings.  The
8-fold actuator redundancy makes single-leg failure recoverable — the
adaptation scenario from the paper (Sec. II-B "simulated leg failure").

Perturbable dynamics params (`PARAM_NAMES`): mass, damping, gain.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.envs.base import Env, EnvState


@dataclasses.dataclass(frozen=True)
class DirectionEnv(Env):
    episode_len: int = 150
    dt: float = 0.05
    obs_dim: int = 8      # vel(2) + target_dir(2) + vel_err(2) + speed + 1
    act_dim: int = 8
    mass: float = 1.0
    damping: float = 1.5
    gain: float = 4.0

    PARAM_NAMES: tuple = ("mass", "damping", "gain")

    def _thruster_axes(self) -> jax.Array:
        ang = jnp.arange(8, dtype=jnp.float32) * (2 * jnp.pi / 8)
        return jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=1)  # (8, 2)

    def init_phys(self, key: jax.Array) -> jax.Array:
        # phys = [x, y, vx, vy]
        v0 = 0.05 * jax.random.normal(key, (2,))
        return jnp.concatenate([jnp.zeros(2), v0])

    def dynamics(self, phys: jax.Array, force: jax.Array,
                 params: Optional[jax.Array] = None) -> jax.Array:
        p = self.default_params() if params is None else params
        mass, damping, gain = p[0], p[1], p[2]
        pos, vel = phys[:2], phys[2:]
        # thrusters only push (rectified), like legs
        f = gain * (jax.nn.relu(force) @ self._thruster_axes())
        acc = f / mass - damping * vel
        vel = vel + self.dt * acc
        pos = pos + self.dt * vel
        return jnp.concatenate([pos, vel])

    def observe(self, state: EnvState) -> jax.Array:
        vel = state.phys[2:]
        tdir = state.task  # unit direction (2,)
        return jnp.concatenate([
            vel, tdir, tdir - vel, jnp.array([jnp.linalg.norm(vel), 1.0])])

    def reward(self, state: EnvState, action: jax.Array,
               new_phys: jax.Array) -> jax.Array:
        vel = new_phys[2:]
        fwd = jnp.dot(vel, state.task)
        lateral = jnp.abs(vel[0] * state.task[1] - vel[1] * state.task[0])
        ctrl = 0.01 * jnp.sum(action ** 2)
        return fwd - 0.1 * lateral - ctrl

    def train_tasks(self) -> jax.Array:
        ang = jnp.arange(8, dtype=jnp.float32) * (2 * jnp.pi / 8)
        return jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=1)

    def eval_tasks(self) -> jax.Array:
        # 72 headings offset from every training heading
        ang = (jnp.arange(72, dtype=jnp.float32) + 0.5) * (2 * jnp.pi / 72)
        return jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=1)
