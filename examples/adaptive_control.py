"""End-to-end driver: closed-loop fleet adaptation under perturbation.

    PYTHONPATH=src python examples/adaptive_control.py
    PYTHONPATH=src python examples/adaptive_control.py --scenario velocity-drag \
        --quant --impl pallas-interpret
    PYTHONPATH=src python examples/adaptive_control.py --train --full

Reproduces the paper's central claim on any named scenario from
`repro.scenarios.SCENARIOS`: a controller whose synapses are continuously
rewritten by a plasticity rule RECOVERS from a mid-episode perturbation
(actuator failure, wind/drag/payload shift, goal switch), while the same
controller with weights frozen at the perturbation onset cannot adapt.

Everything runs through the scenario engine's closed-loop harness: B env
instances against B plastic controllers, one `lax.scan`, every layer step
on the PlasticEngine fleet path (`--impl` picks the backend, `--quant` the
FPGA-faithful fixed-point datapath).  The default rule is the deterministic
reference rule; `--train` runs Phase-1 PEPG search for a learned rule
instead (slower, the paper's actual protocol).
"""
import argparse
import json

import jax

from repro import envs, scenarios
from repro.core import adaptation, snn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="direction-dropout",
                    choices=sorted(scenarios.SCENARIOS))
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"],
                    help="PlasticEngine backend for every layer step")
    ap.add_argument("--quant", action="store_true",
                    help="FPGA-faithful fixed-point datapath")
    ap.add_argument("--train", action="store_true",
                    help="learn the rule with Phase-1 PEPG instead of the "
                         "reference rule")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale Phase-1 run (only with --train)")
    ap.add_argument("--batch", type=int, default=16,
                    help="fleet slots (independent env instances)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = scenarios.SCENARIOS[args.scenario]
    env = spec.make_env()

    if args.train and args.quant:
        raise SystemExit("--train --quant: train float, then deploy with "
                         "scenarios.controller_config(quant=True)")

    if args.train:
        gens = 60 if args.full else 12
        cfg = adaptation.AdaptationConfig(
            hidden=128 if args.full else 24, timesteps=2, pop_pairs=16,
            generations=gens, seed=args.seed, impl=args.impl)
        print(f"== Phase 1: PEPG rule search on {spec.env_name} "
              f"({gens} generations) ==")
        theta, hist, scfg = adaptation.optimize_rule(env, cfg)
        print(f"  train fitness {float(hist[0]):.2f} -> "
              f"{float(hist[-1]):.2f}")
    else:
        scfg = scenarios.controller_config(env, impl=args.impl,
                                           quant=args.quant)
        theta = scenarios.reference_rule(spec.env_name, scfg)
        print(f"== reference rule on {spec.env_name} "
              f"({'quant' if args.quant else 'float32'}, {args.impl}) ==")

    print(f"== Phase 2: {args.batch} slots x {spec.steps} steps, "
          f"perturbation at t={spec.onset}: {spec.perturbations} ==")
    prog = scenarios.make_closed_loop(env, scfg, batch=args.batch,
                                      steps=spec.steps)
    schedule = scenarios.compile_schedule(
        env, spec.perturbations, jax.random.PRNGKey(args.seed + 123),
        args.batch)
    key = jax.random.PRNGKey(args.seed + 7)

    res_p = prog.run(theta, key, tasks=spec.tasks, schedule=schedule)
    res_f = prog.run(theta, key, tasks=spec.tasks, schedule=schedule,
                     freeze_at=spec.onset)
    summary = scenarios.ablation_summary(
        scenarios.adaptation_metrics(res_p.rewards, spec.onset, spec.window),
        scenarios.adaptation_metrics(res_f.rewards, spec.onset, spec.window))
    summary["compiles"] = prog.compile_count()

    print(json.dumps(summary, indent=1))
    mp, mf = summary["plastic"], summary["frozen"]
    print(f"\nplastic : recovered {mp['recovery_frac']:+.0%} of the "
          f"perturbation-induced drop "
          f"(time-to-recover {mp['time_to_recover']} steps)")
    print(f"frozen  : recovered {mf['recovery_frac']:+.0%}")
    print("\nThe plastic controller's weights are rewritten online by the "
          "rule, so it keeps re-balancing after the perturbation; the "
          "frozen controller is stuck with its pre-perturbation synapses. "
          f"Both rollouts reused ONE compiled program "
          f"(compiles={summary['compiles']}).")


if __name__ == "__main__":
    main()
