"""Streaming anomaly detectors over the flight-recorder channels.

Session health is evaluated ON DEVICE, inside the same jitted pool-step /
decode program that produced the telemetry (`obs.recorder` threads these
functions through the schedulers' ``record=`` trace variants): the verdict
is a pure function of fixed-shape ``(B, ...)`` detector state, so stepping
a recorded pool costs zero host syncs and zero extra launches.  The HOST
only reads the latched verdict when it decides to act (quarantine /
rollback — `serving.scheduler.SessionPool.remediate`); detectors are
traced array ops, remediation is host policy (DESIGN.md §Health).

Four detectors, one hysteresis streak each (single-step transients never
flag — a detector must fire ``hysteresis[d]`` CONSECUTIVE recorded steps):

  ewma_z   |x - EWMA mean| / sqrt(EWMA var + z_floor^2) > z_threshold on
           any channel, after ``warmup`` recorded steps (the EWMA needs
           history before a z-score means anything).  Catches runaway
           Hebbian growth / spike-rate blowups relative to the session's
           OWN baseline.  The baseline update is WINSORIZED (see
           `health_update`): firing samples still teach, clipped to
           ±z_threshold·sigma, so a recurring clean burst re-teaches the
           variance within a couple of fires while a real fault out-runs
           the clipped learning for the whole hysteresis streak.
  bound    any channel outside its absolute ``bounds`` corridor — the
           deployment-wide sanity envelope (e.g. saturation fraction
           pinned at 1.0, weight-norm drift past the corridor).
  stuck    the whole channel vector within ``stuck_eps`` of the previous
           recorded step's, ``hysteresis`` steps running (after warmup):
           telemetry that stops moving is a dead datapath, not a healthy
           session.  The default eps of 0.0 means bitwise-frozen only.
  dead     spike rate (channel 0) below ``dead_floor`` after warmup — the
           dead-session / spike-collapse detector.

Flags LATCH (``HealthState.flagged`` is sticky per detector) so the host
policy can run at any cadence without racing a verdict that un-fires; the
scheduler clears a slot's rows on admit/evict/rollback.

Inactive slots are fully gated: their channels arrive as exact zeros (the
recorder multiplies by the same active mask that bit-freezes their state),
no detector fires, streaks reset, EWMA state holds.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Channel schema of the flight-recorder ring (obs/recorder.py): the three
# FleetTelemetry signals plus the weight-norm drift vs admission snapshot.
CHANNELS = ("spike_rate", "mean_abs_dw", "sat_frac", "wnorm_drift")

# Detector order — indexes `HealthConfig.hysteresis`, `HealthState.streaks`
# and `HealthState.flagged` columns.
DETECTORS = ("ewma_z", "bound", "stuck", "dead")


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Static detector configuration (hashable: part of the jit closure).

    window     ring length W of the flight recorder (steps of history kept
               per slot for post-mortem dumps; detectors are streaming and
               do not re-scan the ring).
    ewma_alpha EWMA smoothing for the per-channel mean/variance baseline.
    z_threshold / z_floor
               ewma_z fires when |x - mean| exceeds z_threshold *
               sqrt(var + z_floor^2); the floor stops a near-constant
               channel's vanishing variance from turning numeric jitter
               into infinite z-scores.  The default (0.03, in channel
               units — rates live in [0, 1]) is sized to the quantized
               channel granularity of SMALL pools: an 8-neuron adapter's
               spike rate moves in 1/8 steps, and a floor well under that
               granularity would z-flag every legitimate burst against a
               quiet baseline.
    warmup     recorded steps before ewma_z / stuck / dead may fire (the
               baseline is meaningless on a fresh admission).
    bounds     per-channel (lo, hi) absolute corridor, `CHANNELS` order.
               Defaults are generous deployment-envelope values tuned to
               never fire on the serving benchmarks' clean churn
               (benchmarks/obs_health.py gates the false-positive rate).
    stuck_eps  max per-channel move still counting as "unchanged".
    dead_floor spike-rate floor for the dead-session detector.
    hysteresis per-detector consecutive-fire count before flagging,
               `DETECTORS` order.
    """

    window: int = 64
    ewma_alpha: float = 0.2
    z_threshold: float = 6.0
    z_floor: float = 0.03
    warmup: int = 8
    bounds: tuple = ((0.0, 8.0), (0.0, 4.0), (0.0, 1.01), (0.0, 64.0))
    stuck_eps: float = 0.0
    dead_floor: float = 1e-5
    hysteresis: tuple = (3, 3, 8, 8)

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if len(self.bounds) != len(CHANNELS):
            raise ValueError(
                f"bounds needs one (lo, hi) per channel {CHANNELS}, got "
                f"{len(self.bounds)}")
        if len(self.hysteresis) != len(DETECTORS):
            raise ValueError(
                f"hysteresis needs one entry per detector {DETECTORS}, "
                f"got {len(self.hysteresis)}")
        if any(h < 1 for h in self.hysteresis):
            raise ValueError(f"hysteresis entries must be >= 1, got "
                             f"{self.hysteresis}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HealthState:
    """Per-slot streaming detector state — every leaf slot-major ``(B, ...)``
    (no shared leaves, so the state shards cleanly over the pool's
    ``"data"`` axis and rides through `engine.fleet_spmd` at axis 0).

    ewma_mean / ewma_var   per-channel EWMA baseline ``(B, C) float32``
    last                   previous recorded channel vector ``(B, C)``
    streaks                consecutive-fire counts ``(B, D) int32``
    flagged                LATCHED per-detector flags ``(B, D) bool``
    steps                  recorded (active) steps since reset ``(B,) int32``
    """

    ewma_mean: jax.Array
    ewma_var: jax.Array
    last: jax.Array
    streaks: jax.Array
    flagged: jax.Array
    steps: jax.Array


def init_health(cfg: HealthConfig, slots: int) -> HealthState:
    c, d = len(CHANNELS), len(DETECTORS)
    return HealthState(
        ewma_mean=jnp.zeros((slots, c), jnp.float32),
        ewma_var=jnp.zeros((slots, c), jnp.float32),
        last=jnp.zeros((slots, c), jnp.float32),
        streaks=jnp.zeros((slots, d), jnp.int32),
        flagged=jnp.zeros((slots, d), jnp.bool_),
        steps=jnp.zeros((slots,), jnp.int32))


def health_update(cfg: HealthConfig, h: HealthState, x: jax.Array,
                  active: jax.Array) -> tuple:
    """One streaming detector step: ``(new_state, verdict (B,) bool)``.

    `x` is the recorded channel vector ``(B, C) float32`` (already gated to
    exact zeros on inactive slots); `active` the pool's ``(B,)`` mask.
    Pure array ops — traced into the recording pool-step program, never a
    separate launch.  Detection runs against the PRE-update baseline (this
    step's sample must not defend itself by dragging the mean toward the
    anomaly first), and the baseline is WINSORIZED-robust: once warm, the
    EWMA update uses d clipped per channel to ±z_threshold·sigma.  A naive
    (unclipped) mean chases a sustained anomaly within ~1/alpha steps and
    the z-score collapses before any hysteresis streak completes; a HARD
    robust gate (firing samples never teach) has the opposite failure — a
    legitimately bursty channel whose quiet warmup taught a near-zero
    variance fires forever, because the baseline can never learn the
    burst is normal.  Winsorizing splits the difference exactly: each
    firing step still grows the variance by a bounded factor
    ((1-a)(1+a·z_threshold²)), so a real fault with a large z out-runs the
    clipped learning for the full hysteresis streak, while a recurring
    clean burst stops firing after a couple of occurrences.  Samples that
    fire the absolute `bound` corridor are excluded outright — values
    outside the deployment envelope should never define "normal", and
    bound does not depend on the baseline, so it cannot lock itself out.
    """
    act = jnp.asarray(active).astype(jnp.bool_)
    x = x.astype(jnp.float32)
    warm = h.steps >= cfg.warmup

    # ewma_z: z-score vs the slot's own running baseline
    z = jnp.abs(x - h.ewma_mean) / jnp.sqrt(h.ewma_var + cfg.z_floor ** 2)
    fire_z = warm & jnp.any(z > cfg.z_threshold, axis=-1)

    # bound: the absolute deployment corridor
    lo = jnp.asarray([b[0] for b in cfg.bounds], jnp.float32)
    hi = jnp.asarray([b[1] for b in cfg.bounds], jnp.float32)
    fire_bound = jnp.any((x < lo) | (x > hi), axis=-1)

    # stuck: the whole channel vector stopped moving
    fire_stuck = warm & jnp.all(jnp.abs(x - h.last) <= cfg.stuck_eps,
                                axis=-1)

    # dead: spike collapse
    fire_dead = warm & (x[:, CHANNELS.index("spike_rate")] < cfg.dead_floor)

    fires = jnp.stack([fire_z, fire_bound, fire_stuck, fire_dead],
                      axis=-1) & act[:, None]
    streaks = jnp.where(fires, h.streaks + 1, 0)
    hyst = jnp.asarray(cfg.hysteresis, jnp.int32)
    flagged = h.flagged | (streaks >= hyst)

    # baseline update: inactive slots hold their state bit-exactly;
    # out-of-corridor samples never teach; once warm the deviation is
    # winsorized per channel to ±z_threshold·sigma (clip is a no-op for
    # any channel that did not fire), so a sustained fault cannot drag
    # the mean under itself within a hysteresis streak but a recurring
    # clean burst re-teaches the variance after a couple of fires
    gate = act[:, None]
    learn = (act & ~fire_bound)[:, None]
    d = x - h.ewma_mean
    cap = cfg.z_threshold * jnp.sqrt(h.ewma_var + cfg.z_floor ** 2)
    d = jnp.where(warm[:, None], jnp.clip(d, -cap, cap), d)
    a = cfg.ewma_alpha
    new = HealthState(
        ewma_mean=jnp.where(learn, h.ewma_mean + a * d, h.ewma_mean),
        ewma_var=jnp.where(learn, (1.0 - a) * (h.ewma_var + a * d * d),
                           h.ewma_var),
        last=jnp.where(gate, x, h.last),
        streaks=streaks,
        flagged=flagged,
        steps=h.steps + act.astype(jnp.int32))
    return new, jnp.any(flagged, axis=-1)
