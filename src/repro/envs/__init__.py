"""Pure-JAX continuous-control environments (Brax stand-ins, DESIGN.md §8.1).

Five tasks; the first three mirror the paper's evaluation protocol
(Sec. IV-A), the last two grow the scenario-engine's diversity axis:

  * direction:  planar 8-thruster locomotor trained on 8 target directions,
                evaluated on 72 unseen directions           (Brax `ant`)
  * velocity:   1-D runner trained on 8 target velocities,
                evaluated on 72 unseen velocities           (Brax `halfcheetah`)
  * position:   2-link torque-controlled reacher with random
                goal positions                              (Brax `ur5e`)
  * arm:        2-link arm with in-plane gravity and a variable tip
                payload (persistent-load adaptation scenario)
  * stabilizer: 1-D setpoint regulation with redundant thrusters and a
                wind-force dynamics shift

All are reset/step pure functions, vmap- and scan-compatible, with an
actuator-mask channel to simulate morphology damage ("leg failure") and a
``PARAM_NAMES`` vector of perturbable dynamics constants that the scenario
engine (`repro.scenarios`) shifts per fleet slot as data.
"""
from repro.envs.base import Env, EnvState
from repro.envs.direction import DirectionEnv
from repro.envs.velocity import VelocityEnv
from repro.envs.reacher import ReacherEnv
from repro.envs.arm import ArmEnv
from repro.envs.stabilizer import StabilizerEnv

ENVS = {
    "direction": DirectionEnv,
    "velocity": VelocityEnv,
    "position": ReacherEnv,
    "arm": ArmEnv,
    "stabilizer": StabilizerEnv,
}


def make(name: str, **kwargs) -> Env:
    return ENVS[name](**kwargs)
