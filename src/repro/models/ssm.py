"""Mamba2 (SSD) block: plan + apply (chunked train/prefill) + recurrent decode.

Structure per Mamba2: in_proj -> [z | xBC | dt]; short causal conv over xBC;
SSD scan over heads; gated RMSNorm; out_proj.  Heads shard over "model";
the SSD state (B, H, S, P) is the decode cache — O(1) per token, which is
what qualifies ssm/hybrid archs for the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_constraint
from repro.kernels.ssd import ssd as ssd_op
from repro.kernels.ssd import ssd_decode_step
from repro.models.config import ModelConfig
from repro.models.layers import ParamDesc, rms_norm


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    d_xbc = d_inner + 2 * s.n_groups * s.state
    return d_inner, n_heads, d_xbc


def plan(cfg: ModelConfig, stack: int = 0) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, d_xbc = dims(cfg)
    dt = cfg.dtype

    def desc(shape, spec, **kw):
        if stack:
            shape, spec = (stack, *shape), (None, *spec)
        kw.setdefault("dtype", dt)
        return ParamDesc(shape, spec, **kw)

    return {
        "norm": desc((d,), (None,), init="ones"),
        # fused input projection: z (d_inner) | xBC (d_xbc) | dt (n_heads)
        "w_in": desc((d, d_inner + d_xbc + n_heads), ("data", "model"), fan_in=d),
        "conv_w": desc((s.conv_width, d_xbc), (None, "model"),
                       fan_in=s.conv_width),
        "conv_b": desc((d_xbc,), ("model",), init="zeros"),
        "a_log": desc((n_heads,), ("model",), init="zeros", dtype="float32"),
        "dt_bias": desc((n_heads,), ("model",), init="zeros", dtype="float32"),
        "d_skip": desc((n_heads,), ("model",), init="ones", dtype="float32"),
        "out_norm": desc((d_inner,), ("model",), init="ones"),
        "w_out": desc((d_inner, d), ("model", "data"), fan_in=d_inner),
    }


def _split(cfg, proj):
    s = cfg.ssm
    d_inner, n_heads, d_xbc = dims(cfg)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_xbc]
    dt_raw = proj[..., d_inner + d_xbc:]
    return z, xbc, dt_raw


def _conv(xbc, conv_w, conv_b, conv_state=None):
    """Short causal conv along sequence.  xbc (B,S,C); conv_w (W,C)."""
    w = conv_w.shape[0]
    if conv_state is not None:  # decode: xbc is (B,1,C)
        window = jnp.concatenate([conv_state, xbc], axis=1)  # (B,W,C)
        out = jnp.einsum("bwc,wc->bc", window, conv_w)[:, None, :]
        new_state = window[:, 1:]
        return jax.nn.silu(out + conv_b), new_state
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    stack = jnp.stack([pad[:, i:i + xbc.shape[1]] for i in range(w)], axis=2)
    out = jnp.einsum("bswc,wc->bsc", stack, conv_w)
    return jax.nn.silu(out + conv_b), None


def _ssd_inputs(cfg, xbc, dt_raw, a_log, dt_bias):
    s = cfg.ssm
    d_inner, n_heads, _ = dims(cfg)
    bsz, length = xbc.shape[0], xbc.shape[1]
    x = xbc[..., :d_inner].reshape(bsz, length, n_heads, s.head_dim)
    bc = xbc[..., d_inner:]
    bmat = bc[..., :s.n_groups * s.state].reshape(bsz, length, s.n_groups, s.state)
    cmat = bc[..., s.n_groups * s.state:].reshape(bsz, length, s.n_groups, s.state)
    # broadcast groups -> heads
    rep = n_heads // s.n_groups
    bmat = jnp.repeat(bmat, rep, axis=2)
    cmat = jnp.repeat(cmat, rep, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + dt_bias)  # (B,L,H)
    a = -jnp.exp(a_log)                                         # (H,)
    return x, dt, a, bmat, cmat


def apply(params, x, cfg: ModelConfig, impl: str = "xla"):
    """Full-sequence SSD (train/prefill).  x (B,S,D) ->
    (out (B,S,D), final ssd state, conv tail (B,W-1,C))."""
    s = cfg.ssm
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,dk->bsk", h, params["w_in"])
    z, xbc, dt_raw = _split(cfg, proj)
    conv_tail = xbc[:, -(s.conv_width - 1):]   # raw pre-conv window for decode
    xbc, _ = _conv(xbc, params["conv_w"], params["conv_b"])
    xs, dt, a, bmat, cmat = _ssd_inputs(cfg, xbc, dt_raw,
                                        params["a_log"], params["dt_bias"])
    xs = shard_constraint(xs, ("data", None, "model", None))
    y, state = ssd_op(xs, dt, a, bmat, cmat, chunk=s.chunk, impl=impl)
    y = y + (params["d_skip"][None, None, :, None]
             * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(*y.shape[:2], -1)                              # (B,S,d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["w_out"]).astype(x.dtype)
    return (x + shard_constraint(out, ("data", None, None)), state,
            conv_tail.astype(x.dtype))


def plan_cache(cfg: ModelConfig, batch: int, n_layers: int) -> dict:
    """Decode cache: SSD state + conv window."""
    s = cfg.ssm
    d_inner, n_heads, d_xbc = dims(cfg)
    return {
        "ssm": ParamDesc((n_layers, batch, n_heads, s.state, s.head_dim),
                         (None, "data", "model", None, None),
                         init="zeros", dtype="float32"),
        "conv": ParamDesc((n_layers, batch, s.conv_width - 1, d_xbc),
                          (None, "data", None, "model"),
                          init="zeros", dtype=cfg.dtype),
    }


def decode_step(params, x, ssm_state, conv_state, cfg: ModelConfig):
    """One-token recurrent step.  x (B,1,D); ssm_state (B,H,S,P);
    conv_state (B,W-1,C).  Returns (out, new_ssm_state, new_conv_state)."""
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,dk->bsk", h, params["w_in"])
    z, xbc, dt_raw = _split(cfg, proj)
    xbc, conv_state = _conv(xbc, params["conv_w"], params["conv_b"],
                            conv_state)
    xs, dt, a, bmat, cmat = _ssd_inputs(cfg, xbc, dt_raw,
                                        params["a_log"], params["dt_bias"])
    ssm_state, y = ssd_decode_step(ssm_state, xs[:, 0], dt[:, 0], a,
                                   bmat[:, 0], cmat[:, 0])
    y = y[:, None] + (params["d_skip"][None, None, :, None]
                      * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(*y.shape[:2], -1)
    y = rms_norm(y * jax.nn.silu(z), params["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["w_out"]).astype(x.dtype)
    return x + out, ssm_state, conv_state
