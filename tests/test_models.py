"""Per-arch smoke tests (reduced configs) + serving-path parity.

Every assigned architecture instantiates its SMOKE config and runs one
forward + one train step on CPU, asserting output shapes and finiteness.
The FULL configs are exercised only by launch/dryrun.py (abstract).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.launch.steps import make_train_step, model_flops, n_active_params
from repro.models import transformer as T
from repro.models.config import SHAPES, shape_applicable
from repro.optim import adamw

LM_ARCHS = [a for a in ARCHS if a != "firefly-snn"]


def _batch(cfg, b=2, s=16, key=0):
    k = jax.random.PRNGKey(key)
    toks = jax.random.randint(k, (b, s), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.fold_in(k, 1), (b, s), 0, cfg.vocab)
    if cfg.input_mode == "embeddings":
        inputs = jax.random.normal(k, (b, s, cfg.d_model)).astype(cfg.adtype)
    else:
        inputs = toks
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke(arch)
    params = T.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, _ = T.forward(params, batch["inputs"], cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    params = T.init(cfg, jax.random.PRNGKey(0))
    opt = adamw(lr=1e-3)
    step = make_train_step(cfg, opt, microbatches=2, remat_policy="none")
    opt_state = opt.init(params)
    p1, o1, m = jax.jit(step)(params, opt_state, _batch(cfg, b=4))
    assert np.isfinite(float(m["loss"]))
    # at least one parameter moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert moved


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_decode_matches_forward(arch):
    """Teacher-forced decode reproduces full-sequence forward logits."""
    import dataclasses
    cfg = get_smoke(arch).with_(dtype="float32")
    if cfg.moe is not None:
        # parity requires no token dropping: decode sees T=1 per step while
        # forward sees T=S, so give both ample expert capacity
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                capacity_factor=64.0))
    params = T.init(cfg, jax.random.PRNGKey(1))
    s = 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, s), 0, cfg.vocab)
    if cfg.input_mode == "embeddings":
        # decode looks tokens up in the embed table, so the "precomputed
        # frontend embeddings" must BE those embeddings for parity
        inputs = jnp.take(params["embed"], toks, axis=0)
    else:
        inputs = toks
    full, _ = T.forward(params, inputs, cfg, attn_impl="xla")

    prefix = 4
    _, cache = T.prefill(params, inputs[:, :prefix], cfg, max_len=s,
                         attn_impl="xla")
    outs = []
    for t in range(prefix, s):
        logits, cache = T.decode_step(params, cache, toks[:, t:t + 1], cfg)
        outs.append(logits)
    # decode at position t consumes token t => logits align with full[t]
    for i, lg in enumerate(outs):
        np.testing.assert_allclose(
            np.asarray(lg[0]), np.asarray(full[0, prefix + i]),
            rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_full_config_param_plan(arch):
    """The FULL config's parameter plan is well-formed (no allocation)."""
    cfg = get_config(arch)
    n = T.n_params(cfg)
    assert n > 1e9, f"{arch}: suspicious param count {n}"
    n_act = n_active_params(cfg)
    assert 0 < n_act <= n
    if cfg.moe is not None:
        assert n_act < n  # MoE must have inactive experts


def test_long_500k_applicability():
    """Sub-quadratic archs run long_500k; full-attention archs skip it."""
    shape = SHAPES["long_500k"]
    runs = {a: shape_applicable(get_config(a), shape)[0] for a in LM_ARCHS}
    assert runs["mamba2-1.3b"] and runs["zamba2-7b"]
    for a in ("qwen2-72b", "grok-1-314b", "musicgen-medium", "pixtral-12b"):
        assert not runs[a]


def test_plastic_adapter_decode_updates_fast_weights():
    """The FireFly-P rule runs per decode step: W_fast rewrites online and
    starts at zero (Phase-2 semantics)."""
    cfg = get_smoke("qwen3-4b").with_(plastic_adapter=True,
                                      adapter_neurons=16)
    params = T.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    _, cache = T.prefill(params, toks, cfg, max_len=10)
    assert float(jnp.abs(cache["adapter"]["w_fast"]).sum()) == 0.0
    _, cache = T.decode_step(params, cache, toks[:, :1], cfg)
    assert float(jnp.abs(cache["adapter"]["w_fast"]).sum()) > 0.0


def test_model_flops_formulas():
    cfg = get_config("qwen3-4b")
    n = n_active_params(cfg)
    assert model_flops(cfg, "train", 8, 128) == 6.0 * n * 8 * 128
    assert model_flops(cfg, "decode", 8, 128) == 2.0 * n * 8


@pytest.mark.parametrize("arch", ["qwen3-4b", "zamba2-7b"])
def test_int8_kv_cache_decode_parity(arch):
    """int8 KV cache (kv_quant=True): decode tracks the fp path within
    quantization tolerance; cache tensors actually store int8."""
    cfg = get_smoke(arch).with_(dtype="float32")
    cfgq = cfg.with_(kv_quant=True)
    params = T.init(cfg, jax.random.PRNGKey(1))
    s = 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, s), 0, cfg.vocab)
    full, _ = T.forward(params, toks, cfg, attn_impl="xla")
    _, cache = T.prefill(params, toks[:, :4], cfgq, max_len=s,
                         attn_impl="xla")
    seg0 = cache["segments"][0]
    assert seg0["k"].dtype == jnp.int8 and "k_scale" in seg0
    for t in range(4, s):
        lg, cache = T.decode_step(params, cache, toks[:, t:t + 1], cfgq)
        ref = full[0, t]
        rel = float(jnp.abs(lg[0] - ref).max() / jnp.abs(ref).max())
        assert rel < 0.05, (t, rel)
