"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64; Mamba2 backbone + SHARED attention block.  [arXiv:2411.15242]

Mapped to the `hybrid` layout: 81 layers = 9 super-blocks x (1 shared
attention+MLP block + 8 Mamba2 blocks).  The attention/MLP parameters are
SHARED across super-blocks (stored once at top level), reproducing Zamba2's
parameter-shared global block; ssm_state=64, mamba head_dim=64."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    layout="hybrid", sub_quadratic=True,
    ssm=SSMConfig(state=64, head_dim=64, expand=2, n_groups=1,
                  conv_width=4, chunk=256, attn_every=9),
)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512,
    layout="hybrid", sub_quadratic=True, remat=False,
    ssm=SSMConfig(state=16, head_dim=16, expand=2, n_groups=1,
                  conv_width=4, chunk=16, attn_every=3),
)
