"""HLO analyzer unit tests on synthetic module text (no devices needed)."""
import pytest

from repro.launch import hlo_analysis as H


def test_shape_bytes():
    assert H._shape_bytes("f32[8,256]{1,0}") == 8 * 256 * 4
    assert H._shape_bytes("bf16[4]") == 8
    assert H._shape_bytes("(s32[], f32[2,2])") == 4 + 16
    assert H._shape_bytes("pred[]") == 1


def test_group_size_formats():
    assert H._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert H._group_size("replica_groups=[16,32]<=[32,16]T(1,0)") == 32
    assert H._group_size("no groups here") == 1


def test_collective_wire_model():
    # all-reduce over 4 devices, 100-byte result: 2 * 100 * 3/4
    assert H._collective_wire_bytes("all-reduce", 100, 4) == 150.0
    assert H._collective_wire_bytes("all-gather", 100, 4) == 75.0
    assert H._collective_wire_bytes("reduce-scatter", 100, 4) == 300.0
    assert H._collective_wire_bytes("all-reduce", 100, 1) == 0.0


SYNTH = """
HloModule synth

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %w = f32[8,8] all-gather(%x), channel_id=1, replica_groups={{0,1},{2,3}}, dimensions={0}
  %d = f32[8,8] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %d)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %wh = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,8] get-tuple-element(%wh), index=1
}
"""


def test_synthetic_module_trip_counted():
    a = H.analyze(SYNTH)
    # dot: 2*8*8*8 = 1024 flops x 10 iterations
    assert a["flops_per_device"] == 1024 * 10
    # all-gather result 256B, group 2 -> wire 128B x 10
    assert a["collective_by_kind"]["all-gather"] == 128.0 * 10
    assert a["bytes_per_device"] > 0


def test_roofline_terms_dominant():
    hw = {"peak_flops_bf16": 1e12, "hbm_bw": 1e11, "ici_bw": 5e10}
    terms = H.roofline_terms(
        {"flops_per_device": 1e12, "bytes_per_device": 1e9,
         "collective_wire_bytes_per_device": 1e9}, hw)
    assert terms["dominant"] == "compute"
    assert terms["compute_s"] == 1.0


def test_dus_fusion_window_accounting():
    """A dus-rooted fusion charges the update window, not the buffer."""
    text = """
HloModule m

%fused (fp0: f32[1024,1024], fp1: f32[1,1024]) -> f32[1024,1024] {
  %fp0 = f32[1024,1024] parameter(0)
  %fp1 = f32[1,1024] parameter(1)
  %z = s32[] constant(0)
  ROOT %dus = f32[1024,1024] dynamic-update-slice(%fp0, %fp1, %z, %z)
}

ENTRY %main (x: f32[1024,1024], u: f32[1,1024]) -> f32[1024,1024] {
  %x = f32[1024,1024] parameter(0)
  %u = f32[1,1024] parameter(1)
  ROOT %f = f32[1024,1024] fusion(%x, %u), kind=kLoop, calls=%fused
}
"""
    a = H.analyze(text)
    # window write (4KB) + window read (4KB update operand) -- NOT 4MB
    assert a["bytes_per_device"] < 64 * 1024
