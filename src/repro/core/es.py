"""Parameter-Exploring Policy Gradients (PEPG) — Sehnke et al. 2010.

The paper's Phase-1 offline optimizer: searches the plasticity-coefficient
space theta with symmetric (antithetic) sampling.  Pure JAX; the fitness
function is expected to be vmappable (a whole plastic-SNN episode rollout).

    eps ~ N(0, sigma^2)            (one per population pair)
    theta+/- = mu +/- eps
    d_mu    = alpha_mu    * T^T r_diff      T_ij = eps_ij
    d_sigma = alpha_sigma * S^T r_avg       S_ij = (eps_ij^2 - sigma_j^2)/sigma_j

with r_diff = (r+ - r-)/2 and r_avg = (r+ + r-)/2 - b (running baseline).
Optional rank-based fitness shaping stabilizes heavy-tailed RL returns.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PEPGConfig:
    num_params: int
    pop_pairs: int = 32              # population = 2 * pop_pairs (antithetic)
    lr_mu: float = 0.1
    lr_sigma: float = 0.05
    sigma_init: float = 0.05
    sigma_min: float = 1e-3
    sigma_max: float = 1.0
    baseline_decay: float = 0.9
    rank_shaping: bool = True
    mu_init_scale: float = 0.0


class PEPGState(NamedTuple):
    mu: jax.Array          # (num_params,)
    sigma: jax.Array       # (num_params,)
    baseline: jax.Array    # ()
    step: jax.Array        # ()
    best_fitness: jax.Array
    best_theta: jax.Array


def init(cfg: PEPGConfig, key: jax.Array) -> PEPGState:
    mu = cfg.mu_init_scale * jax.random.normal(key, (cfg.num_params,))
    return PEPGState(
        mu=mu,
        sigma=jnp.full((cfg.num_params,), cfg.sigma_init),
        baseline=jnp.zeros(()),
        step=jnp.zeros((), jnp.int32),
        best_fitness=jnp.full((), -jnp.inf),
        best_theta=mu,
    )


def ask(cfg: PEPGConfig, state: PEPGState, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sample the antithetic population.

    Returns (population, eps): population is (2*pop_pairs, num_params) laid
    out as [mu+eps_0..mu+eps_{P-1}, mu-eps_0..mu-eps_{P-1}].
    """
    eps = jax.random.normal(key, (cfg.pop_pairs, cfg.num_params)) * state.sigma[None, :]
    pop = jnp.concatenate([state.mu[None, :] + eps, state.mu[None, :] - eps], axis=0)
    return pop, eps


def _rank_shape(f: jax.Array) -> jax.Array:
    """Centered rank transform in [-0.5, 0.5]."""
    n = f.shape[0]
    ranks = jnp.argsort(jnp.argsort(f))
    return ranks.astype(jnp.float32) / (n - 1) - 0.5


def tell(cfg: PEPGConfig, state: PEPGState, eps: jax.Array,
         fitness: jax.Array) -> PEPGState:
    """PEPG update from population fitness (ordered as `ask` returned it)."""
    p = cfg.pop_pairs
    f_raw = fitness
    f = _rank_shape(fitness) if cfg.rank_shaping else fitness
    f_pos, f_neg = f[:p], f[p:]

    r_diff = 0.5 * (f_pos - f_neg)                       # (P,)
    r_avg = 0.5 * (f_pos + f_neg)                        # (P,)
    baseline = jnp.where(
        state.step == 0, r_avg.mean(),
        cfg.baseline_decay * state.baseline + (1 - cfg.baseline_decay) * r_avg.mean())

    # mu gradient:  T^T r_diff / P
    d_mu = eps.T @ r_diff / p                            # (num_params,)
    # sigma gradient: S^T (r_avg - b) / P
    s_mat = (eps ** 2 - state.sigma[None, :] ** 2) / state.sigma[None, :]
    d_sigma = s_mat.T @ (r_avg - baseline) / p

    mu = state.mu + cfg.lr_mu * d_mu
    sigma = jnp.clip(state.sigma + cfg.lr_sigma * d_sigma,
                     cfg.sigma_min, cfg.sigma_max)

    # elitism bookkeeping over raw (unshaped) fitness
    pop = jnp.concatenate([state.mu[None, :] + eps, state.mu[None, :] - eps], 0)
    best_idx = jnp.argmax(f_raw)
    gen_best_f = f_raw[best_idx]
    gen_best_theta = pop[best_idx]
    improved = gen_best_f > state.best_fitness
    return PEPGState(
        mu=mu, sigma=sigma, baseline=baseline, step=state.step + 1,
        best_fitness=jnp.where(improved, gen_best_f, state.best_fitness),
        best_theta=jnp.where(improved, gen_best_theta, state.best_theta),
    )


def run(cfg: PEPGConfig,
        fitness_fn: Callable[[jax.Array, jax.Array], jax.Array],
        key: jax.Array,
        generations: int,
        log_every: int = 0) -> tuple[PEPGState, jax.Array]:
    """Full ES loop.  fitness_fn(population, key) -> (pop_size,) fitness.

    Returns (final_state, per-generation mean-fitness history).  The loop is
    a lax.scan so the entire offline phase jit-compiles to one program.
    """
    state = init(cfg, key)

    def gen(carry, k):
        st = carry
        k_ask, k_fit = jax.random.split(k)
        pop, eps = ask(cfg, st, k_ask)
        fit = fitness_fn(pop, k_fit)
        st = tell(cfg, st, eps, fit)
        return st, fit.mean()

    keys = jax.random.split(jax.random.fold_in(key, 1), generations)
    state, history = jax.lax.scan(gen, state, keys)
    return state, history
