"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (kv=16) d_ff=1408 (expert
width) vocab=102400; 2 shared + 64 routed top-6, fine-grained; first layer
dense (d_ff=10944).  [arXiv:2401.06066; hf]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    rope_theta=10_000.0,
    layout="moe",
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  first_dense=1, first_dense_ff=10944,
                  capacity_factor=1.25),
)

SMOKE = ModelConfig(
    name="deepseek-moe-16b-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=512,
    layout="moe", remat=False,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=96, n_shared=2,
                  first_dense=1, first_dense_ff=192,
                  capacity_factor=1.25),
)
