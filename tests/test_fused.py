"""Time-fused rollout megakernel (`engine.rollout` / kernels.plasticity.fused).

The fusion contract, in order of load-bearing-ness:

  1. K=1 fused window == the per-step composition (input-trace update +
     per-layer `engine.layer_step`) BIT-for-bit, on both backends, on all
     four datapath variants (shared/fleet x float/quant).  Fusing a window
     of one must be a pure refactor of the per-step kernels.
  2. K>1 fused Pallas window == the scanned xla oracle BIT-for-bit (float
     at the default ``unroll_k=1``; quant at EVERY unroll setting — its
     reductions are integer, so loop restructuring cannot move a bit).
  3. Grid padding: fleet pools whose B is not a multiple of ``block_b``
     (and layer widths off the 128 tile) produce identical bits; the
     padded tail programs must not write.
  4. Inactive fleet slots stay bit-frozen across the whole fused window,
     and evict -> re-admit through the FleetScheduler between fused
     windows is bit-identical to an uninterrupted session.
  5. The callers routed through the fused path (`snn.controller_step`,
     `FleetScheduler.pool_step`, `models.plastic.decode_rollout`) are
     bit-identical to their per-step equivalents.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, plasticity as P, snn
from repro.kernels.plasticity import quant as Q
from repro.serving import FleetScheduler, SessionStore

IMPLS = ["xla", "pallas-interpret"]
SIZES = (6, 10, 4)          # deliberately off the 128-wide Pallas tile


def _net_state(key, sizes, batch=None, fleet=False, qc=None):
    """Random NetworkState (float or fixed-point), batched/fleet on demand."""
    ks = jax.random.split(key, 16)
    L = len(sizes) - 1
    lead = (batch,) if batch is not None else ()
    wlead = (batch,) if fleet else ()

    def r(k, *shape):
        x = 0.3 * jax.random.normal(k, shape)
        return Q.to_fixed(x, qc) if qc is not None else x

    w = tuple(
        jax.random.randint(ks[i], (*wlead, sizes[i], sizes[i + 1]),
                           -20, 20, jnp.int8) if qc is not None
        else 0.2 * jax.random.normal(ks[i], (*wlead, sizes[i], sizes[i + 1]))
        for i in range(L))
    v = tuple(r(ks[4 + i], *lead, sizes[i + 1]) for i in range(L))
    tr = tuple(jnp.abs(r(ks[8 + i], *lead, sizes[i])) for i in range(L + 1))
    if qc is None:
        ws = ()
    elif fleet:
        ws = tuple(jnp.full((batch,), qc.w_scale, jnp.float32)
                   for _ in range(L))
    else:
        ws = tuple(jnp.float32(qc.w_scale) for _ in range(L))
    return engine.NetworkState(w=w, v=v, trace=tr,
                               t=jnp.zeros((), jnp.int32), w_scale=ws)


def _theta(key, sizes):
    return [0.05 * jax.random.normal(jax.random.fold_in(key, i),
                                     (4, sizes[i], sizes[i + 1]))
            for i in range(len(sizes) - 1)]


def _params(sizes, qc=None):
    L = len(sizes) - 1
    return [engine.EngineParams(spiking=i < L - 1, quant=qc,
                                tau_m=qc.tau_m if qc else 2.0,
                                trace_decay=qc.decay if qc else 0.8)
            for i in range(L)]


def _case(name, K, batch=None, fleet=False, qc=None):
    key = jax.random.PRNGKey(abs(hash(name)) % 2**31)
    ks = jax.random.split(key, 4)
    st = _net_state(ks[0], SIZES, batch=batch, fleet=fleet, qc=qc)
    theta = _theta(ks[1], SIZES)
    params = _params(SIZES, qc=qc)
    lead = (batch,) if batch is not None else ()
    drives = jax.random.uniform(ks[2], (K, *lead, SIZES[0]))
    if qc is not None:
        drives = Q.to_fixed(drives, qc)
    return st, theta, params, drives


def _assert_trees_equal(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{msg} leaf {i}")


# All four datapath variants: (batch, fleet, quant)
VARIANTS = [
    pytest.param(3, False, False, id="shared-float"),
    pytest.param(5, True, False, id="fleet-float"),
    pytest.param(3, False, True, id="shared-quant"),
    pytest.param(5, True, True, id="fleet-quant"),
]


class TestK1VsPerStep:
    """A fused window of ONE step is a pure refactor of the per-step path."""

    @pytest.mark.parametrize("batch,fleet,quant", VARIANTS)
    @pytest.mark.parametrize("impl", IMPLS)
    def test_k1_bitwise_vs_per_step_composition(self, impl, batch, fleet,
                                                quant):
        qc = Q.QuantConfig() if quant else None
        st, theta, params, drives = _case(f"k1-{impl}", 1, batch=batch,
                                          fleet=fleet, qc=qc)

        def per_step(state, drive):
            # exactly what the per-step stack does: input-trace update,
            # then one `layer_step` per layer on the same backend
            w, v, tr = list(state.w), list(state.v), list(state.trace)
            if qc is not None:
                tr[0] = Q.trace_update_q(tr[0], drive, qc)
            else:
                tr[0] = P.update_trace(tr[0], drive, 0.8)
            x = drive
            for i in range(state.num_layers):
                layer = engine.LayerState(
                    w=w[i], v=v[i], trace_pre=tr[i], trace_post=tr[i + 1],
                    theta=theta[i],
                    w_scale=state.w_scale[i] if state.w_scale else None)
                seed = (Q.fold_seed(state.t.astype(jnp.int32), i)
                        if qc is not None else None)
                layer, x = engine.layer_step(layer, x, params=params[i],
                                             impl=impl, seed=seed)
                w[i], v[i], tr[i + 1] = layer.w, layer.v, layer.trace_post
            return engine.NetworkState(w=tuple(w), v=tuple(v),
                                       trace=tuple(tr), t=state.t + 1,
                                       w_scale=state.w_scale), x

        f_step = jax.jit(per_step)
        f_roll = jax.jit(functools.partial(engine.rollout, params=params,
                                           impl=impl))
        s_ref, out_ref = f_step(st, drives[0])
        s_fus, outs = f_roll(st, theta, drives)
        if impl != "xla" and fleet and not quant:
            # The per-step FLEET float kernel reduces per-stream (grid over
            # B) while the fused kernel reduces a whole stream block; their
            # float bits differ by ULPs — as the per-step kernel's always
            # have vs the oracle (TestLayerStepParity is tolerance-based).
            # The fused kernel is pinned BITWISE to the oracle instead
            # (TestKWindowVsOracle); here the two kernels agree to float
            # precision.
            for r, f in zip(jax.tree.leaves((s_ref.w, s_ref.v, s_ref.trace,
                                             out_ref)),
                            jax.tree.leaves((s_fus.w, s_fus.v, s_fus.trace,
                                             outs[0]))):
                np.testing.assert_allclose(np.asarray(r), np.asarray(f),
                                           rtol=1e-6, atol=1e-6)
            return
        _assert_trees_equal((s_ref.w, s_ref.v, s_ref.trace, s_ref.t),
                            (s_fus.w, s_fus.v, s_fus.trace, s_fus.t),
                            "state")
        np.testing.assert_array_equal(np.asarray(out_ref),
                                      np.asarray(outs[0]), err_msg="out")


class TestKWindowVsOracle:
    """K>1 fused Pallas window == scanned per-step xla oracle, bit-for-bit."""

    @pytest.mark.parametrize("batch,fleet,quant", VARIANTS)
    @pytest.mark.parametrize("K", [2, 8])
    def test_window_bitwise_vs_scanned_oracle(self, K, batch, fleet, quant):
        qc = Q.QuantConfig() if quant else None
        st, theta, params, drives = _case(f"kw-{K}", K, batch=batch,
                                          fleet=fleet, qc=qc)
        fns = [jax.jit(functools.partial(engine.rollout, params=params,
                                         impl=impl)) for impl in IMPLS]
        (s_x, o_x), (s_p, o_p) = [f(st, theta, drives) for f in fns]
        np.testing.assert_array_equal(np.asarray(o_x), np.asarray(o_p))
        _assert_trees_equal(s_x, s_p, "state")

    def test_teach_window_and_held_teach(self):
        st, theta, params, drives = _case("teach", 6, batch=4)
        key = jax.random.PRNGKey(9)
        held = 0.5 * jax.random.normal(key, (4, SIZES[-1]))
        window = 0.5 * jax.random.normal(key, (6, 4, SIZES[-1]))
        for teach in (held, window):
            fns = [jax.jit(functools.partial(engine.rollout, params=params,
                                             impl=impl, teach=teach))
                   for impl in IMPLS]
            (s_x, o_x), (s_p, o_p) = [f(st, theta, drives) for f in fns]
            np.testing.assert_array_equal(np.asarray(o_x), np.asarray(o_p))
            _assert_trees_equal(s_x, s_p)

    def test_quant_bitwise_at_every_unroll(self):
        """Integer reductions: loop restructuring cannot move a bit."""
        qc = Q.QuantConfig()
        st, theta, params, drives = _case("unroll", 6, batch=4, fleet=True,
                                          qc=qc)
        ref = None
        for unroll_k in (0, 1, 3):
            f = jax.jit(functools.partial(engine.rollout, params=params,
                                          impl="pallas-interpret",
                                          unroll_k=unroll_k))
            s, o = f(st, theta, drives)
            if ref is None:
                ref = (s, o)
            else:
                np.testing.assert_array_equal(np.asarray(ref[1]),
                                              np.asarray(o))
                _assert_trees_equal(ref[0], s, f"unroll_k={unroll_k}")


class TestGridPadding:
    """B off the block_b grid (and widths off the 128 tile) stay bitwise."""

    @pytest.mark.parametrize("b,block_b", [(7, 4), (5, 8), (3, 2)])
    def test_fleet_padding_bitwise(self, b, block_b):
        st, theta, params, drives = _case(f"pad-{b}-{block_b}", 5, batch=b,
                                          fleet=True)
        f_x = jax.jit(functools.partial(engine.rollout, params=params,
                                        impl="xla"))
        f_p = jax.jit(functools.partial(engine.rollout, params=params,
                                        impl="pallas-interpret",
                                        block_b=block_b))
        (s_x, o_x), (s_p, o_p) = f_x(st, theta, drives), f_p(st, theta,
                                                             drives)
        np.testing.assert_array_equal(np.asarray(o_x), np.asarray(o_p))
        _assert_trees_equal(s_x, s_p)

    def test_block_m_is_irrelevant_to_fusion(self):
        """The fused kernel keeps whole layers resident (layer i+1 consumes
        ALL of layer i's events), so block_m never partitions it — any
        block_m in the params yields identical bits."""
        st, theta, params, drives = _case("bm", 4, batch=3, fleet=True)
        outs = []
        for bm in (8, 128):
            p = [dataclasses.replace(pi, block_m=bm) for pi in params]
            f = jax.jit(functools.partial(engine.rollout, params=p,
                                          impl="pallas-interpret"))
            outs.append(f(st, theta, drives))
        np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                      np.asarray(outs[1][1]))
        _assert_trees_equal(outs[0][0], outs[1][0])


class TestActiveWindow:
    """Fleet slot masks across a fused window."""

    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("quant", [False, True])
    def test_inactive_slots_bit_frozen_across_window(self, impl, quant):
        qc = Q.QuantConfig() if quant else None
        b = 6
        st, theta, params, drives = _case(f"act-{impl}", 8, batch=b,
                                          fleet=True, qc=qc)
        act = jnp.arange(b) % 2 == 0
        f = jax.jit(functools.partial(engine.rollout, params=params,
                                      impl=impl, block_b=4))
        s_m, o_m = f(st, theta, drives, active=act)
        idle = np.where(~np.asarray(act))[0]
        for leaves0, leaves1 in ((st.w, s_m.w), (st.v, s_m.v),
                                 (st.trace, s_m.trace)):
            for a0, a1 in zip(leaves0, leaves1):
                np.testing.assert_array_equal(np.asarray(a0)[idle],
                                              np.asarray(a1)[idle])
        np.testing.assert_array_equal(
            np.asarray(o_m)[:, idle],
            np.zeros_like(np.asarray(o_m)[:, idle]))
        # active slots vs an UNMASKED window: bitwise on the integer
        # datapath; to float precision in float mode (the mask gates are
        # fusion barriers, so masked and unmasked float programs contract
        # FMAs differently — a different-program artifact, not drift: the
        # masked window itself is pinned bitwise across backends below)
        s_u, o_u = f(st, theta, drives)
        live = np.where(np.asarray(act))[0]
        eq = (np.testing.assert_array_equal if quant else
              functools.partial(np.testing.assert_allclose,
                                rtol=1e-6, atol=1e-6))
        eq(np.asarray(o_m)[:, live], np.asarray(o_u)[:, live])
        for a1, a0 in zip(s_m.w, s_u.w):
            eq(np.asarray(a1)[live], np.asarray(a0)[live])

    @pytest.mark.parametrize("quant", [False, True])
    def test_masked_window_backend_parity_bitwise(self, quant):
        qc = Q.QuantConfig() if quant else None
        b = 6
        st, theta, params, drives = _case("actpar", 8, batch=b, fleet=True,
                                          qc=qc)
        act = jnp.arange(b) % 2 == 0
        fns = [jax.jit(functools.partial(engine.rollout, params=params,
                                         impl=impl, block_b=4))
               for impl in IMPLS]
        (s_x, o_x), (s_p, o_p) = [f(st, theta, drives, active=act)
                                  for f in fns]
        np.testing.assert_array_equal(np.asarray(o_x), np.asarray(o_p))
        _assert_trees_equal(s_x, s_p)

    def test_active_requires_fleet(self):
        st, theta, params, drives = _case("actval", 3, batch=4)
        with pytest.raises(ValueError, match="fleet-mode"):
            engine.rollout(st, theta, drives, params=params,
                           active=jnp.ones(4, bool))


class TestSchedulerFusedWindows:
    """`pool_step` (K fused timesteps) against the scheduler contracts."""

    def _cfg(self, impl="xla", quant=False):
        cfg = snn.SNNConfig(layer_sizes=SIZES, timesteps=4, impl=impl,
                            block_b=4)
        return snn.quant_config(cfg) if quant else cfg

    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("quant", [False, True])
    def test_pool_step_matches_k_single_steps(self, impl, quant):
        cfg = self._cfg(impl, quant)
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
        K = 3

        def run(fused):
            s = FleetScheduler(cfg, theta, slots=3, store=SessionStore())
            s.admit("a"); s.admit("b")
            d = {u: 0.1 * np.arange(SIZES[0], dtype=np.float32) + len(u)
                 for u in ("a", "b")}
            if fused:
                outs = s.pool_step(d, timesteps=K)
                window = {u: np.asarray(outs[u]) for u in d}
            else:
                rows = [s.step(d) for _ in range(K)]
                window = {u: np.stack([np.asarray(r[u]) for r in rows])
                          for u in d}
            return window, s.fleet, dict(zip(s.slot_user, s._steps))

        w_f, fleet_f, steps_f = run(True)
        w_s, fleet_s, steps_s = run(False)
        assert steps_f == steps_s
        for u in ("a", "b"):
            np.testing.assert_array_equal(w_f[u], w_s[u])
        _assert_trees_equal(fleet_f, fleet_s, "fleet")

    @pytest.mark.parametrize("impl", IMPLS)
    def test_evict_readmit_between_windows_bit_identical(self, impl,
                                                         tmp_path):
        """A session interrupted between fused windows — evicted, persisted,
        re-admitted into a DIFFERENT slot — continues bit-identically."""
        cfg = self._cfg(impl, quant=True)   # quant: per-session seeds too
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
        windows, K = 4, 3
        cut = windows // 2

        def trajectory(interrupt):
            sub = "int" if interrupt else "unint"
            sched = FleetScheduler(
                cfg, theta, slots=2,
                store=SessionStore(root=str(tmp_path / f"{impl}-{sub}")))
            assert sched.admit("probe") == 0
            outs = []
            for t in range(windows):
                if interrupt and t == cut:
                    sched.evict("probe")
                    sched.store._warm.clear()       # force the disk path
                    sched.admit("rival")            # rival takes slot 0
                    sched.pool_step(
                        {"rival": np.ones(SIZES[0], np.float32)},
                        timesteps=K)
                    assert sched.admit("probe") == 1    # DIFFERENT slot
                drives = {u: np.sin(0.3 * t + np.arange(SIZES[0]))
                          .astype(np.float32)
                          for u in sched.active_users}
                outs.append(np.asarray(
                    sched.pool_step(drives, timesteps=K)["probe"]))
            sched.evict("probe")
            final, step = sched.store.checkout(
                "probe", lambda: snn.init_state(cfg))
            return outs, final, step

        o1, f1, s1 = trajectory(False)
        o2, f2, s2 = trajectory(True)
        assert s1 == s2 == windows * K
        for a, b in zip(o1, o2):
            np.testing.assert_array_equal(a, b)
        _assert_trees_equal(f1, f2, "final state")

    def test_compile_count_stable_across_window_churn(self):
        cfg = self._cfg()
        theta = snn.init_theta(cfg, jax.random.PRNGKey(0))
        s = FleetScheduler(cfg, theta, slots=3, store=SessionStore())
        d = lambda us: {u: np.ones(SIZES[0], np.float32) for u in us}
        s.admit("w"); s.pool_step(d(["w"]))
        s.evict("w"); s.admit("w"); s.pool_step(d(["w"])); s.evict("w")
        c0 = s.compile_count()
        for t in range(8):
            uid = f"u{t % 3}"
            if uid in s.user_slot:
                s.evict(uid)
            else:
                s.admit(uid, evict_lru=True)
            s.pool_step(d(s.active_users))
        assert s.compile_count() == c0


class TestFusedCallers:
    """Callers routed through the megakernel stay pinned to per-step."""

    @pytest.mark.parametrize("impl", IMPLS)
    def test_controller_step_backend_parity_bitwise(self, impl):
        cfg = snn.SNNConfig(layer_sizes=SIZES, timesteps=4, impl=impl)
        ref = dataclasses.replace(cfg, impl="xla")
        theta = snn.init_theta(cfg, jax.random.PRNGKey(1))
        st = snn.init_state(cfg, batch=5, fleet=True)
        obs = jax.random.normal(jax.random.PRNGKey(2), (5, SIZES[0]))
        s_r, a_r = jax.jit(functools.partial(snn.controller_step, ref,
                                             theta=theta))(st, obs=obs)
        s_i, a_i = jax.jit(functools.partial(snn.controller_step, cfg,
                                             theta=theta))(st, obs=obs)
        np.testing.assert_array_equal(np.asarray(a_r), np.asarray(a_i))
        _assert_trees_equal(s_r, s_i)

    def test_decode_rollout_matches_sequential_decode(self):
        from repro.models import plastic
        from repro.models.config import ModelConfig
        B, K, N = 3, 5, 12
        base = dict(name="t", n_layers=1, d_model=16, n_heads=2,
                    n_kv_heads=2, d_ff=32, vocab=64, adapter_neurons=N)
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 5)
        params = {"p_in": 0.3 * jax.random.normal(ks[0], (16, N)),
                  "p_out": 0.3 * jax.random.normal(ks[1], (N, 16)),
                  "theta": 0.1 * jax.random.normal(ks[2], (4, N, N)),
                  "scale": jnp.float32(0.5)}
        state = {k: jnp.zeros((B, N, N)) if k == "w_fast"
                 else jnp.zeros((B, N))
                 for k in ("w_fast", "v1", "v2", "tr1", "tr2")}
        state["t"] = jnp.zeros((B,), jnp.int32)  # per-session step counter
        h = jax.random.normal(ks[3], (B, K, 16))
        cfg = ModelConfig(**base, adapter_impl="xla")

        def seq(params, state, h):
            outs = []
            for k in range(K):
                hk, state = plastic.decode_step(params, state,
                                                h[:, k:k + 1], cfg)
                outs.append(hk)
            return jnp.concatenate(outs, axis=1), state

        h_ref, st_ref = jax.jit(seq)(params, state, h)
        for impl in IMPLS:
            c = ModelConfig(**base, adapter_impl=impl)
            f = jax.jit(functools.partial(plastic.decode_rollout, cfg=c))
            h_r, st_r = f(params, state, h)
            np.testing.assert_array_equal(np.asarray(h_ref),
                                          np.asarray(h_r), err_msg=impl)
            for k in st_ref:
                np.testing.assert_array_equal(np.asarray(st_ref[k]),
                                              np.asarray(st_r[k]),
                                              err_msg=f"{impl} {k}")


class TestRolloutValidation:
    def test_nonuniform_params_raise(self):
        st, theta, params, drives = _case("val1", 2, batch=3)
        params = list(params)
        params[0] = dataclasses.replace(params[0], tau_m=4.0)
        with pytest.raises(ValueError, match="uniform EngineParams"):
            engine.rollout(st, theta, drives, params=params)

    def test_bad_teach_rank_raises(self):
        st, theta, params, drives = _case("val2", 2, batch=3)
        with pytest.raises(ValueError, match="teach"):
            engine.rollout(st, theta, drives, params=params,
                           teach=jnp.zeros((2, 2, 3, SIZES[-1])))

    def test_fleet_drive_shape_raises(self):
        st, theta, params, _ = _case("val3", 2, batch=3, fleet=True)
        with pytest.raises(ValueError, match="fleet rollout"):
            engine.rollout(st, theta, jnp.zeros((2, SIZES[0])),
                           params=params)

    def test_quant_dtype_contract_raises(self):
        qc = Q.QuantConfig()
        st, theta, params, drives = _case("val4", 2, batch=3, qc=qc)
        with pytest.raises(ValueError, match="int32"):
            engine.rollout(st, theta, drives.astype(jnp.float32),
                           params=params)
