"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; pixtral-ViT frontend + mistral-nemo decoder.
[hf:mistralai/Pixtral-12B-2409]

Backbone only, per the shape spec: the ViT patch-encoder is a STUB —
input_specs() provides precomputed patch/text embeddings (input_mode=
"embeddings"); the 12B decoder is fully real."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128,
    rope_theta=1_000_000.0,
    layout="dense", input_mode="embeddings",
)

SMOKE = ModelConfig(
    name="pixtral-12b-smoke",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=32,
    layout="dense", input_mode="embeddings", remat=False,
)
