"""Abstract input construction for the dry-run: ShapeDtypeStruct stand-ins
with shardings for every (arch x shape) cell, plus the per-arch launch
setup table (microbatches / activation sharding / moment dtype) that makes
the big train cells fit 16 GiB/chip.

Nothing here allocates device memory — everything is eval_shape-grade.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp  # noqa: F401

from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.layers import abstract_from_plan, shardings_from_plan
from repro.optim import OptState

# ---------------------------------------------------------------------------
# Per-arch train launch setup.  Derived by napkin math against 16 GiB/chip
# (see EXPERIMENTS.md §Dry-run): saved-residual bytes = L * B_loc/mb * S * D
# * 2 / (model-axis if sp), plus params + grads + Adam moments under 2D
# (fsdp x tensor) sharding.
# ---------------------------------------------------------------------------

#   * "sp" activation sharding (sequence over the model axis between
#     blocks) is used wherever the saved-residual footprint would not fit —
#     it also halves the TP activation collectives (RS+AG vs AR).
#   * microbatches are kept MINIMAL: with fsdp-sharded parameters every
#     extra microbatch pays one more weight-grad reduction per layer
#     (comm ∝ μb), so μb is a memory knob of last resort.
#   * SSM/hybrid archs stay "dp": the SSD chunk scan wants contiguous
#     sequence per device; sharding seq over model would gather per chunk.
TRAIN_SETUP: dict[str, dict] = {
    "qwen2-72b":        dict(microbatches=2, act_shard="sp"),
    "qwen1.5-32b":      dict(microbatches=2, act_shard="sp"),
    "internlm2-20b":    dict(microbatches=2, act_shard="sp"),
    "grok-1-314b":      dict(microbatches=1, act_shard="sp",
                             moment_dtype="bfloat16",
                             accum_dtype="bfloat16"),
    "pixtral-12b":      dict(microbatches=2, act_shard="sp"),
    "qwen3-4b":         dict(microbatches=2),
    "deepseek-moe-16b": dict(microbatches=2),
    "musicgen-medium":  dict(microbatches=2),
    "zamba2-7b":        dict(microbatches=4),
    "mamba2-1.3b":      dict(microbatches=2),
}


def train_setup(arch: str) -> dict:
    return dict(TRAIN_SETUP.get(arch, {}))


def apply_setup(cfg: ModelConfig, setup: dict) -> ModelConfig:
    """Fold launch-level overrides that live on the ModelConfig."""
    if "act_shard" in setup:
        cfg = cfg.with_(act_shard=setup["act_shard"])
    return cfg


# ---------------------------------------------------------------------------
# Abstract values
# ---------------------------------------------------------------------------


def _sds(shape, dtype, spec, mesh):
    sh = shd.named_sharding(mesh, spec, shape) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype), sharding=sh)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """Train batch stand-ins: tokens or (stub-frontend) embeddings."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_mode == "embeddings":
        inputs = _sds((b, s, cfg.d_model), cfg.dtype,
                      ("data", None, None), mesh)
    else:
        inputs = _sds((b, s), "int32", ("data", None), mesh)
    return {"inputs": inputs,
            "labels": _sds((b, s), "int32", ("data", None), mesh)}


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    return {"inputs": batch_specs(cfg, shape, mesh)["inputs"]}


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    b = shape.global_batch
    spec = ("data", None) if b > 1 else (None, None)
    return {
        "tokens": _sds((b, 1), "int32", spec, mesh),
        "cache": abstract_from_plan(
            T.cache_plan(cfg, b, shape.seq_len), mesh),
    }


def params_abstract(cfg: ModelConfig, mesh, fsdp: bool = True):
    return abstract_from_plan(T.plan(cfg, fsdp), mesh)


def opt_state_abstract(params_abs, moment_dtype: str = "float32"):
    """OptState stand-in mirroring the parameter tree (ZeRO-sharded)."""
    mdt = jnp.dtype(moment_dtype)

    def like(p):
        return jax.ShapeDtypeStruct(p.shape, mdt, sharding=p.sharding)

    return OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(like, params_abs),
        nu=jax.tree.map(like, params_abs),
        master=None)


def input_specs(arch: str, shape_name: str, mesh,
                plastic: bool = False, fsdp: bool = True,
                cfg_overrides: Optional[dict] = None) -> dict:
    """Everything dryrun.py needs to lower one (arch x shape) cell.

    Returns {"kind", "cfg", "setup", "args": tuple of abstract values
    ordered as the step function expects}.  `cfg_overrides` are applied
    BEFORE abstract args are built (e.g. kv_quant changes the cache plan).
    """
    from repro.configs import get_config
    from repro.models.config import SHAPES, shape_applicable

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"kind": "skip", "cfg": cfg, "why": why}
    if plastic:
        cfg = cfg.with_(plastic_adapter=True)
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)

    setup = train_setup(arch) if shape.kind == "train" else {}
    if shape.kind == "train":
        cfg = apply_setup(cfg, setup)
    else:
        # Serving: parameters replicate over the data axis (pure tensor
        # parallel).  ZeRO-sharded serving params would re-gather every
        # layer every token — §Perf decode hillclimb measured 31x lower
        # collective wire by switching this off.  Baselines with fsdp=True
        # are snapshotted in roofline_*_baseline.json.
        fsdp = False

    p_abs = params_abstract(cfg, mesh, fsdp)
    if shape.kind == "train":
        o_abs = opt_state_abstract(
            p_abs, setup.get("moment_dtype", "float32"))
        args = (p_abs, o_abs, batch_specs(cfg, shape, mesh))
    elif shape.kind == "prefill":
        args = (p_abs, prefill_specs(cfg, shape, mesh)["inputs"])
    else:  # decode
        d = decode_specs(cfg, shape, mesh)
        args = (p_abs, d["cache"], d["tokens"])
    return {"kind": shape.kind, "cfg": cfg, "setup": setup,
            "shape": shape, "args": args}
