"""Plastic fast-weight adapter — FireFly-P's rule as an LM serving feature.

A two-population spiking micro-network rides on the backbone's hidden state
during DECODE (adaptation is a serve-time behavior, matching the paper's
Phase 2).  Per decode step, per request:

    drive   = h @ P_in                  (fixed random projection, D -> N)
    s1      = LIF(v1, drive)            (presynaptic population)
    s2, W_fast <- PlasticEngine.layer_step(s1)   (fused forward + rule)
    h'      = h + scale * (s2 @ P_out)  (readout back into the residual)

The synaptic layer between the two populations is ONE fleet-mode
`core.engine.layer_step` over the whole batch: W_fast carries a leading
request rank (B, N, N) and every decode stream rewrites its own synapses
with a per-sample dw inside a single fused launch (grid (tiles, B) on
Pallas) — not B vmap-stamped kernel calls.  The serving hot path runs the
SAME fused dual-engine program as the SNN controller; ``cfg.adapter_impl``
selects the backend ("xla" | "pallas" | "pallas-interpret").

W_fast starts at ZERO and lives in the decode cache (B, N, N) — one plastic
memory per request stream, continuously rewritten online.  theta is the
offline-learned rule (ES / PEPG in core/), frozen at serve time.

Continuous-batching contracts (the `serving.lm.LMScheduler` pool):

  * ``active (B,)`` — vacant decode slots are TRUE no-ops: the engine's
    fleet mask freezes W_fast/v2/tr2 bit-exactly, and this module gates the
    presynaptic state (v1, tr1) and the per-session step counter ``t`` the
    same way, so a vacant slot's adapter state never drifts.
  * ``cfg.adapter_quant`` — the FPGA-faithful fixed-point pool: W_fast is
    int8 with a per-slot fp32 scale, membranes/traces are int32, and dw is
    rounded to grid steps by the deterministic stochastic round keyed on
    the per-SESSION counter ``t`` (never the slot), so evict -> persist ->
    re-admit is bit-identical mid-generation.  The presynaptic population
    stays float (it is driven by the float backbone h); the datapath
    boundary is ``to_fixed(s1)`` — exact, since spikes are 0/1.

Applicability notes per arch family are in DESIGN.md §Arch-applicability
(which backbone layouts the adapter composes with, and why).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core import plasticity as P
from repro.core.snn import LIFConfig, lif_step
from repro.kernels.plasticity import quant as Q
from repro.models.config import ModelConfig
from repro.models.layers import ParamDesc

LIF = LIFConfig(tau_m=2.0, v_threshold=1.0, v_reset=0.0)
# The adapter's fixed-point grid (cfg.adapter_quant).  Defaults pair with
# the paper's datapath: tau_m = 2**1 matches LIF.tau_m, trace decay 0.75,
# int8 weights on a 2**-5 grid spanning w_clip = 4.
QUANT = Q.QuantConfig()


def plan(cfg: ModelConfig) -> dict:
    d, n = cfg.d_model, cfg.adapter_neurons
    return {
        "p_in": ParamDesc((d, n), ("data", "model"), fan_in=d, dtype=cfg.dtype),
        "p_out": ParamDesc((n, d), ("model", "data"), fan_in=n, dtype=cfg.dtype),
        "theta": ParamDesc((P.NUM_TERMS, n, n), (None, None, "model"),
                           scale=0.3, fan_in=n, dtype="float32"),
        "scale": ParamDesc((), (), init="zeros", dtype="float32"),
    }


def plan_cache(cfg: ModelConfig, batch: int) -> dict:
    """Per-stream adapter state descriptors (one session = one row).

    ``t`` is the per-SESSION step counter: scattered in and out with the
    session, it seeds the quantized datapath's deterministic stochastic
    round (and is plain bookkeeping in float mode), so an update stream
    follows the session across evictions and slot changes.
    """
    n = cfg.adapter_neurons
    f32, i32 = "float32", "int32"

    def z(shape, spec, dtype=f32):
        return ParamDesc(shape, spec, init="zeros", dtype=dtype)

    sdt = i32 if cfg.adapter_quant else f32   # synaptic-layer state dtype
    out = {
        "w_fast": ParamDesc((batch, n, n), ("data", None, "model"),
                            init="zeros",
                            dtype="int8" if cfg.adapter_quant else f32),
        "v1": z((batch, n), ("data", "model")),          # presyn: always f32
        "v2": z((batch, n), ("data", "model"), sdt),
        "tr1": z((batch, n), ("data", "model"), sdt),
        "tr2": z((batch, n), ("data", "model"), sdt),
        "t": z((batch,), ("data",), i32),
    }
    if cfg.adapter_quant:
        # per-slot dequant scale: the int8 payload is meaningless without
        # it, so it travels with the session like every other state row
        out["w_scale"] = ParamDesc((batch,), ("data",), init="full",
                                   scale=QUANT.w_scale, dtype=f32)
    return out


def _engine_params(cfg: ModelConfig, trace_decay: float, w_clip: float
                   ) -> engine.EngineParams:
    if cfg.adapter_quant:
        return engine.EngineParams(
            tau_m=QUANT.tau_m, v_th=LIF.v_threshold, v_reset=LIF.v_reset,
            trace_decay=QUANT.decay, w_clip=w_clip, plastic=True,
            spiking=True, quant=QUANT)
    return engine.EngineParams(
        tau_m=LIF.tau_m, v_th=LIF.v_threshold, v_reset=LIF.v_reset,
        trace_decay=trace_decay, w_clip=w_clip, plastic=True, spiking=True)


def _gate(active, new, old):
    """Freeze per-slot rows whose active flag is false (bit-exact no-op)."""
    if active is None:
        return new
    mask = active.astype(bool).reshape((-1,) + (1,) * (new.ndim - 1))
    return jnp.where(mask, new, old)


def decode_step(params, state: dict, h, cfg: ModelConfig,
                trace_decay: float = 0.8, w_clip: float = 4.0,
                active=None):
    """h (B,1,D) -> (h', new_state).  One online plasticity step per token.

    ``active (B,)`` (optional) freezes vacant pool slots bit-exactly —
    presynaptic state, synaptic layer, and step counter alike."""
    quant = cfg.adapter_quant
    drive = jnp.einsum("bd,dn->bn", h[:, 0].astype(jnp.float32),
                       params["p_in"].astype(jnp.float32))
    v1, s1 = lif_step(state["v1"], drive, LIF)
    v1 = _gate(active, v1, state["v1"])
    if quant:
        x = Q.to_fixed(s1, QUANT)                  # exact: spikes are 0/1
        tr1 = Q.trace_update_q(state["tr1"], x, QUANT)
    else:
        x = s1
        tr1 = P.update_trace(state["tr1"], s1, trace_decay)
    tr1 = _gate(active, tr1, state["tr1"])

    # Plastic synaptic layer: ONE fleet-mode fused dual-engine launch over
    # all request streams — w_fast (B, N, N) triggers per-sample dw, each
    # stream rewriting its own W_fast against the shared rule theta.
    ep = _engine_params(cfg, trace_decay, w_clip)
    layer = engine.LayerState(
        w=state["w_fast"], v=state["v2"], trace_pre=tr1,
        trace_post=state["tr2"], theta=params["theta"].astype(jnp.float32),
        w_scale=state.get("w_scale"))
    layer, s2 = engine.layer_step(
        layer, x, params=ep, impl=cfg.adapter_impl, active=active,
        seed=Q.fold_seed(state["t"], 0) if quant else None)

    s2f = Q.from_fixed(s2, QUANT) if quant else s2
    out = jnp.einsum("bn,nd->bd", s2f, params["p_out"].astype(jnp.float32))
    if active is not None:
        out = out * active.astype(jnp.float32)[:, None]
    h = h + (params["scale"] * out[:, None, :]).astype(h.dtype)
    new_state = {"w_fast": layer.w, "v1": v1, "v2": layer.v,
                 "tr1": tr1, "tr2": layer.trace_post,
                 "t": state["t"] + _gate(active, jnp.ones((), jnp.int32),
                                         jnp.zeros((), jnp.int32))}
    if quant:
        new_state["w_scale"] = state["w_scale"]
    return h, new_state


def decode_rollout(params, state: dict, h, cfg: ModelConfig,
                   trace_decay: float = 0.8, w_clip: float = 4.0,
                   active=None):
    """h (B, K, D) -> (h', new_state).  K plasticity steps, ONE fused launch.

    The multi-token form of K sequential `decode_step` calls — speculative
    drafts, chunked prefill tails, the scheduler's windowed `decode_window`,
    any case where a decode stream advances several tokens at once.  The
    presynaptic population is feedforward (v1/s1 depend only on the tokens),
    so its LIF series is peeled into a cheap scan of per-token projections;
    the expensive part — K steps of the plastic synaptic layer, forward +
    four-term rule on every stream's own (N, N) W_fast — then runs as ONE
    time-fused `engine.rollout` launch (a single `pallas_call` on the
    Pallas backends) instead of K per-token `layer_step` launches.
    Bit-identical to the sequential path (`tests/test_fused.py` pins it):
    the per-token einsums stay per-token inside scans, and the rollout
    oracle is the same `layer_step` program.  In quant mode step k of the
    window draws its stochastic round from the per-session counter
    ``t + k`` — exactly the sequence K single `decode_step` calls would.
    """
    quant = cfg.adapter_quant
    p_in = params["p_in"].astype(jnp.float32)
    p_out = params["p_out"].astype(jnp.float32)
    hk = jnp.swapaxes(h, 0, 1)                       # time-major (K, B, D)

    def pre(v1, h_t):
        drive = jnp.einsum("bd,dn->bn", h_t.astype(jnp.float32), p_in)
        v1_new, s1 = lif_step(v1, drive, LIF)
        return _gate(active, v1_new, v1), s1

    v1, s1_series = jax.lax.scan(pre, state["v1"], hk)   # (K, B, N)

    ep = _engine_params(cfg, trace_decay, w_clip)
    net = engine.NetworkState(
        w=(state["w_fast"],), v=(state["v2"],),
        trace=(state["tr1"], state["tr2"]), t=jnp.zeros((), jnp.int32),
        w_scale=(state["w_scale"],) if quant else ())
    drives = Q.to_fixed(s1_series, QUANT) if quant else s1_series
    net, s2_series = engine.rollout(
        net, [params["theta"].astype(jnp.float32)], drives,
        params=ep, impl=cfg.adapter_impl, active=active,
        seed=state["t"] if quant else None)

    def post(_, s2):
        s2f = Q.from_fixed(s2, QUANT) if quant else s2
        return None, jnp.einsum("bn,nd->bd", s2f, p_out)

    _, outs = jax.lax.scan(post, None, s2_series)        # (K, B, D)
    if active is not None:
        outs = outs * active.astype(jnp.float32)[None, :, None]
    h = h + (params["scale"] * jnp.swapaxes(outs, 0, 1)).astype(h.dtype)
    k_steps = h.shape[1]
    new_state = {"w_fast": net.w[0], "v1": v1, "v2": net.v[0],
                 "tr1": net.trace[0], "tr2": net.trace[1],
                 "t": state["t"] + _gate(active,
                                         jnp.full((), k_steps, jnp.int32),
                                         jnp.zeros((), jnp.int32))}
    if quant:
        new_state["w_scale"] = state["w_scale"]
    return h, new_state
