"""Observability pins: metrics registry, fleet telemetry, recompile watchdog.

The contracts this file locks down (see src/repro/obs/ and DESIGN.md):

  1. TELEMETRY IS FREE WHEN OFF — `telemetry=False` engine/rollout results
     are bitwise identical to `telemetry=True`'s state/outputs on xla AND
     pallas-interpret, float32 AND int8: the flag is a static trace
     variant, never a runtime branch inside the program.
  2. TELEMETRY IS HONEST WHEN ON — the per-slot health vector matches an
     independent numpy oracle computed from the step's own inputs/outputs
     (spike rate, net |dw|, membrane saturation), and VACANT slots report
     exact zeros in every field (no stale-state leakage).
  3. The metrics registry exports a stable JSON snapshot schema and valid
     Prometheus text exposition; typed get-or-create never aliases kinds.
  4. The schedulers' `compiled_programs()` audit names every jitted entry
     point, telemetry variants included, with untraced variants at 0.
  5. The recompile watchdog counts every backend compile, flags compiles
     as violations ONLY while armed, and captures the offending program's
     name.
  6. SessionStore's legacy counter attributes (warm_hits/restores/creates/
     persists) are live views of the obs counters — one source of truth.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, snn
from repro.kernels.plasticity import quant as Q
from repro.obs import (FleetTelemetry, MetricsRegistry, SAT_FRACTION,
                       adapter_telemetry, record_fleet_telemetry,
                       watchdog as watch)
from repro.serving import FleetScheduler, SessionStore

IMPLS = ["xla", "pallas-interpret"]
DATAPATHS = ["float32", "int8"]

B, SIZES, K = 4, (6, 10, 3), 5
VACANT = 2                       # slot held inactive in the fleet fixtures
TEL_FIELDS = ("spike_rate", "mean_abs_dw", "sat_frac", "occupancy")


def _np(x):
    return np.asarray(jax.device_get(x))


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestMetricsRegistry:
    def test_counter_monotonic(self):
        reg = MetricsRegistry()
        c = reg.counter("admissions_total", "h")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)
        assert reg.counter("admissions_total") is c  # get-or-create

    def test_gauge_set_add(self):
        g = MetricsRegistry().gauge("occupancy")
        g.set(0.5)
        g.add(0.25)
        assert g.value == 0.75

    def test_histogram_buckets_and_percentiles(self):
        h = MetricsRegistry().histogram("lat", buckets=(0.01, 0.1, 1.0))
        for v in (0.005, 0.05, 0.05, 0.5, 5.0):
            h.observe(v)
        assert h.count == 5 and h.sum == pytest.approx(5.605)
        assert h.mean == pytest.approx(5.605 / 5)
        assert h.percentile(50) == 0.05
        snap = h.snapshot()
        # cumulative le-buckets: 0.005 | +2x0.05 | +0.5 (the 5.0 overflows)
        assert snap["buckets"] == {"0.01": 1, "0.1": 3, "1": 4}
        assert snap["p50"] == 0.05

    def test_histogram_time_context(self):
        reg = MetricsRegistry()
        with reg.timer("block_seconds"):
            pass
        h = reg.histogram("block_seconds")
        assert h.count == 1 and h.sum >= 0.0

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x_total")
        with pytest.raises(TypeError):
            reg.gauge("x_total")

    def test_snapshot_schema_and_to_json(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("a_total").inc()
        reg.gauge("b").set(2)
        reg.histogram("c").observe(0.5)
        snap = reg.snapshot()
        assert snap["a_total"] == {"type": "counter", "value": 1.0}
        assert snap["b"] == {"type": "gauge", "value": 2.0}
        assert snap["c"]["type"] == "histogram" and snap["c"]["count"] == 1
        path = tmp_path / "m.json"
        reg.to_json(str(path))
        assert json.loads(path.read_text()) == json.loads(json.dumps(snap))

    def test_prometheus_text(self):
        reg = MetricsRegistry()
        reg.counter("req_total", "requests").inc(3)
        reg.histogram("lat", buckets=(0.1, 1.0)).observe(0.05)
        text = reg.prometheus_text()
        assert "# TYPE req_total counter" in text
        assert "req_total 3" in text
        assert '# TYPE lat histogram' in text
        assert 'lat_bucket{le="0.1"} 1' in text
        assert 'lat_bucket{le="+Inf"} 1' in text
        assert "lat_count 1" in text


class TestRecordFleetTelemetry:
    def test_active_weighted_means(self):
        # 4 slots, slot 2 vacant (mandated zeros): gauges must average
        # over ACTIVE slots only, occupancy over ALL slots
        reg = MetricsRegistry()
        tel = FleetTelemetry(
            spike_rate=jnp.array([0.2, 0.4, 0.0, 0.6], jnp.float32),
            mean_abs_dw=jnp.array([1e-3, 2e-3, 0.0, 3e-3], jnp.float32),
            sat_frac=jnp.array([0.1, 0.2, 0.0, 0.3], jnp.float32),
            occupancy=jnp.array([1.0, 1.0, 0.0, 1.0], jnp.float32))
        vals = record_fleet_telemetry(reg, tel)
        assert vals["fleet_spike_rate"] == pytest.approx(0.4)
        assert vals["fleet_mean_abs_dw"] == pytest.approx(2e-3)
        assert vals["fleet_sat_frac"] == pytest.approx(0.2, abs=1e-7)
        assert vals["fleet_occupancy"] == pytest.approx(0.75)
        assert reg.gauge("fleet_spike_rate").value == pytest.approx(0.4)

    def test_empty_fleet_is_zero(self):
        reg = MetricsRegistry()
        vals = record_fleet_telemetry(reg, FleetTelemetry.zeros(3),
                                      prefix="adapter")
        assert vals == {"adapter_spike_rate": 0.0,
                        "adapter_mean_abs_dw": 0.0,
                        "adapter_sat_frac": 0.0,
                        "adapter_occupancy": 0.0}


# ---------------------------------------------------------------------------
# engine telemetry: static-variant identity + numpy oracle + vacant zeros
# ---------------------------------------------------------------------------

def _fleet_fixture(datapath: str):
    quant = datapath == "int8"
    cfg = snn.SNNConfig(layer_sizes=SIZES, timesteps=K, plastic=True,
                        encoding="current",
                        trace_decay=0.75 if quant else 0.8,
                        quant=Q.QuantConfig() if quant else None)
    state = snn.init_state(cfg, batch=B, fleet=True)
    theta = snn.init_theta(cfg, jax.random.PRNGKey(1), scale=0.05)
    drives = jax.random.normal(jax.random.PRNGKey(2), (K, B, SIZES[0])) * 2.5
    active = jnp.array([1.0, 1.0, 0.0, 1.0])
    assert float(active[VACANT]) == 0.0
    return cfg, state, theta, drives, active


def _run_rollout(datapath, impl, telemetry):
    cfg, state, theta, drives, active = _fleet_fixture(datapath)
    qc = cfg.quant
    d = Q.to_fixed(drives, qc) if qc is not None else drives
    params = [cfg.engine_params(i) for i in range(cfg.num_layers)]
    return state, engine.rollout(state, list(theta), d, params=params,
                                 impl=impl, active=active,
                                 telemetry=telemetry)


class TestTelemetryStaticVariant:
    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("datapath", DATAPATHS)
    def test_off_path_bitwise_identical(self, impl, datapath):
        """telemetry=True must not perturb the computation: state and
        outputs are BITWISE equal to the telemetry=False run."""
        _, off = _run_rollout(datapath, impl, telemetry=False)
        _, on = _run_rollout(datapath, impl, telemetry=True)
        assert len(off) == 2 and len(on) == 3
        for a, b in zip(jax.tree.leaves((off[0], off[1])),
                        jax.tree.leaves((on[0], on[1]))):
            np.testing.assert_array_equal(_np(a), _np(b))

    @pytest.mark.parametrize("datapath", DATAPATHS)
    def test_backend_parity_and_vacant_zeros(self, datapath):
        """xla and pallas-interpret agree on every telemetry field, and the
        vacant slot reports exact zeros on both."""
        _, tx = _run_rollout(datapath, "xla", telemetry=True)
        _, tp = _run_rollout(datapath, "pallas-interpret", telemetry=True)
        for f in TEL_FIELDS:
            ax, ap = _np(getattr(tx[2], f)), _np(getattr(tp[2], f))
            assert ax.shape == (B,) and ax.dtype == np.float32
            np.testing.assert_allclose(ax, ap, atol=2e-4, err_msg=f)
            assert ax[VACANT] == 0.0 and ap[VACANT] == 0.0
        # the fixture drives hard enough that active slots actually spike —
        # an all-zero parity pass would prove nothing
        assert _np(tx[2].spike_rate)[0] > 0.0
        np.testing.assert_array_equal(_np(tx[2].occupancy),
                                      [1.0, 1.0, 0.0, 1.0])

    def test_layer_step_matches_numpy_oracle(self):
        """One float fleet layer step on the oracle backend: telemetry
        re-derived in numpy from the step's own inputs/outputs."""
        cfg, state, theta, drives, active = _fleet_fixture("float32")
        layer = engine.LayerState(
            w=state.w[0], v=state.v[0], trace_pre=state.trace[0],
            trace_post=state.trace[1], theta=theta[0], w_scale=None)
        p = cfg.engine_params(0)
        new, out, tel = engine.layer_step(layer, drives[0], params=p,
                                          impl="xla", active=active,
                                          telemetry=True)
        spikes, v, w0, w1 = _np(out), _np(new.v), _np(layer.w), _np(new.w)
        act, m = _np(active), SIZES[1]
        np.testing.assert_allclose(
            _np(tel.spike_rate),
            np.abs(spikes).sum(1) / m * act, atol=1e-6)
        np.testing.assert_allclose(
            _np(tel.mean_abs_dw),
            np.abs(w1 - w0).sum((1, 2)) / (SIZES[0] * m) * act, atol=1e-6)
        np.testing.assert_allclose(
            _np(tel.sat_frac),
            (np.abs(v) >= SAT_FRACTION * p.v_th).sum(1) / m * act,
            atol=1e-6)
        np.testing.assert_array_equal(_np(tel.occupancy), act)

    @pytest.mark.parametrize("datapath", DATAPATHS)
    def test_rollout_dw_is_net_window_motion(self, datapath):
        """Windowed mean_abs_dw is the NET weight motion over the window,
        sum_i |w_end - w_start| / (N_i*M_i), / (K * n_plastic) — checked
        in numpy against the rollout's own weight endpoints."""
        state, (new_state, _, tel) = _run_rollout(datapath, "xla",
                                                  telemetry=True)
        qc = Q.QuantConfig() if datapath == "int8" else None
        plast = [0, 1]               # both layers plastic in the fixture
        dw = np.zeros(B)
        for i in plast:
            a, b = _np(state.w[i]), _np(new_state.w[i])
            d = np.abs(b.astype(np.int64) - a.astype(np.int64)) \
                if qc is not None else np.abs(b - a)
            per_slot = d.sum((1, 2)).astype(np.float64)
            if qc is not None:
                per_slot = per_slot * _np(state.w_scale[i]).reshape(-1)
            dw += per_slot / (a.shape[-2] * a.shape[-1])
        dw /= K * len(plast)
        dw[VACANT] = 0.0
        np.testing.assert_allclose(_np(tel.mean_abs_dw), dw, atol=2e-6)


class TestAdapterTelemetry:
    def _caches(self, b=3, n=4, decay=0.8):
        rng = np.random.default_rng(0)
        tr2 = rng.uniform(0.1, 0.9, (b, n)).astype(np.float32)
        s2 = (rng.random((b, n)) < 0.5).astype(np.float32)  # this step's events
        w0 = rng.standard_normal((b, n, n)).astype(np.float32)
        dw = rng.standard_normal((b, n, n)).astype(np.float32) * 1e-3
        before = {"tr2": jnp.asarray(tr2), "w_fast": jnp.asarray(w0),
                  "v2": jnp.zeros((b, n), jnp.float32)}
        after = {"tr2": jnp.asarray(decay * tr2 + s2),
                 "w_fast": jnp.asarray(w0 + dw),
                 "v2": jnp.asarray(
                     np.array([[0.95, 0.1, -0.92, 0.0]] * b, np.float32))}
        return before, after, s2, dw

    def test_exact_event_recovery(self):
        """tr2' = decay*tr2 + s2  =>  the recovered event vector equals s2
        exactly, |dw| comes off the w_fast delta, sat off v2."""
        before, after, s2, dw = self._caches()
        tel = adapter_telemetry(before, after, jnp.ones(3))
        np.testing.assert_allclose(_np(tel.spike_rate),
                                   np.abs(s2).mean(1), atol=1e-6)
        np.testing.assert_allclose(_np(tel.mean_abs_dw),
                                   np.abs(dw).sum((1, 2)) / 16, atol=1e-7)
        # v2 rows are [0.95, 0.1, -0.92, 0.0]: two of four >= 0.9*v_th
        np.testing.assert_allclose(_np(tel.sat_frac), [0.5] * 3)

    def test_inactive_slots_report_zeros(self):
        """Gating by `active` kills the phantom (1-decay)*tr2 event a
        frozen slot's unchanged trace would otherwise 'recover'."""
        before, _, _, _ = self._caches()
        frozen = {k: v for k, v in before.items()}   # no step happened
        tel = adapter_telemetry(before, frozen, jnp.array([1.0, 0.0, 0.0]))
        for f in TEL_FIELDS:
            arr = _np(getattr(tel, f))
            assert arr[1] == 0.0 and arr[2] == 0.0, f
        # ...and the active slot DOES see the phantom — proof the gate, not
        # the math, is what protects vacant slots
        assert _np(tel.spike_rate)[0] > 0.0


# ---------------------------------------------------------------------------
# scheduler integration: compile audit + recorded gauges
# ---------------------------------------------------------------------------

def _sched(impl="xla", slots=3):
    cfg = snn.SNNConfig(layer_sizes=(8, 12, 4), timesteps=3, plastic=True,
                        encoding="current", impl=impl)
    theta = snn.init_theta(cfg, jax.random.PRNGKey(0), scale=0.05)
    return FleetScheduler(cfg, theta, slots=slots)


class TestSchedulerObs:
    def test_compiled_programs_audit(self):
        """Every jitted entry point is named; telemetry variants register
        up-front at 0 executables and grow to exactly 1 when used."""
        sched = _sched()
        progs = sched.compiled_programs()
        assert set(progs) == {"slot_put", "slot_take", "recorder_reset",
                              "pool_step", "pool_rollout",
                              "pool_step_telemetry",
                              "pool_rollout_telemetry",
                              "pool_step_record", "pool_rollout_record"}
        assert progs["pool_step_telemetry"] == 0
        sched.admit("u0")
        drives = {"u0": np.ones(8, np.float32)}
        sched.step(drives)
        sched.step(drives, telemetry=True)
        sched.step(drives, telemetry=True)      # cached, must not grow
        progs = sched.compiled_programs()
        assert progs["pool_step"] == 1
        assert progs["pool_step_telemetry"] == 1
        assert sched.compile_count() == sum(progs.values())

    def test_step_telemetry_records_gauges(self):
        sched = _sched(slots=4)
        for u in ("u0", "u1"):
            sched.admit(u)
        drives = {u: np.ones(8, np.float32) * 2.0
                  for u in sched.active_users}
        outs, tel = sched.step(drives, telemetry=True)
        assert set(outs) == {"u0", "u1"}
        assert _np(tel.occupancy).tolist() == [1.0, 1.0, 0.0, 0.0]
        snap = sched.metrics.snapshot()
        assert snap["fleet_occupancy"]["value"] == pytest.approx(0.5)
        for name in ("fleet_spike_rate", "fleet_mean_abs_dw",
                     "fleet_sat_frac"):
            assert name in snap
        # off-path step returns the plain dict (no tuple)
        assert set(sched.step(drives)) == {"u0", "u1"}

    def test_pool_lifecycle_counters(self):
        sched = _sched()
        sched.admit("a")
        sched.admit("b")
        sched.evict("a")
        snap = sched.metrics.snapshot()
        assert snap["pool_admissions_total"]["value"] == 2
        assert snap["pool_evictions_total"]["value"] == 1
        assert snap["pool_occupancy"]["value"] == pytest.approx(1 / 3)
        assert snap["pool_admit_seconds"]["count"] == 2


class TestSessionStoreMetrics:
    def test_counters_are_the_source_of_truth(self, tmp_path):
        """warm_hits/restores/creates/persists read through to the obs
        counters, and reconcile with the admission/eviction event log."""
        store = SessionStore(root=str(tmp_path), capacity=1)
        sched = _sched()
        sched2 = FleetScheduler(sched.cfg, sched.theta, slots=3, store=store)
        sched2.admit("u0")          # create
        sched2.admit("u1")          # create
        sched2.evict("u0")          # persist (capacity-1 cache keeps u0)
        sched2.evict("u1")          # persist (evicts u0 from warm cache)
        sched2.admit("u0")          # fell out of warm cache -> disk restore
        sched2.admit("u1")          # warm hit
        assert (store.creates, store.persists) == (2, 2)
        assert (store.restores, store.warm_hits) == (1, 1)
        snap = store.metrics.snapshot()
        assert snap["session_store_creates_total"]["value"] == 2
        assert snap["session_store_persists_total"]["value"] == 2
        assert snap["session_store_restores_total"]["value"] == 1
        assert snap["session_store_warm_hits_total"]["value"] == 1
        checkouts = sum(snap[f"session_store_{k}_total"]["value"]
                        for k in ("warm_hits", "restores", "creates"))
        assert checkouts == 4       # == admissions
        assert snap["session_store_checkout_seconds"]["count"] == 4
        assert snap["session_store_persist_seconds"]["count"] == 2


# ---------------------------------------------------------------------------
# recompile watchdog
# ---------------------------------------------------------------------------

class TestWatchdog:
    def test_armed_compile_is_a_violation_with_name(self):
        reg = MetricsRegistry()
        w = watch.install(reg)
        assert watch.install(reg) is w      # idempotent singleton
        x = jnp.ones(7)                      # constants compiled UNARMED
        w.reset()
        jax.jit(lambda a: a * 2.0 + 1.0)(x)  # unarmed: counted, no flag
        assert w.compiles >= 1 and w.violations == 0
        base = w.compiles
        with w.armed():
            assert w.is_armed
            jax.jit(lambda a: a * 3.0 - 2.0)(x)
        assert not w.is_armed
        assert w.compiles > base
        assert w.violations >= 1
        assert any("lambda" in s for s in w.violation_signatures)
        snap = reg.snapshot()
        assert snap["recompiles_after_warmup_total"]["value"] \
            == w.violations
        w.reset()
        assert (w.compiles, w.violations, w.violation_signatures) \
            == (0, 0, [])

    def test_cached_executions_never_fire(self):
        w = watch.install()
        f = jax.jit(lambda a: a + 1)
        x = jnp.ones(5)
        f(x)                                 # compile unarmed
        w.reset()
        with w.armed():
            for _ in range(3):
                f(x)                         # cache hits
        assert w.violations == 0


# ---------------------------------------------------------------------------
# LM adapter telemetry (the cache-delta route)
# ---------------------------------------------------------------------------

class TestLMAdapterTelemetry:
    @pytest.mark.parametrize("datapath", DATAPATHS)
    def test_step_and_window_telemetry(self, datapath):
        from repro.models import factory
        from repro.serving import LMScheduler

        cfg = factory.build("qwen3-4b", smoke=True).cfg.with_(
            plastic_adapter=True, adapter_neurons=8, adapter_impl="xla",
            adapter_quant=(datapath == "int8"))
        model = factory.build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        params["adapter"]["scale"] = jnp.float32(0.5)
        sched = LMScheduler(model, params, slots=3, max_len=16)
        rng = np.random.RandomState(0)
        sched.admit_prompt("u", rng.randint(0, cfg.vocab, 5).astype(np.int32))

        toks, tel = sched.step(telemetry=True)
        assert set(toks) == {"u"}
        for f in TEL_FIELDS:
            arr = _np(getattr(tel, f))
            assert arr.shape == (3,) and arr.dtype == np.float32
            assert arr[1] == 0.0 and arr[2] == 0.0, f"{f}: vacant leaked"
        np.testing.assert_array_equal(_np(tel.occupancy), [1.0, 0.0, 0.0])
        snap = sched.metrics.snapshot()
        assert snap["adapter_occupancy"]["value"] == pytest.approx(1 / 3)
        assert "adapter_spike_rate" in snap

        win = np.full((2,), sched.pending("u"), np.int32)
        out, wtel = sched.decode_window({"u": win}, telemetry=True)
        assert out["u"].shape == (2, cfg.vocab)
        assert _np(wtel.occupancy)[0] == 1.0
        # telemetry audit entries exist even for the unused variants
        progs = sched.compiled_programs()
        assert progs["decode_step_telemetry"] == 1
        assert progs["decode_window_telemetry"] == 1

    def test_telemetry_requires_plastic_adapter(self):
        from repro.models import factory
        from repro.serving import LMScheduler

        cfg = factory.build("qwen3-4b", smoke=True).cfg
        assert not cfg.plastic_adapter
        model = factory.build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        sched = LMScheduler(model, params, slots=2, max_len=16)
        sched.admit_prompt("u", np.arange(4, dtype=np.int32))
        with pytest.raises(ValueError, match="plastic_adapter"):
            sched.step(telemetry=True)
