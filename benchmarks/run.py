"""Benchmark entry point: one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick | --smoke] [--check]

  adaptation        Fig. 3   plasticity vs weight-trained generalization
  engine_breakdown  Table I  per-engine FLOPs/bytes/roofline latency
  mnist_throughput  Table II pipelined fwd+learn FPS methodology
  latency           8 us     controller end-to-end latency analogue
  fleet_throughput  serving  native batched-weights launch vs vmap recipe
  serving_churn     serving  session churn into a fixed slot pool (pinned
                             zero recompiles + evict/restore bit-equality)
  serving_lm        serving  plastic LM decode pool under churn: layout x
                             backend x adapter datapath, tokens/s +
                             windowed rollout path (pinned zero recompiles,
                             mid-generation evict/re-admit bit-identity,
                             vacant-slot freeze)
  quant_parity      fixed-pt float-vs-quant control parity + int8 pool bytes
                             (asserted bounds; bit-equal across backends)
  rollout_fused     perf     time-fused rollout megakernel vs per-step
                             launches, K-sweep x float/int8 datapaths
                             (parity gate: quant bitwise, float <= 1e-6)
  robustness        scenario  closed-loop adaptation sweep: scenario x
                             backend x datapath, plastic vs frozen (gate
                             scenarios asserted: recovery >= 0.5 plastic,
                             <= 0.25 frozen, one compile per cell)
  obs_overhead      obs      fleet telemetry cost gate: <= 5% throughput
                             overhead at B=256, exactly one extra program
                             per entry point, watchdog-silent churn
  obs_health        obs      session-health gate: every detector catches
                             its injected fault, zero false positives on
                             clean churn (fleet + LM), recorder overhead
                             <= 5% at B=256, one program per record variant
  roofline          Roofline table from the dry-run artifacts (if present)

``--check`` is the bench DRIFT GATE (CI): after the run, every checked-in
``benchmarks/results/<name>.json`` must have a freshly-written counterpart
(``<name>_smoke.json`` under --smoke, or an overwritten canonical file)
whose SCHEMA covers the checked-in one — recursive key paths plus backend
(``impl``/``impls``) coverage, never timings.  A bench that silently stops
producing a cell (a dropped key, a lost backend, a bench that stopped
writing at all) fails CI instead of rotting unnoticed.
"""
from __future__ import annotations

import glob
import json
import os
import sys
import time

RESULTS = os.path.join(os.path.dirname(__file__), "results")


# ---- drift gate ------------------------------------------------------------

def _schema_paths(obj, prefix=""):
    """Recursive key paths of a JSON document; list elements merge under
    '[]' so a sweep's schema is the union of its rows' keys."""
    paths = set()
    if isinstance(obj, dict):
        for k, v in obj.items():
            p = f"{prefix}.{k}" if prefix else str(k)
            paths.add(p)
            paths |= _schema_paths(v, p)
    elif isinstance(obj, list):
        for el in obj:
            paths |= _schema_paths(el, prefix + "[]")
    return paths


def _coverage_values(obj, keys):
    """Coverage cells: every scalar value reachable under one of `keys`
    (e.g. backend names under 'impl'/'impls', scenario names under
    'scenario'/'scenarios').  Non-scalar values under those keys are
    recursed into like any other node."""
    found = set()
    if isinstance(obj, dict):
        for k, v in obj.items():
            vals = v if isinstance(v, list) else [v]
            if k in keys and all(isinstance(x, (str, int, float))
                                 for x in vals):
                found |= {str(x) for x in vals}
            else:
                found |= _coverage_values(v, keys)
    elif isinstance(obj, list):
        for el in obj:
            found |= _coverage_values(el, keys)
    return found


# Coverage dimensions: every sweep axis the gate protects, by NAME, so a
# failure says which dimension lost cells (not just that "something" did).
# Each entry: dimension -> the JSON keys whose scalar values enumerate its
# cells.  Adding a protected axis = adding one row here.
_DIMENSIONS = {
    # engine backend: xla oracle / pallas / pallas-interpret
    "impl": ("impl", "impls"),
    # scenario sweeps (robustness/adaptation): a sweep that silently loses
    # a scenario row fails the gate like a lost backend
    "scenario": ("scenario", "scenarios", "gate_scenarios"),
    # numeric datapath: float32 vs int8 — both cells must keep appearing
    "datapath": ("datapath", "datapaths", "mode"),
    # LM backbone family: dense GQA, Mamba2 SSM, MoE, hybrids
    "layout": ("layout", "layouts"),
    # sharded-pool device counts (fleet_throughput's device sweep): a
    # sweep that silently drops a D cell fails like a lost backend — the
    # smoke sweep must force the same counts the checked-in artifact has
    "devices": ("devices", "device_counts"),
    # session-health detectors (obs_health's detection table): a detector
    # whose injected-fault row silently disappears fails the gate
    "detector": ("detector", "detectors"),
}


def check_drift(reference: dict, started_at: float) -> list:
    """Compare fresh smoke outputs against the checked-in result schemas.

    `reference` maps canonical stem -> parsed checked-in JSON (snapshotted
    BEFORE the benches ran — quick-mode benches overwrite their canonical
    files in place).  Returns a list of human-readable failures: each
    names the exact key paths that went missing and, per `_DIMENSIONS`
    axis, exactly which coverage cells were lost.  EXTRA fresh paths
    (cells the checked-in artifact has never seen) are reported too — as
    a notice, not a failure — so a bench growing new cells is visible in
    the gate output before the canonical result is re-checked in.
    """
    failures = []
    for stem, ref in sorted(reference.items()):
        fresh = None
        # smoke runs write <stem>_smoke.json, capped full runs (the
        # harness's --max-batch/--steps bounds) write <stem>_capped.json,
        # quick-mode benches overwrite the canonical file in place
        for cand in (os.path.join(RESULTS, f"{stem}_smoke.json"),
                     os.path.join(RESULTS, f"{stem}_capped.json"),
                     os.path.join(RESULTS, f"{stem}.json")):
            if (os.path.exists(cand)
                    and os.path.getmtime(cand) >= started_at):
                with open(cand) as f:
                    fresh = json.load(f)
                break
        if fresh is None:
            failures.append(
                f"{stem}: no fresh output (expected {stem}_smoke.json or an "
                f"overwritten {stem}.json) — the bench stopped writing "
                "results")
            continue
        ref_paths, fresh_paths = _schema_paths(ref), _schema_paths(fresh)
        missing = ref_paths - fresh_paths
        if missing:
            failures.append(
                f"{stem}: schema key paths missing from the fresh output: "
                f"{sorted(missing)}")
        extra = fresh_paths - ref_paths
        if extra:
            print(f"NOTE: {stem}: fresh output has key paths not in the "
                  f"checked-in result (re-check it in to protect them): "
                  f"{sorted(extra)}")
        for dim, keys in _DIMENSIONS.items():
            lost = _coverage_values(ref, keys) - _coverage_values(fresh, keys)
            if lost:
                failures.append(
                    f"{stem}: coverage dimension {dim!r} lost cells: "
                    f"{sorted(lost)}")
    return failures


def _reference_results() -> dict:
    ref = {}
    for path in glob.glob(os.path.join(RESULTS, "*.json")):
        stem = os.path.splitext(os.path.basename(path))[0]
        if stem.endswith("_smoke") or "_smoke_" in stem or \
                stem.endswith("_capped"):
            continue
        with open(path) as f:
            ref[stem] = json.load(f)
    return ref


# ---- harness ---------------------------------------------------------------

def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv or "--smoke" in argv
    check = "--check" in argv
    reference = _reference_results() if check else {}
    t0 = time.time()
    failures = []

    from benchmarks import (adaptation, engine_breakdown, fleet_throughput,
                            latency, mnist_throughput, obs_health,
                            obs_overhead, quant_parity, robustness,
                            rollout_fused, roofline, serving_churn,
                            serving_lm)

    for name, fn in (
        ("engine_breakdown", lambda: engine_breakdown.main(quick=quick)),
        # latency's checked-in artifact validates the TPU program (the
        # canonical results/latency.json is impl=pallas-interpret); the
        # harness runs the same backend so the drift gate's coverage check
        # compares like with like.
        ("latency",
         lambda: latency.main(quick=quick, impl="pallas-interpret")),
        ("mnist_throughput", lambda: mnist_throughput.main(quick=quick)),
        ("adaptation", lambda: adaptation.main(quick=quick)),
        ("fleet_throughput",
         lambda: fleet_throughput.main(
             ["--smoke"] if quick else ["--max-batch", "256"])),
        ("serving_churn",
         lambda: serving_churn.main(
             ["--smoke"] if quick else ["--steps", "100"])),
        ("serving_lm",
         lambda: serving_lm.main(["--smoke"] if quick else [])),
        ("quant_parity",
         lambda: quant_parity.main(["--smoke"] if quick else [])),
        ("rollout_fused",
         lambda: rollout_fused.main(["--smoke"] if quick else [])),
        ("robustness",
         lambda: robustness.main(["--smoke"] if quick else [])),
        ("obs_overhead",
         lambda: obs_overhead.main(["--smoke"] if quick else [])),
        ("obs_health",
         lambda: obs_health.main(["--smoke"] if quick else [])),
        ("roofline_single", lambda: roofline.main(["--mesh", "single"])),
        ("roofline_multi", lambda: roofline.main(["--mesh", "multi"])),
    ):
        print(f"\n===== {name} =====")
        try:
            rc = fn()
            # benches with asserted bounds return an int exit code; the
            # older harnesses return their results dict (not a failure)
            if isinstance(rc, int) and rc:
                failures.append((name, f"exit code {rc}"))
        except Exception as e:  # keep the harness running; report at end
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))

    if check:
        print("\n===== drift gate =====")
        drift = check_drift(reference, t0)
        for msg in drift:
            print("DRIFT:", msg)
        if not drift:
            print(f"all {len(reference)} checked-in result schemas covered "
                  "by fresh outputs")
        failures += [("drift-gate", m) for m in drift]

    print(f"\nbenchmarks done in {time.time() - t0:.0f}s; "
          f"{len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
