"""Jit'd public wrapper for the Forward Engine kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.lif import kernel as _kernel
from repro.kernels.lif import ref as _ref


@functools.partial(
    jax.jit,
    static_argnames=("tau_m", "v_th", "v_reset", "trace_decay", "impl",
                     "interpret", "block_m", "block_k"))
def lif_forward(x, w, v, trace, *, tau_m: float = 2.0, v_th: float = 1.0,
                v_reset: float = 0.0, trace_decay: float = 0.8,
                impl: str = "xla", interpret: bool = False,
                block_m: int = 128, block_k: int = 128):
    kw = dict(tau_m=tau_m, v_th=v_th, v_reset=v_reset, trace_decay=trace_decay)
    if impl == "pallas":
        return _kernel.lif_forward_pallas(
            x, w, v, trace, block_m=block_m, block_k=block_k,
            interpret=interpret, **kw)
    return _ref.lif_forward(x, w, v, trace, **kw)
