"""Unit + property tests for the four-term plasticity rule (paper Sec. II-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import plasticity as P


def _theta(key, n_pre, n_post, scale=0.1):
    return scale * jax.random.normal(key, (P.NUM_TERMS, n_pre, n_post))


class TestTrace:
    def test_update_matches_formula(self):
        tr = jnp.array([0.5, 1.0, 0.0])
        s = jnp.array([1.0, 0.0, 1.0])
        out = P.update_trace(tr, s, 0.8)
        np.testing.assert_allclose(out, [1.4, 0.8, 1.0], rtol=1e-6)

    @given(lam=st.floats(0.0, 0.99), steps=st.integers(1, 50))
    @settings(max_examples=25, deadline=None)
    def test_trace_bounded(self, lam, steps):
        """S(t) <= 1/(1-lam) for binary spikes — no unbounded growth."""
        tr = jnp.zeros(())
        for _ in range(steps):
            tr = P.update_trace(tr, jnp.ones(()), lam)
        assert float(tr) <= 1.0 / (1.0 - lam) + 1e-4


class TestDeltaW:
    def test_four_terms_decompose(self):
        """dw == alpha-term + beta-term + gamma-term + delta-term exactly."""
        key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        th = _theta(k1, 4, 3)
        sp = jax.random.uniform(k2, (4,))
        so = jax.random.uniform(k3, (3,))
        dw = P.delta_w(th, sp, so)
        expect = (th[P.ALPHA] * np.outer(sp, so)
                  + th[P.BETA] * np.asarray(sp)[:, None]
                  + th[P.GAMMA] * np.asarray(so)[None, :]
                  + th[P.DELTA])
        np.testing.assert_allclose(np.asarray(dw), expect, rtol=1e-5,
                                   atol=1e-6)

    def test_zero_traces_leave_only_decay(self):
        th = _theta(jax.random.PRNGKey(1), 5, 2)
        dw = P.delta_w(th, jnp.zeros((5,)), jnp.zeros((2,)))
        np.testing.assert_allclose(np.asarray(dw), np.asarray(th[P.DELTA]),
                                   atol=1e-7)

    def test_batch_averaging(self):
        """Batched traces average: dw(batch) == mean over per-sample dw."""
        key = jax.random.PRNGKey(2)
        th = _theta(key, 3, 3)
        sp = jax.random.uniform(jax.random.fold_in(key, 1), (8, 3))
        so = jax.random.uniform(jax.random.fold_in(key, 2), (8, 3))
        batched = P.delta_w(th, sp, so)
        per = jnp.stack([P.delta_w(th, sp[i], so[i]) for i in range(8)])
        np.testing.assert_allclose(np.asarray(batched),
                                   np.asarray(per.mean(0)), rtol=1e-4,
                                   atol=1e-6)

    @given(st.integers(1, 16), st.integers(1, 16))
    @settings(max_examples=15, deadline=None)
    def test_shapes(self, n_pre, n_post):
        th = _theta(jax.random.PRNGKey(3), n_pre, n_post)
        dw = P.delta_w(th, jnp.ones((n_pre,)), jnp.ones((n_post,)))
        assert dw.shape == (n_pre, n_post)

    def test_linearity_in_theta(self):
        """dw is linear in theta (it is literally a contraction)."""
        key = jax.random.PRNGKey(4)
        th1, th2 = _theta(key, 4, 4), _theta(jax.random.fold_in(key, 1), 4, 4)
        sp = jax.random.uniform(jax.random.fold_in(key, 2), (4,))
        so = jax.random.uniform(jax.random.fold_in(key, 3), (4,))
        lhs = P.delta_w(th1 + 2.0 * th2, sp, so)
        rhs = P.delta_w(th1, sp, so) + 2.0 * P.delta_w(th2, sp, so)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-4, atol=1e-5)


class TestApply:
    def test_clip_bounds_weights(self):
        cfg = P.PlasticityConfig(n_pre=2, n_post=2, w_clip=1.0)
        th = 100.0 * jnp.ones((P.NUM_TERMS, 2, 2))
        w = jnp.zeros((2, 2))
        for _ in range(5):
            w = P.apply_plasticity(w, th, jnp.ones((2,)), jnp.ones((2,)), cfg)
        assert float(jnp.abs(w).max()) <= 1.0 + 1e-6

    def test_spikify_binary(self):
        x = jnp.array([-1.0, 0.0, 0.5, 2.0])
        s = P.spikify(x)
        np.testing.assert_array_equal(np.asarray(s), [0, 0, 1, 1])
