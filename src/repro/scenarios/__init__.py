"""Scenario engine: vectorized envs, perturbation schedules, closed-loop
fleet adaptation (the ROADMAP's "as many scenarios as you can imagine" axis).

Three layers:

  * `vector_env.VectorEnv` — struct-of-arrays wrapper stepping B
    independent env instances (per-slot keys, tasks, actuator masks, AND
    dynamics parameters) as one jitted program.
  * `perturb` — composable `Perturbation` specs (actuator dropout, sensor
    noise/bias, dynamics-parameter shifts, goal switches) compiled to pure
    array `Schedule`s: domain randomization as data, applied inside a scan
    with zero recompiles.
  * `harness.make_closed_loop` — B envs against B plastic controllers
    through the engine's fleet path in a single `lax.scan`, float32 or
    quantized, on any engine backend, with a freeze-step operand for the
    plasticity-vs-frozen ablation; `metrics.adaptation_metrics` turns the
    reward streams into the paper's adaptation numbers.

`presets.SCENARIOS` names the checked-in robustness scenarios;
`presets.reference_rule` the deterministic adaptive rule tests assert the
paper's recovery claim with (see benchmarks/robustness.py).
"""
from repro.scenarios.vector_env import VectorEnv, VecEnvState
from repro.scenarios.perturb import (ActuatorDropout, GoalSwitch, ParamShift,
                                     Perturbation, Schedule, SensorNoise,
                                     compile_schedule, empty_schedule)
from repro.scenarios.harness import (ANOMALIES, AnomalyPreset, ClosedLoop,
                                     RolloutResult, inject_anomaly,
                                     make_closed_loop, run_closed_loop)
from repro.scenarios.metrics import adaptation_metrics, ablation_summary
from repro.scenarios.presets import (GATE_SCENARIOS, SCENARIOS, ScenarioSpec,
                                     controller_config, reference_rule)
