"""Logical -> physical sharding vocabulary.

Models annotate params/activations with LOGICAL axes:

  "data"   — batch-like; maps to ("pod", "data") on a multi-pod mesh so the
             global batch shards across pods transparently
  "model"  — tensor-parallel axis (heads / ffn / vocab / experts)
  "seq"    — sequence/context-parallel; rides the data axes (long-context
             cells with tiny batch shard sequence instead of batch)
  None     — replicated

The same model code therefore lowers unchanged on (data, model) and
(pod, data, model) meshes — the pod axis is purely a launch-layer concern.

The active mesh is process-global (set by the launcher / dry-run); model
code only ever names logical axes.  Without an active mesh every constraint
is a no-op, so unit tests run the identical code on one CPU device.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


class use_mesh:
    """Context manager: `with sharding.use_mesh(mesh): ...`"""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        self.prev = get_mesh()
        set_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_mesh(self.prev)
        return False


def _axis(mesh: Mesh, logical):
    if logical is None:
        return None
    if isinstance(logical, (tuple, list)):
        out = []
        for a in logical:
            m = _axis(mesh, a)
            if m is None:
                continue
            out.extend(m if isinstance(m, tuple) else (m,))
        return tuple(out) if out else None
    if logical in ("data", "seq"):
        return ("pod", "data") if "pod" in mesh.axis_names else "data"
    if logical == "model":
        return "model"
    raise ValueError(f"unknown logical axis {logical!r}")


def _axis_size(mesh: Mesh, phys) -> int:
    if phys is None:
        return 1
    if isinstance(phys, tuple):
        n = 1
        for a in phys:
            n *= mesh.shape[a]
        return n
    return mesh.shape[phys]


def logical_to_physical(mesh: Mesh, spec, shape=None) -> P:
    """spec: tuple/list of logical axis names.

    Two shape-aware fallbacks keep every arch/mesh combination lowerable:

    * divisibility — axes that do not evenly divide the corresponding
      dimension are dropped (GSPMD rejects uneven input shardings), e.g. a
      40-head or 50280-vocab dim over a 16-way model axis replicates.
    * dedup — a physical mesh axis may shard at most one dim; the first
      (shape-valid) claimant wins and later duplicates are dropped.  This
      lets plans list a PREFERENCE ORDER, e.g. MoE expert weights
      ("model", "data", "model"): expert-parallel when num_experts divides
      the axis (deepseek 64e), falling back to ffn-sharding when it does
      not (grok 8e on a 16-way axis).
    """
    phys = [_axis(mesh, s) for s in tuple(spec)]
    if shape is not None:
        phys = [p if dim % _axis_size(mesh, p) == 0 else None
                for p, dim in zip(phys, shape)]
    used: set = set()
    out = []
    for i, p in enumerate(phys):
        if p is None:
            out.append(None)
            continue
        axes = p if isinstance(p, tuple) else (p,)
        kept = tuple(a for a in axes if a not in used)
        if shape is not None:
            while kept and shape[i] % _axis_size(mesh, kept) != 0:
                kept = kept[:-1]
        if not kept:
            out.append(None)
            continue
        used.update(kept)
        out.append(kept if len(kept) > 1 else kept[0])
    return P(*out)


def named_sharding(mesh: Mesh, spec, shape=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_physical(mesh, spec, shape))


# ---- session-pool placement (the serving fleet) ----------------------------

def fleet_mesh(num_devices: Optional[int] = None) -> Mesh:
    """A 1-D device mesh over the logical ``"data"`` axis for session pools.

    The serving schedulers shard their slot pools over this axis: B slots on
    D devices = B/D resident sessions per device, every slot row whole on
    exactly one device (slot rows are mutually independent, so the placement
    is pure data parallelism — no cross-device collectives in the hot path).
    ``num_devices`` defaults to every local device; CI forces D with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    import numpy as np
    devs = jax.devices()
    d = len(devs) if num_devices is None else int(num_devices)
    if d < 1 or d > len(devs):
        raise ValueError(
            f"fleet_mesh needs 1 <= num_devices <= {len(devs)} (visible "
            f"devices), got {num_devices}")
    return Mesh(np.array(devs[:d]), ("data",))


def slot_pspec(axis, name: str = "data") -> P:
    """PartitionSpec placing a pool leaf's slot `axis` on mesh axis `name`.

    `axis` is an int (the dimension carrying slot rows) or any non-int
    sentinel (`serving.scheduler.SHARED` / None) meaning the leaf is pool-
    global and replicated."""
    if isinstance(axis, bool) or not isinstance(axis, int):
        return P()
    return P(*((None,) * axis), name)


def pool_shardings(mesh: Mesh, axes, name: str = "data"):
    """NamedSharding pytree for a slot pool, from its slot-axes pytree.

    `axes` mirrors the pool structure (the same pytree `serving.scheduler.
    make_slot_ops` consumes): int leaves name the slot axis, anything else
    (the SHARED sentinel) marks pool-global replicated state.  This is the
    single source of truth for slot -> device placement: NamedSharding over
    a length-D ``"data"`` axis places slot s on device ``s * D // B``
    (contiguous blocks of B/D slots per device).
    """
    return jax.tree.map(
        lambda ax: NamedSharding(mesh, slot_pspec(ax, name)), axes)


def shard_constraint(x, spec):
    """with_sharding_constraint in logical axes; no-op without a mesh.

    Shape-aware: non-dividing axes are replicated instead of erroring, so
    the same model code serves every arch/mesh combination.
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, named_sharding(mesh, spec, x.shape))
