"""Batched serving driver: prefill + decode with KV/SSM caches, optional
FireFly-P plastic adapter (the paper's Phase-2 online adaptation running
inside an LM serving stack).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --batch 4 --prompt-len 32 --gen 16 --plastic [--plastic-impl pallas]

With --plastic every decode step runs the fused dual-engine program
(core.engine.layer_step) once per request stream; --plastic-impl picks the
backend ("xla" oracle, "pallas" TPU kernel, "pallas-interpret" validation).

With --session-dir the adapter's per-stream fast weights become SESSIONS
(repro.serving): each batch row is a named user admitted into a
`serving.AdapterPool` before decode and evicted (persisted) after —
re-running the driver with the same --session-dir resumes every user's
plastic memory bit-identically instead of re-zeroing it.  --adapter-quant
makes the pool FPGA-faithful fixed-point: int8 W_fast rows with per-user
scales and deterministic stochastic rounding keyed on each user's own step
counter.

The model lowers through `models.factory`, so any registered arch — dense
GQA, MoE, Mamba2 SSM, zamba hybrid — serves through the same driver.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.distributed import sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_decode_step, make_prefill
from repro.models import factory
from repro.obs import (AdapterFlightRecorder, HealthConfig, MetricsRegistry,
                       phase, serve_metrics)
from repro.obs import watchdog as _watchdog
from repro.serving import AdapterPool, SessionStore


def generate(cfg, params, prompts, max_len: int, gen: int,
             temperature: float = 0.0, seed: int = 0, adapters=None,
             registry=None, watch=None, metrics_json=None,
             metrics_interval: int = 0, flight=None):
    """Greedy/temperature sampling loop.  prompts (B, S) int32.

    Returns (tokens (B, gen), per-step latencies, final cache).  The decode
    step is AOT-compiled BEFORE the timed loop — historically the first
    iteration absorbed the jit compile, skewing decode_ms_p50/mean and
    tokens_per_s; all reported latencies are now steady-state.

    `adapters`: optional `serving.AdapterPool` whose admitted users are the
    batch rows (user b in slot b).  Its pool pytree REPLACES the fresh
    prefill cache's adapter entry, so each stream resumes its user's
    learned fast weights instead of starting from zero; after the loop the
    learned state flows back into the pool (the caller evicts to persist).

    `registry`: optional `obs.MetricsRegistry` — per-step decode latencies
    go into the ``serve_decode_seconds`` histogram and throughput into the
    ``serve_tokens_per_s`` gauge.  `watch`: optional `RecompileWatchdog`,
    ARMED only after loop iteration 0 (the decode step is AOT-compiled
    up-front, but the sampling helpers — argmax/categorical/fold_in — are
    tiny jitted programs that legitimately compile on first use inside the
    loop); from iteration 1 on, any backend compile is a violation.
    `metrics_json` + ``metrics_interval > 0``: dump a registry snapshot to
    that path every `metrics_interval` decode steps (and the caller dumps
    once more at exit).

    `flight`: optional `obs.AdapterFlightRecorder` (requires a plastic
    adapter in the cache).  Each decode step feeds the adapter state into
    the device-side ring + detectors.  The decode jit DONATES the cache,
    so the "before" view is a jitted materialized copy (`a + 0`) taken
    each step — aliasing the donated buffers would read freed memory.
    """
    prefill = jax.jit(make_prefill(cfg, max_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    m_decode = (registry.histogram("serve_decode_seconds",
                                   "per-token decode step latency")
                if registry is not None else None)
    with phase("serve.prefill"):
        logits, cache = prefill(params, prompts)
    if adapters is not None:
        # the pool IS the adapter state: one scheduler-admitted row per
        # batch stream (restored or fresh), installed wholesale — no
        # per-row scatter loop
        cache["adapter"] = adapters.pool
    key = jax.random.PRNGKey(seed)
    if flight is not None and "adapter" not in cache:
        raise ValueError("flight recording needs a plastic adapter in the "
                         "cache (cfg.plastic_adapter=True)")
    # decode donates `cache`, so the recorder's before-view must be a real
    # copy; `a + 0` materializes fresh buffers (compiles once, pre-arm)
    snap = jax.jit(lambda t: jax.tree.map(lambda a: a + jnp.zeros_like(a), t))
    outs, lats = [], []
    tok = _sample(logits, key, temperature)
    # Warm-up: compile against the real avals without consuming the (donated)
    # cache buffers or advancing the generation state; the loop calls the
    # compiled executable, so no iteration pays trace+compile.
    decode_c = decode.lower(params, cache, tok[:, None]).compile()
    if flight is not None and adapters is not None:
        # align the restored pool's layout with the decode step's OUTPUT
        # adapter: from iteration 1 on the loop feeds decode outputs back
        # in, so without this the flight snapshot's input shardings change
        # once after the first step and snap/_update re-lower post-arm
        _, out_cache_sh = decode_c.output_shardings
        cache["adapter"] = jax.device_put(cache["adapter"],
                                          out_cache_sh["adapter"])
    armed = False
    try:
        for i in range(gen):
            if i == 1 and watch is not None:
                watch.arm()
                armed = True
            outs.append(tok)
            before = snap(cache["adapter"]) if flight is not None else None
            t0 = time.perf_counter()
            with phase("serve.decode_step"):
                logits, cache = decode_c(params, cache, tok[:, None])
                logits.block_until_ready()
            dt = time.perf_counter() - t0
            lats.append(dt)
            if flight is not None:
                flight.observe(before, cache["adapter"])
            if m_decode is not None:
                m_decode.observe(dt)
            key = jax.random.fold_in(key, i)
            tok = _sample(logits, key, temperature)
            if (metrics_json and metrics_interval > 0 and registry is not None
                    and (i + 1) % metrics_interval == 0):
                registry.to_json(metrics_json)
    finally:
        if armed:
            watch.disarm()
    if registry is not None and lats:
        registry.gauge("serve_tokens_per_s",
                       "steady-state decode throughput (whole batch)"
                       ).set(prompts.shape[0] * len(lats) / sum(lats))
    if adapters is not None:
        # hand the learned rows back (the loop's donation consumed the
        # buffers the pool was holding)
        adapters.pool = cache["adapter"]
        adapters.advance_steps(gen)
    return jnp.stack(outs, axis=1), lats, cache


def _sample(logits, key, temperature):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--plastic", action="store_true",
                    help="attach the FireFly-P plastic adapter at decode")
    ap.add_argument("--plastic-impl", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"],
                    help="PlasticEngine backend for the adapter's fused "
                         "dual-engine step (pallas on TPU)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (2.3x decode memory-roofline win)")
    ap.add_argument("--adapter-quant", action="store_true",
                    help="with --plastic: fixed-point adapter pool (int8 "
                         "W_fast, per-user scales, int32 membranes/traces)")
    ap.add_argument("--session-dir", default=None,
                    help="with --plastic: durable per-user session store "
                         "for the adapter fast weights; each batch row is a "
                         "user whose learned W_fast persists across runs")
    ap.add_argument("--users", default=None,
                    help="comma-separated user ids for the batch rows "
                         "(default user0..user{B-1}); needs --session-dir")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-json", default=None,
                    help="write a metrics-registry JSON snapshot here "
                         "(final, plus periodic with --metrics-interval)")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    help="with --metrics-json: also dump every N decode "
                         "steps (0 = final snapshot only)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the metrics registry over HTTP on this "
                         "port for the run's duration (/metrics Prometheus "
                         "text, /metrics.json snapshot; 0 = ephemeral)")
    ap.add_argument("--flight-dir", default=None,
                    help="with --plastic: run the adapter flight recorder "
                         "over the decode loop and write one incident "
                         "bundle (JSON + NPZ ring dump) per flagged "
                         "stream into this directory")
    args = ap.parse_args(argv)
    if (args.session_dir or args.users) and not args.plastic:
        ap.error("--session-dir/--users require --plastic (sessions are "
                 "the adapter's fast-weight state)")
    if args.users and not args.session_dir:
        ap.error("--users names the rows of a durable session store; "
                 "pass --session-dir too")
    if args.adapter_quant and not args.plastic:
        ap.error("--adapter-quant quantizes the plastic adapter pool; "
                 "pass --plastic too")
    if args.flight_dir and not args.plastic:
        ap.error("--flight-dir records the plastic adapter's health "
                 "channels; pass --plastic too")

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.plastic:
        cfg = cfg.with_(plastic_adapter=True,
                        adapter_neurons=min(128, cfg.d_model),
                        adapter_impl=args.plastic_impl,
                        adapter_quant=args.adapter_quant)
    if args.kv_quant:
        cfg = cfg.with_(kv_quant=True)
    model = factory.build(cfg)
    mesh = make_local_mesh()
    max_len = args.prompt_len + args.gen

    with shd.use_mesh(mesh), mesh:
        params = model.init(jax.random.PRNGKey(args.seed))
        prompts = jax.random.randint(
            jax.random.PRNGKey(args.seed + 1),
            (args.batch, args.prompt_len), 0, cfg.vocab)
        if cfg.input_mode == "embeddings":
            prompts_in = jax.nn.one_hot(prompts % cfg.d_model, cfg.d_model,
                                        dtype=cfg.adtype)
        else:
            prompts_in = prompts

        registry = MetricsRegistry()
        watch = _watchdog.install(registry)
        watch.reset()
        metrics_server = None
        if args.metrics_port is not None:
            metrics_server = serve_metrics(registry, port=args.metrics_port)
        flight = None
        if args.flight_dir is not None:
            from repro.models import plastic as _plastic
            flight = AdapterFlightRecorder(
                HealthConfig(), slots=args.batch,
                qcfg=_plastic.QUANT if args.adapter_quant else None,
                mesh=mesh)
        store = users = pool = None
        if args.session_dir is not None:
            store = SessionStore(root=args.session_dir, capacity=args.batch,
                                 registry=registry)
            users = (args.users.split(",") if args.users
                     else [f"user{b}" for b in range(args.batch)])
            if len(users) != args.batch:
                raise SystemExit(f"--users needs exactly {args.batch} ids, "
                                 f"got {len(users)}")
            if len(set(users)) != len(users):
                raise SystemExit(
                    "--users ids must be unique: two rows sharing a session "
                    "would silently overwrite each other's learned state")
            # scheduler-admit path: user b lands in pool slot b (admission
            # fills free slots in order), restoring persisted fast weights
            # through the SessionStore's validated checkout
            pool = AdapterPool(cfg, slots=args.batch, store=store,
                               registry=registry)
            for u in users:
                pool.admit(u)

        toks, lats, cache = generate(cfg, params, prompts_in, max_len,
                                     args.gen, args.temperature, args.seed,
                                     adapters=pool, registry=registry,
                                     watch=watch,
                                     metrics_json=args.metrics_json,
                                     metrics_interval=args.metrics_interval,
                                     flight=flight)
        tokens_learned = None
        if pool is not None:
            tokens_learned = [int(pool._steps[pool.user_slot[u]])
                              for u in users]
            for u in users:         # evict = gather + write-through persist
                pool.evict(u)

    out = {
        "arch": cfg.name, "plastic": bool(cfg.plastic_adapter),
        "batch": args.batch, "generated": int(toks.shape[1]),
        "decode_ms_p50": sorted(lats)[len(lats) // 2] * 1e3,
        "decode_ms_mean": sum(lats) / len(lats) * 1e3,
        "tokens_per_s": args.batch * len(lats) / sum(lats),
        "recompiles_after_warmup": watch.violations,
    }
    if watch.violations:
        out["recompile_signatures"] = watch.violation_signatures
    if store is not None:
        out["sessions"] = {
            "users": users, "resumed": store.restores,
            "created": store.creates,
            "tokens_learned": tokens_learned}
    if flight is not None:
        uid_by_slot = dict(enumerate(users)) if users else None
        incidents = flight.dump(args.flight_dir, uid_by_slot=uid_by_slot,
                                registry=registry, watchdog=watch)
        out["flight"] = {
            "dir": args.flight_dir, "steps_recorded": flight.pos,
            "flagged_slots": flight.flagged_slots(),
            "incidents": incidents}
    if args.metrics_json:
        registry.to_json(args.metrics_json)
        out["metrics_json"] = args.metrics_json
    if metrics_server is not None:
        out["metrics_port"] = metrics_server.server_address[1]
        metrics_server.shutdown()
    print(json.dumps(out, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
