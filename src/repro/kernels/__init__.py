"""Pallas TPU kernels (pl.pallas_call + BlockSpec) with jnp oracles.

  plasticity — fused dual-engine SNN step (the paper's Table I datapath)
  lif        — psum-stationary matmul + LIF (Forward Engine)
  attention  — flash attention, GQA-aware block index maps
  ssd        — Mamba2 chunked state-space scan, VMEM-resident state

Every op exposes impl="xla" (oracle; what dry-runs lower) and impl="pallas"
(TPU target; interpret=True executes the kernel body on CPU for tests).
"""
from repro.kernels.attention import attention
from repro.kernels.lif import lif_forward
from repro.kernels.plasticity import dual_engine_step
from repro.kernels.ssd import ssd, ssd_decode_step

__all__ = ["attention", "lif_forward", "dual_engine_step", "ssd",
           "ssd_decode_step"]
