"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import attention, dual_engine_step, lif_forward, ssd
from repro.kernels.ssd import ssd_decode_step
from repro.kernels.ssd.ref import ssd_scan_ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# plasticity: fused dual-engine step (the paper's core kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,n,m", [(1, 8, 8), (4, 32, 48), (2, 100, 130),
                                   (8, 128, 128), (3, 17, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dual_engine_matches_oracle(b, n, m, dtype):
    key = jax.random.PRNGKey(b * 1000 + n + m)
    ks = jax.random.split(key, 6)
    x = (jax.random.uniform(ks[0], (b, n)) > 0.5).astype(dtype)
    w = _rand(ks[1], (n, m), dtype) * 0.1
    theta = _rand(ks[2], (4, n, m), dtype) * 0.01
    v = _rand(ks[3], (b, m), dtype) * 0.1
    tp = jax.random.uniform(ks[4], (b, n)).astype(dtype)
    tq = jax.random.uniform(ks[5], (b, m)).astype(dtype)

    ref = dual_engine_step(x, w, theta, v, tp, tq, impl="xla")
    pal = dual_engine_step(x, w, theta, v, tp, tq, impl="pallas",
                           interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    for r, p, name in zip(ref, pal, ["spikes", "v", "trace", "w"]):
        np.testing.assert_allclose(
            np.asarray(r, np.float32), np.asarray(p, np.float32),
            rtol=tol, atol=tol, err_msg=name)


def test_dual_engine_plastic_flag():
    key = jax.random.PRNGKey(0)
    x = (jax.random.uniform(key, (2, 16)) > 0.5).astype(jnp.float32)
    w = 0.1 * jax.random.normal(key, (16, 16))
    th = jnp.ones((4, 16, 16))
    v = jnp.zeros((2, 16))
    tp = tq = jnp.ones((2, 16))
    _, _, _, w_off = dual_engine_step(x, w, th, v, tp, tq, plastic=False,
                                      impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(w_off), np.asarray(w), rtol=1e-6)


# ---------------------------------------------------------------------------
# lif: psum-stationary forward engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k,m", [(2, 16, 16), (4, 200, 64), (1, 784, 1024),
                                   (8, 130, 250)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lif_forward_matches_oracle(b, k, m, dtype):
    key = jax.random.PRNGKey(k + m)
    ks = jax.random.split(key, 4)
    x = (jax.random.uniform(ks[0], (b, k)) > 0.5).astype(dtype)
    w = _rand(ks[1], (k, m), dtype) * (k ** -0.5)
    v = _rand(ks[2], (b, m), dtype) * 0.1
    tr = jax.random.uniform(ks[3], (b, m)).astype(dtype)
    ref = lif_forward(x, w, v, tr, impl="xla")
    pal = lif_forward(x, w, v, tr, impl="pallas", interpret=True,
                      block_m=64, block_k=64)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    for r, p, name in zip(ref, pal, ["spikes", "v", "trace"]):
        np.testing.assert_allclose(
            np.asarray(r, np.float32), np.asarray(p, np.float32),
            rtol=tol, atol=tol, err_msg=name)


# ---------------------------------------------------------------------------
# attention: flash kernel + blocked-XLA path vs dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,sq,skv,h,hkv,d", [
    (1, 64, 64, 4, 4, 32),      # MHA square
    (2, 128, 128, 8, 2, 16),    # GQA
    (1, 100, 100, 4, 1, 64),    # ragged seq (padding path)
    (2, 1, 96, 4, 2, 32),       # decode-like (sq=1)
])
@pytest.mark.parametrize("impl", ["pallas", "xla_flash"])
def test_attention_matches_oracle(b, sq, skv, h, hkv, d, impl):
    key = jax.random.PRNGKey(sq + skv)
    ks = jax.random.split(key, 3)
    q = _rand(ks[0], (b, sq, h, d), jnp.float32)
    k = _rand(ks[1], (b, skv, hkv, d), jnp.float32)
    v = _rand(ks[2], (b, skv, hkv, d), jnp.float32)
    ref = attention(q, k, v, causal=True, impl="xla")
    out = attention(q, k, v, causal=True, impl=impl,
                    interpret=True, block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)


def test_attention_kv_len_mask():
    """kv_len masks trailing cache positions (decode semantics)."""
    key = jax.random.PRNGKey(7)
    q = _rand(key, (1, 1, 2, 16), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (1, 32, 2, 16), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (1, 32, 2, 16), jnp.float32)
    full = attention(q, k[:, :10], v[:, :10], causal=False, impl="xla")
    masked = attention(q, k, v, causal=False, kv_len=10, impl="xla_flash")
    np.testing.assert_allclose(np.asarray(masked), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# ssd: chunked scan vs literal recurrence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,l,h,p,s,chunk", [
    (1, 16, 2, 8, 4, 8), (2, 64, 4, 16, 8, 16),
    (1, 100, 2, 32, 16, 32),    # non-multiple length (padding path)
])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_ssd_matches_scan(b, l, h, p, s, chunk, impl):
    key = jax.random.PRNGKey(l + h)
    ks = jax.random.split(key, 4)
    x = _rand(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (b, l, h), jnp.float32))
    a = -jnp.exp(0.1 * jax.random.normal(ks[2], (h,)))
    bm = _rand(ks[3], (b, l, h, s), jnp.float32)
    cm = _rand(jax.random.fold_in(key, 9), (b, l, h, s), jnp.float32)
    y_ref, s_ref = ssd(x, dt, a, bm, cm, impl="scan")
    y, s_f = ssd(x, dt, a, bm, cm, impl=impl, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_ref),
                               rtol=2e-3, atol=2e-3)


def test_ssd_decode_step_matches_scan():
    """Token-by-token decode reproduces the full-sequence scan."""
    key = jax.random.PRNGKey(3)
    b, l, h, p, s = 2, 12, 2, 8, 4
    ks = jax.random.split(key, 5)
    x = _rand(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (b, l, h), jnp.float32))
    a = -jnp.exp(0.1 * jax.random.normal(ks[2], (h,)))
    bm = _rand(ks[3], (b, l, h, s), jnp.float32)
    cm = _rand(ks[4], (b, l, h, s), jnp.float32)
    y_ref, s_ref = ssd_scan_ref(x, dt, a, bm, cm)
    state = jnp.zeros((b, h, s, p))
    ys = []
    for t in range(l):
        state, y = ssd_decode_step(state, x[:, t], dt[:, t], a,
                                   bm[:, t], cm[:, t])
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_ref),
                               rtol=2e-3, atol=2e-3)
