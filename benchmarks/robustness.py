"""Robustness sweep: scenario x backend x datapath closed-loop adaptation.

The scenario engine's CI gate and the paper's core claim measured end-to-
end: for every named scenario in `repro.scenarios.SCENARIOS`, drive B env
instances against B plastic controllers through the engine fleet path
(one `lax.scan`, perturbations as data), and compare the plasticity-on run
against the frozen-weights ablation (theta gated to zero at the
perturbation onset, same program, same seed).

Asserted bounds (nonzero exit -> CI fails), on the GATE scenarios
(`scenarios.GATE_SCENARIOS`), for EVERY (backend, datapath) cell:

  * the perturbation hurts:    drop      >= MIN_DROP
  * plasticity recovers:       recovery  >= REC_PLASTIC  (>= half the drop)
  * frozen weights do not:     recovery  <= REC_FROZEN
  * zero recompiles:           ONE compiled program per (backend, datapath)
                               across the plastic run, the frozen run, and
                               every perturbation event inside the scan

The other scenarios are reported (and their schema drift-gated: losing a
scenario row or a backend cell fails `benchmarks.run --check`) but not
bounded — sensor-noise and goal-switch rows measure graceful degradation,
not recovery of a persistent disturbance.

    PYTHONPATH=src python benchmarks/robustness.py [--smoke] [--out ...]

Writes benchmarks/results/robustness.json (or *_smoke.json under --smoke).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro import scenarios as S

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# Documented bounds, asserted on the gate scenarios in every cell.
MIN_DROP = 0.02      # the perturbation must cost at least this per step
REC_PLASTIC = 0.5    # plastic recovers at least half the drop
REC_FROZEN = 0.25    # frozen recovers at most a quarter of it

IMPLS = ("xla", "pallas-interpret")
MODES = ("float32", "quant")


def run_cell(spec: S.ScenarioSpec, impl: str, mode: str,
             seed: int = 7) -> dict:
    """One (scenario, backend, datapath) cell: plastic vs frozen rollout."""
    env = spec.make_env()
    scfg = S.controller_config(env, impl=impl, quant=(mode == "quant"))
    theta = S.reference_rule(spec.env_name, scfg)
    prog = S.make_closed_loop(env, scfg, batch=spec.batch, steps=spec.steps)
    schedule = S.compile_schedule(env, spec.perturbations,
                                  jax.random.PRNGKey(123), spec.batch)
    key = jax.random.PRNGKey(seed)
    t0 = time.perf_counter()
    res_p = prog.run(theta, key, tasks=spec.tasks, schedule=schedule)
    res_f = prog.run(theta, key, tasks=spec.tasks, schedule=schedule,
                     freeze_at=spec.onset)
    jax.block_until_ready((res_p.rewards, res_f.rewards))
    wall = time.perf_counter() - t0
    mp = S.adaptation_metrics(res_p.rewards, spec.onset, spec.window)
    mf = S.adaptation_metrics(res_f.rewards, spec.onset, spec.window)
    return {
        "scenario": spec.name, "env": spec.env_name, "impl": impl,
        "mode": mode, "batch": spec.batch, "steps": spec.steps,
        "gate": spec.name in S.GATE_SCENARIOS,
        "pre": mp["pre"], "drop": mp["drop"],
        "recovery_plastic": mp["recovery_frac"],
        "recovery_frozen": mf["recovery_frac"],
        "time_to_recover": mp["time_to_recover"],
        "compiles": prog.compile_count(),
        "wall_s": wall,
    }


def check_bounds(row: dict) -> list:
    failures = []
    cell = f"{row['scenario']}/{row['impl']}/{row['mode']}"
    if row["compiles"] != 1:
        failures.append(f"{cell}: {row['compiles']} compiles (expected 1 "
                        "program across plastic+frozen+perturbations)")
    if not row["gate"]:
        return failures
    if row["drop"] < MIN_DROP:
        failures.append(f"{cell}: drop {row['drop']:.3f} < {MIN_DROP}")
    if row["recovery_plastic"] < REC_PLASTIC:
        failures.append(f"{cell}: plastic recovery "
                        f"{row['recovery_plastic']:.2f} < {REC_PLASTIC}")
    if row["recovery_frozen"] > REC_FROZEN:
        failures.append(f"{cell}: frozen recovery "
                        f"{row['recovery_frozen']:.2f} > {REC_FROZEN}")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: identical sweep (it is already CI-"
                         "sized, and the drift gate demands full scenario "
                         "coverage) but writes *_smoke.json so the "
                         "checked-in artifact is never clobbered")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        args.out = os.path.join(
            RESULTS, "robustness_smoke.json" if args.smoke
            else "robustness.json")

    names = tuple(S.SCENARIOS)
    t0 = time.time()
    rows, failures = [], []
    print("scenario,impl,mode,drop,recovery_plastic,recovery_frozen,"
          "ttr,compiles")
    for name in names:
        spec = S.SCENARIOS[name]
        for impl in IMPLS:
            for mode in MODES:
                row = run_cell(spec, impl, mode)
                rows.append(row)
                failures += check_bounds(row)
                print(f"{name},{impl},{mode},{row['drop']:.3f},"
                      f"{row['recovery_plastic']:.2f},"
                      f"{row['recovery_frozen']:.2f},"
                      f"{row['time_to_recover']},{row['compiles']}")

    out = {"smoke": bool(args.smoke), "impls": list(IMPLS),
           "modes": list(MODES),
           "gate_scenarios": list(S.GATE_SCENARIOS),
           "bounds": {"min_drop": MIN_DROP, "recovery_plastic": REC_PLASTIC,
                      "recovery_frozen": REC_FROZEN, "compiles": 1},
           "results": rows}
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)

    print(f"\nrobustness done in {time.time() - t0:.0f}s; "
          f"{len(failures)} bound violations: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
