"""Scenario registry + the reference adaptive rule.

A `ScenarioSpec` names an env, a perturbation schedule, and the episode
geometry (onset, metric window, fleet batch) — one row of the robustness
sweep (`benchmarks/robustness.py`).

`reference_rule` builds a *hand-designed* plasticity rule for the paper's
single-layer error-feedback controller, used by tests and benchmarks so the
adaptation claim is deterministic and cheap to evaluate (Phase-1 PEPG
search, `core.adaptation.optimize_rule`, remains the path for *learned*
rules).  The mechanism, in the four-term rule's language
(``dw = alpha*pre*post + beta*pre + gamma*post + delta``):

  * ``delta`` rows on the env's error channels bootstrap the wiring from
    zero weights (Phase-2 semantics: the rule, not the init, builds the
    connectivity) — weights grow toward the signed pattern ``G`` mapping
    error channels to actuators, giving a proportional controller.
  * ``alpha`` (Hebbian) on the same rows is the adaptive part: while an
    error PERSISTS, the presynaptic error trace and the postsynaptic
    action trace stay correlated, so the effective loop gain keeps
    growing — an adaptive-gain/integral action that cancels persistent
    disturbances (payload, wind, drag shifts, lost actuators).  When the
    error vanishes the pre trace vanishes and growth stops.  A frozen
    controller keeps its pre-perturbation gain and holds a steady-state
    error — exactly the plastic-vs-frozen separation the paper claims.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro import envs
from repro.core import snn
from repro.scenarios.perturb import (ActuatorDropout, GoalSwitch, ParamShift,
                                     Perturbation, SensorNoise)


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One named robustness scenario (env + schedule + episode geometry)."""

    name: str
    env_name: str
    perturbations: tuple = ()
    env_kwargs: tuple = ()     # (("wind", 1.2),) — kwargs for envs.make
    steps: int = 160
    onset: int = 60            # nominal perturbation step (metrics anchor)
    window: int = 30           # metric averaging window
    tasks: object = "train"    # ClosedLoop.init_tasks spec
    batch: int = 8

    def make_env(self) -> envs.Env:
        return envs.make(self.env_name, episode_len=self.steps,
                         **dict(self.env_kwargs))


SCENARIOS = {
    s.name: s for s in (
        # -- gate scenarios: the paper's core claim is asserted on these ----
        ScenarioSpec(
            name="stabilizer-wind", env_name="stabilizer",
            env_kwargs=(("spring", 2.5),),
            perturbations=(ParamShift(param="wind", add=3.0, step=80),),
            steps=260, onset=80, window=40, tasks="train"),
        ScenarioSpec(
            name="velocity-drag", env_name="velocity",
            perturbations=(ParamShift(param="drag", scale=3.0, step=80),),
            steps=260, onset=80, window=40, tasks=1),
        # -- sweep scenarios ------------------------------------------------
        ScenarioSpec(
            name="arm-payload", env_name="arm",
            perturbations=(ParamShift(param="payload", add=1.5, step=80),),
            steps=260, onset=80, window=40, tasks="train"),
        ScenarioSpec(
            name="stabilizer-dropout", env_name="stabilizer",
            env_kwargs=(("spring", 2.5), ("wind", 2.0)),
            perturbations=(ActuatorDropout(k=1, step=80),),
            steps=260, onset=80, window=40, tasks="train"),
        ScenarioSpec(
            name="direction-dropout", env_name="direction",
            perturbations=(ActuatorDropout(k=3, step=80),),
            steps=260, onset=80, window=40, tasks="train"),
        ScenarioSpec(
            name="direction-goalswitch", env_name="direction",
            perturbations=(GoalSwitch(step=80, source="eval"),),
            steps=260, onset=80, window=40, tasks="train"),
        ScenarioSpec(
            name="position-noise", env_name="position",
            perturbations=(SensorNoise(std=0.4, bias=0.2, step=80),),
            steps=260, onset=80, window=40, tasks="train"),
    )
}

# The two scenarios on which tests/benchmarks ASSERT the paper's claim
# (plastic recovery_frac >= 0.5, frozen below): persistent-disturbance
# scenarios where adaptive gain provably separates plastic from frozen.
GATE_SCENARIOS = ("stabilizer-wind", "velocity-drag")


# ---- reference controller + rule -------------------------------------------

def controller_config(env: envs.Env, impl: str = "xla",
                      quant: bool = False, timesteps: int = 2,
                      w_clip: float = 3.0,
                      block_m: int = 128) -> snn.SNNConfig:
    """The reference single-layer error-feedback controller for ``env``.

    ``w_clip`` doubles as the adaptive-gain ceiling — it is chosen low
    enough that the loop stays stable even with every weight pegged, so
    runaway Hebbian growth saturates instead of destabilizing.
    """
    cfg = snn.SNNConfig(layer_sizes=(env.obs_dim, env.act_dim),
                        timesteps=timesteps, plastic=True, impl=impl,
                        w_clip=w_clip, block_m=block_m)
    return snn.quant_config(cfg) if quant else cfg


def _wiring(env_name: str, env: envs.Env) -> tuple:
    """Signed error-channel -> actuator patterns for the reference rule.

    Returns ``(g_boot, g_adapt)``, both (obs_dim, act_dim): ``g_boot`` is
    the full proportional wiring the delta term ramps from zero (error
    feedback + rate damping); ``g_adapt`` marks the ERROR rows only — the
    Hebbian adaptive-gain term must not touch the damping rows, where it
    would amplify the lagged (destabilizing) velocity/action correlation.
    """
    g = np.zeros((env.obs_dim, env.act_dim), np.float32)
    a = np.zeros((env.obs_dim, env.act_dim), np.float32)
    if env_name == "stabilizer":
        g[0, :] = 1.0          # err -> both thrusters
        g[1, :] = -0.4         # velocity damping (bootstrap only)
        a[0, :] = 1.0
    elif env_name == "velocity":
        g[2, :] = 1.0          # v_err -> all gait actuators
        a[2, :] = 1.0
    elif env_name == "direction":
        axes = np.asarray(env._thruster_axes(), np.float32)  # (8, 2)
        g[4, :] = axes[:, 0]   # vel-err x -> thruster axis x
        g[5, :] = axes[:, 1]   # vel-err y -> thruster axis y
        a[4, :] = np.abs(axes[:, 0])
        a[5, :] = np.abs(axes[:, 1])
    elif env_name in ("arm", "position"):
        # obs layout [sin q(2), cos q(2), dq(2), goal(2), goal-tip(2), 1]:
        # tip error rows 8, 9; joint-rate damping rows 4, 5.  Signs follow
        # the Jacobian transpose averaged over the frontal, elbow-down
        # workspace (x_tip > 0; sin(q1+q2) < 0): e_y drives both joints
        # CCW, e_x mostly extends the elbow.
        g[9, 0] = 1.0          # e_y -> shoulder torque
        g[9, 1] = 1.0          # e_y -> elbow torque
        g[8, 1] = 0.7          # e_x -> elbow extension
        g[4, 0] = -0.4         # dq damping (bootstrap only)
        g[5, 1] = -0.4
        a[9, 0] = a[9, 1] = 1.0
        a[8, 1] = 0.7
    else:
        raise ValueError(f"no reference wiring for env {env_name!r}")
    return g, a


def reference_rule(env_name: str, scfg: snn.SNNConfig,
                   boot: float = 3e-3, hebb: float = 1e-3):
    """Hand-designed theta for the single-layer reference controller.

    ``boot`` scales the delta (bootstrap) term, ``hebb`` the Hebbian
    adaptive-gain term (see module docstring for the mechanism).  Returns
    the per-layer theta list `snn.timestep` consumes.
    """
    if scfg.num_layers != 1:
        raise ValueError("reference_rule wires the single-layer controller; "
                         f"got layer_sizes={scfg.layer_sizes}")
    env = envs.make(env_name)
    g, a = _wiring(env_name, env)
    if g.shape != (scfg.layer_sizes[0], scfg.layer_sizes[1]):
        raise ValueError(f"wiring {g.shape} does not match controller "
                         f"{tuple(scfg.layer_sizes)}")
    theta = np.zeros((4, *g.shape), np.float32)
    from repro.core.plasticity import ALPHA, DELTA
    theta[DELTA] = boot * g
    # Hebbian growth is sign-blind (it amplifies whatever correlation the
    # bootstrapped wiring creates), so alpha takes the error-row magnitudes.
    theta[ALPHA] = hebb * a
    return [jnp.asarray(theta, scfg.dtype)]
