"""Config-registry factory smoke: every arch in `repro.configs` must build
through `models.factory` — param plan, abstract trace of the serving entry
points, and (one arch per layout) a concrete tiny prefill/decode step — so
an arch the factory cannot lower fails tier-1 instead of failing at serve
time.  Also pins the factory's validation surface: the informative
firefly-snn TypeError, layout checks, and the structural slot-axis
inference the serving pool rides on (DESIGN.md §Arch-applicability).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.models import factory
from repro.models.config import ModelConfig

LM_ARCHS = [a for a in ARCHS if a != "firefly-snn"]
# one representative per layout for the concrete (allocating) smoke
LAYOUT_REPS = ["qwen3-4b", "deepseek-moe-16b", "mamba2-1.3b", "zamba2-7b"]


class TestRegistryCoverage:
    @pytest.mark.parametrize("arch", LM_ARCHS)
    def test_every_arch_builds_and_traces(self, arch):
        """plan + abstract forward/prefill/decode for EVERY registry entry
        (eval_shape: no allocation, catches lowering bugs)."""
        model = factory.build(arch, smoke=True)
        assert isinstance(model.cfg, ModelConfig)
        assert model.n_params() > 0
        assert model.plan() is not None

        cfg = model.cfg
        max_len = 16
        params = model.abstract()
        if cfg.input_mode == "tokens":
            prompt = jax.ShapeDtypeStruct((2, 4), jnp.int32)
        else:
            prompt = jax.ShapeDtypeStruct((2, 4, cfg.d_model), cfg.adtype)
        logits, cache = jax.eval_shape(
            lambda p, x: model.prefill(p, x, max_len), params, prompt)
        assert logits.shape == (2, cfg.vocab)  # last-position logits
        # decode always consumes token IDS — embeddings-mode archs (musicgen,
        # pixtral) prefill with embeddings but generate vocab ids
        step_tok = jax.ShapeDtypeStruct((2, 1), jnp.int32)
        logits2, _ = jax.eval_shape(model.decode_step, params, cache,
                                    step_tok)
        assert logits2.shape[0] == 2

    @pytest.mark.parametrize("arch", LAYOUT_REPS)
    def test_layout_rep_concrete_prefill_decode(self, arch):
        """One arch per layout runs a REAL tiny prefill + decode step."""
        model = factory.build(arch, smoke=True)
        params = model.init(jax.random.PRNGKey(0))
        prompt = jnp.arange(8, dtype=jnp.int32).reshape(2, 4) % model.cfg.vocab
        logits, cache = model.prefill(params, prompt, max_len=12)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, cache = model.decode_step(params, cache, tok[:, None])
        assert logits2.shape == (2, model.cfg.vocab)
        assert np.isfinite(np.asarray(logits2)).all()


class TestValidation:
    def test_firefly_snn_refused_with_pointer(self):
        """The SNN controller config is not an LM backbone: the error must
        say where it DOES serve (FleetScheduler), not just reject it."""
        with pytest.raises(TypeError, match="FleetScheduler"):
            factory.build(get_smoke("firefly-snn"))

    def test_unknown_arch(self):
        with pytest.raises(KeyError, match="unknown arch"):
            factory.build("qwen9-999t")

    def test_overrides_apply(self):
        model = factory.build("qwen3-4b", smoke=True, plastic_adapter=True,
                              adapter_neurons=8, adapter_quant=True)
        assert model.cfg.plastic_adapter
        assert model.cfg.adapter_neurons == 8
        assert model.cfg.adapter_quant

    def test_bad_adapter_impl_rejected(self):
        with pytest.raises(ValueError, match="adapter_impl"):
            factory.build("qwen3-4b", smoke=True, plastic_adapter=True,
                          adapter_impl="cuda")


class TestPoolPlumbing:
    @pytest.mark.parametrize("arch", LAYOUT_REPS)
    def test_cache_axes_match_pool(self, arch):
        """The inferred slot axis of every pooled-cache leaf really is the
        slot axis: its extent equals the pool size, and no other layout
        information is hand-tabled."""
        model = factory.build(arch, smoke=True)
        slots, max_len = 3, 8
        pool = jax.eval_shape(lambda: model.pool_cache(slots, max_len))
        axes = model.cache_axes(max_len)
        leaves = jax.tree.leaves(jax.tree.map(
            lambda leaf, ax: leaf.shape[ax] == slots, pool, axes))
        assert leaves and all(leaves)

    @pytest.mark.parametrize("arch", ["qwen3-4b", "zamba2-7b"])
    def test_session_from_prefill_matches_template(self, arch):
        """A squeezed B=1 prefill cache is exactly one session row of the
        pool (the scatter the scheduler admits, the pytree the store
        persists)."""
        model = factory.build(arch, smoke=True)
        max_len = 8
        params = model.abstract()
        prompt = jax.ShapeDtypeStruct((1, 4), jnp.int32)
        _, cache1 = jax.eval_shape(
            lambda p, x: model.prefill(p, x, max_len), params, prompt)
        session = jax.eval_shape(model.session_from_prefill, cache1)
        template = model.session_template(max_len)
        assert jax.tree.map(lambda a, b: (a.shape, a.dtype)
                            == (b.shape, b.dtype), session, template)
        assert all(jax.tree.leaves(jax.tree.map(
            lambda a, b: a.shape == b.shape and a.dtype == b.dtype,
            session, template)))
